"""Genetic exploration of the binary ensemble space (Algorithm 2).

Operators (Eq. 4):
  Recombination(b1, b2) = concat(b1[:i], b2[i+1:])  (single crossover point)
  Mutation(b3, S)       = flip S randomly chosen coordinates
plus uniform random exploration with probability (1 - p).
Duplicates (against both the profiled set B and the candidate set B')
are rejected, exactly as in the paper's pseudo-code.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Set

import numpy as np


def recombination(b1: np.ndarray, b2: np.ndarray,
                  rng: np.random.Generator) -> np.ndarray:
    i = int(rng.integers(0, len(b1)))
    out = b1.copy()
    out[i + 1:] = b2[i + 1:]
    return out


def mutation(b3: np.ndarray, S: int, rng: np.random.Generator) -> np.ndarray:
    """S flips == uniform sample from the Manhattan-S neighborhood."""
    out = b3.copy()
    idx = rng.choice(len(b3), size=min(S, len(b3)), replace=False)
    out[idx] = 1 - out[idx]
    return out


def explore(B: np.ndarray, n_samples: int, S: int, p: float, q: float,
            rng: Optional[np.random.Generator] = None,
            max_tries: Optional[int] = None) -> np.ndarray:
    """Algorithm 2.  B: [n_profiled, n] profiled selectors.  Returns B'
    with up to n_samples NEW selectors (never duplicating B or B').

    p: probability of genetic (vs uniform-random) exploration;
    q: probability of mutation (vs recombination) within genetic moves.
    """
    rng = rng or np.random.default_rng(0)
    B = np.asarray(B, np.int8)
    n = B.shape[1]
    seen: Set[bytes] = {row.tobytes() for row in B}
    out: List[np.ndarray] = []
    tries = 0
    max_tries = max_tries or 50 * n_samples
    while len(out) < n_samples and tries < max_tries:
        tries += 1
        rnd, rnd1 = rng.random(), rng.random()
        picks = rng.integers(0, len(B), size=3)
        b1, b2, b3 = B[picks[0]], B[picks[1]], B[picks[2]]
        if rnd > p:
            b = rng.integers(0, 2, size=n).astype(np.int8)
        elif rnd1 > q:
            b = recombination(b1, b2, rng)
        else:
            b = mutation(b3, S, rng)
        key = b.tobytes()
        if key in seen:
            continue
        seen.add(key)
        out.append(b)
    if not out:
        return np.zeros((0, n), np.int8)
    return np.stack(out)
