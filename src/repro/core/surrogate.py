"""Surrogate probability models for the accuracy and latency profilers
(§3.3.2b).  "we build two random forest as the surrogate models for
accuracy and latency" (§4.2) — fit on the binary selectors b profiled so
far, predicting f_a(V,b) and f_l(V,c,b) cheaply for candidate screening.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.forest import RandomForest


class SurrogatePair:
    def __init__(self, n_trees: int = 40, max_depth: int = 10,
                 seed: int = 0):
        self.acc = RandomForest(n_trees=n_trees, max_depth=max_depth,
                                max_features=None, seed=seed)
        self.lat = RandomForest(n_trees=n_trees, max_depth=max_depth,
                                max_features=None, seed=seed + 1)
        self._fitted = False

    @property
    def is_fitted(self) -> bool:
        return self._fitted

    @classmethod
    def from_observations(cls, B: np.ndarray, y_acc: np.ndarray,
                          y_lat: np.ndarray, **kwargs) -> "SurrogatePair":
        """A pair pre-fitted on a previous run's profiled set — the
        warm-start surrogate the online ``recompose`` screens candidate
        seeds with before any fresh profiling."""
        return cls(**kwargs).fit(B, y_acc, y_lat)

    def fit(self, B: np.ndarray, y_acc: np.ndarray, y_lat: np.ndarray
            ) -> "SurrogatePair":
        B = np.asarray(B, np.float64)
        # feature augmentation: |b| (ensemble size) is highly informative
        # for latency and helps shallow trees generalize.
        X = self._features(B)
        self.acc.fit(X, y_acc)
        self.lat.fit(X, y_lat)
        self._fitted = True
        return self

    @staticmethod
    def _features(B: np.ndarray) -> np.ndarray:
        B = np.atleast_2d(np.asarray(B, np.float64))
        size = B.sum(axis=1, keepdims=True)
        return np.concatenate([B, size], axis=1)

    def predict(self, B: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        if not self._fitted:
            raise RuntimeError("surrogates not fitted")
        X = self._features(B)
        return self.acc.predict(X), self.lat.predict(X)

    def r2(self, B: np.ndarray, y_acc: np.ndarray, y_lat: np.ndarray
           ) -> Tuple[float, float]:
        """Fig. 8's metric on held-out (unexplored) selectors."""
        X = self._features(B)
        return self.acc.score_r2(X, y_acc), self.lat.score_r2(X, y_lat)
