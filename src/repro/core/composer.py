"""Ensemble Composer (Algorithm 1): sequential model-based Bayesian
optimization with genetic exploration over binary ensemble selectors.

The profilers are injected callables:
    f_a(b) -> float   true ensemble validation accuracy  (accuracy profiler)
    f_l(b) -> float   true serving latency under config c (latency profiler)
so the same search runs against the real serving system, the DES simulator,
or an analytic model (§3.4 exposes f_l(V, c, b) as an API).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.genetic import explore
from repro.core.objective import LatencyConstrainedObjective, soft_delta
from repro.core.surrogate import SurrogatePair


@dataclasses.dataclass
class ComposerParams:
    """Algorithm 1 parameters (names follow the paper)."""
    N: int = 15                 # search iterations
    N0: int = 16                # warm-start samples
    M: int = 200                # explore samples per iteration
    K: int = 8                  # newly profiled samples per iteration
    S: int = 2                  # mutation degree
    p: float = 0.8              # P(genetic explore)  (else uniform random)
    q: float = 0.5              # P(mutation)         (else recombination)
    lam: float = 1.0            # lambda for the surrogate-side soft score
    seed: int = 0


@dataclasses.dataclass
class ComposerResult:
    b_star: np.ndarray
    accuracy: float
    latency: float
    feasible: bool
    n_profiler_calls: int
    B: np.ndarray               # all profiled selectors
    Y_acc: np.ndarray
    Y_lat: np.ndarray
    history: List[Dict]         # per-iteration trajectory (Fig. 6 / 8)
    wall_seconds: float


def _profile(B_new, f_a, f_l):
    acc = np.asarray([f_a(b) for b in B_new], np.float64)
    lat = np.asarray([f_l(b) for b in B_new], np.float64)
    return acc, lat


def _screen(surrogates: SurrogatePair, candidates: np.ndarray,
            latency_budget: float, soft, k: int) -> np.ndarray:
    """Surrogate screening (lines 17-19): estimated accuracy plus the
    one-sided soft latency penalty, top-k by score.  Shared by the
    search loop and recompose's warm-start seed selection so the
    screening objective cannot drift between them."""
    a_hat, l_hat = surrogates.predict(candidates)
    scores = a_hat + np.asarray(
        [soft(latency_budget - l) for l in l_hat])
    return candidates[np.argsort(-scores, kind="stable")[:k]]


def compose(n_models: int,
            f_a: Callable[[np.ndarray], float],
            f_l: Callable[[np.ndarray], float],
            latency_budget: float,
            params: Optional[ComposerParams] = None,
            warm_start: Optional[Sequence[np.ndarray]] = None,
            heldout_B: Optional[np.ndarray] = None,
            heldout_acc: Optional[np.ndarray] = None,
            heldout_lat: Optional[np.ndarray] = None) -> ComposerResult:
    """Algorithm 1.  ``warm_start``: seed selectors (the paper adds the
    RD/AF/LF solutions).  ``heldout_*``: optional independent selectors for
    the Fig.-8 surrogate-R² tracking (never added to B)."""
    t0 = time.time()
    prm = params or ComposerParams()
    rng = np.random.default_rng(prm.seed)
    objective = LatencyConstrainedObjective(latency_budget)
    soft = soft_delta(prm.lam)

    # ---- warm start (line 6) -------------------------------------------
    seeds: List[np.ndarray] = [np.asarray(b, np.int8)
                               for b in (warm_start or [])]
    while len(seeds) < prm.N0:
        size = int(rng.integers(1, max(2, n_models // 2)))
        b = np.zeros(n_models, np.int8)
        b[rng.choice(n_models, size=size, replace=False)] = 1
        seeds.append(b)
    # dedupe
    uniq, seen = [], set()
    for b in seeds:
        k = b.tobytes()
        if k not in seen:
            seen.add(k)
            uniq.append(b)
    B_new = np.stack(uniq)

    B = np.zeros((0, n_models), np.int8)
    Y_acc = np.zeros((0,))
    Y_lat = np.zeros((0,))
    surrogates = SurrogatePair(seed=prm.seed)
    history: List[Dict] = []
    calls = 0

    for it in range(prm.N):
        # ---- profile the new candidates (lines 9-11) -------------------
        acc_new, lat_new = _profile(B_new, f_a, f_l)
        calls += len(B_new)
        B = np.concatenate([B, B_new])
        Y_acc = np.concatenate([Y_acc, acc_new])
        Y_lat = np.concatenate([Y_lat, lat_new])

        # ---- fit surrogates (line 13) ----------------------------------
        surrogates.fit(B, Y_acc, Y_lat)

        # ---- genetic exploration (line 15, Algorithm 2) ----------------
        B_prime = explore(B, prm.M, prm.S, prm.p, prm.q, rng)
        if len(B_prime) == 0:
            break

        # ---- surrogate screening (lines 17-19) -------------------------
        B_new = _screen(surrogates, B_prime, latency_budget, soft, prm.K)

        # ---- trajectory bookkeeping ------------------------------------
        feas = Y_lat <= latency_budget
        best = (int(np.argmax(np.where(feas, Y_acc, -np.inf)))
                if feas.any() else int(np.argmin(Y_lat)))
        rec = {"iteration": it, "profiler_calls": calls,
               "best_acc": float(Y_acc[best]),
               "best_lat": float(Y_lat[best]),
               "new_acc": float(acc_new.mean()),
               "new_lat": float(lat_new.mean())}
        if heldout_B is not None and len(heldout_B):
            r2a, r2l = surrogates.r2(heldout_B, heldout_acc, heldout_lat)
            rec["r2_acc"], rec["r2_lat"] = r2a, r2l
        history.append(rec)

    # ---- final answer over the true-profiled set (line 24) -------------
    values = np.asarray([objective(a, l) for a, l in zip(Y_acc, Y_lat)])
    j = int(np.argmax(values))
    feasible = bool(np.isfinite(values[j]))
    if not feasible:                      # nothing fits: least-bad latency
        j = int(np.argmin(Y_lat))
    return ComposerResult(
        b_star=B[j].copy(), accuracy=float(Y_acc[j]),
        latency=float(Y_lat[j]), feasible=feasible,
        n_profiler_calls=calls, B=B, Y_acc=Y_acc, Y_lat=Y_lat,
        history=history, wall_seconds=time.time() - t0)


def recompose(f_a: Callable[[np.ndarray], float],
              f_l: Callable[[np.ndarray], float],
              latency_budget: float,
              warm_start: ComposerResult,
              params: Optional[ComposerParams] = None,
              seed_pool: int = 6) -> ComposerResult:
    """Incremental Algorithm-1 re-run: the online control plane's inner
    loop, warm-started from a previous ``ComposerResult``.

    Two things carry over from the previous run:

    * accuracy observations — f_a is load-invariant, so every
      previously profiled (b, acc) pair becomes a memo entry and only
      genuinely NEW selectors hit the accuracy profiler;
    * the incumbent's surrogate — refit on the previous profiled set,
      it screens a genetic neighbourhood of b_star to pick the
      warm-start seeds (prior latencies are stale in absolute terms
      under the new load but still rank candidates by cost).

    Latency is always re-profiled: f_l must reflect the CURRENT load
    (arrival rate / census), which is exactly what changed.
    """
    prev = warm_start
    n_models = prev.B.shape[1]
    prm = params or ComposerParams(N=4, N0=8, M=120, K=6)
    rng = np.random.default_rng(prm.seed + 1)
    soft = soft_delta(prm.lam)

    memo: Dict[bytes, float] = {
        np.asarray(b, np.int8).tobytes(): float(a)
        for b, a in zip(prev.B, prev.Y_acc)}

    def f_a_memo(b: np.ndarray) -> float:
        k = np.asarray(b, np.int8).tobytes()
        if k not in memo:
            memo[k] = float(f_a(b))
        return memo[k]

    # seeds: the incumbent + the best previously profiled selectors
    seeds: List[np.ndarray] = [prev.b_star.astype(np.int8)]
    for j in np.argsort(-prev.Y_acc)[:seed_pool]:
        seeds.append(prev.B[j].astype(np.int8))

    # surrogate-screened genetic neighbourhood of the incumbent
    prior = SurrogatePair.from_observations(prev.B, prev.Y_acc,
                                            prev.Y_lat, seed=prm.seed)
    cand = explore(np.stack(seeds), prm.M, prm.S, prm.p, prm.q, rng)
    if len(cand):
        take = max(0, prm.N0 - len(seeds))
        seeds += list(_screen(prior, cand, latency_budget, soft, take))

    return compose(n_models, f_a_memo, f_l, latency_budget,
                   params=prm, warm_start=seeds)
