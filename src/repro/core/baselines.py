"""Composer baselines (§4.2): RD, AF, LF, NPO.

Each returns a ComposerResult so the benchmark harness treats every method
uniformly.  RD/AF/LF greedily grow an ensemble until it EXCEEDS the latency
budget (then back off one step), per the paper's descriptions.  NPO is the
non-parametric random-subset search of Snoek et al. as modified in §4.2.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import numpy as np

from repro.core.composer import ComposerResult
from repro.core.objective import LatencyConstrainedObjective


def _result(B, Ya, Yl, budget, calls, t0, history=None) -> ComposerResult:
    obj = LatencyConstrainedObjective(budget)
    values = np.asarray([obj(a, l) for a, l in zip(Ya, Yl)])
    j = int(np.argmax(values))
    feasible = bool(np.isfinite(values[j]))
    if not feasible:
        j = int(np.argmin(Yl))
    return ComposerResult(
        b_star=B[j].copy(), accuracy=float(Ya[j]), latency=float(Yl[j]),
        feasible=feasible, n_profiler_calls=calls,
        B=np.asarray(B), Y_acc=np.asarray(Ya), Y_lat=np.asarray(Yl),
        history=history or [], wall_seconds=time.time() - t0)


def _greedy(order: List[int], n: int, f_a, f_l, budget) -> ComposerResult:
    t0 = time.time()
    b = np.zeros(n, np.int8)
    B, Ya, Yl, hist = [], [], [], []
    calls = 0
    for idx in order:
        cand = b.copy()
        cand[idx] = 1
        acc, lat = f_a(cand), f_l(cand)
        calls = len(B) + 1
        B.append(cand)
        Ya.append(acc)
        Yl.append(lat)
        hist.append({"iteration": len(B) - 1, "profiler_calls": len(B),
                     "best_acc": acc, "best_lat": lat,
                     "new_acc": acc, "new_lat": lat})
        if lat > budget:
            break                      # paper: stop once budget exceeded
        b = cand
    return _result(B, Ya, Yl, budget, calls, t0, hist)


def random_baseline(n: int, f_a, f_l, budget, seed: int = 0
                    ) -> ComposerResult:
    """RD: random single model added iteratively, without replacement."""
    rng = np.random.default_rng(seed)
    order = list(rng.permutation(n))
    return _greedy(order, n, f_a, f_l, budget)


def accuracy_first(n: int, f_a, f_l, budget, single_acc: np.ndarray
                   ) -> ComposerResult:
    """AF: next most-accurate single model first."""
    order = list(np.argsort(-np.asarray(single_acc), kind="stable"))
    return _greedy(order, n, f_a, f_l, budget)


def latency_first(n: int, f_a, f_l, budget, single_lat: np.ndarray
                  ) -> ComposerResult:
    """LF: next lowest-latency single model first."""
    order = list(np.argsort(np.asarray(single_lat), kind="stable"))
    return _greedy(order, n, f_a, f_l, budget)


def npo(n: int, f_a, f_l, budget, max_subset: int, n_calls: int,
        seed: int = 0, warm_start: Optional[List[np.ndarray]] = None
        ) -> ComposerResult:
    """NPO (modified from Snoek et al. 2012): iteratively merge a random
    subset (size bounded by the LF ensemble size) into the current set,
    profiling each merged candidate, until the call budget N is spent."""
    t0 = time.time()
    rng = np.random.default_rng(seed)
    B, Ya, Yl, hist = [], [], [], []
    cur = np.zeros(n, np.int8)
    for b0 in (warm_start or []):
        b0 = np.asarray(b0, np.int8)
        B.append(b0)
        Ya.append(f_a(b0))
        Yl.append(f_l(b0))
    while len(B) < n_calls:
        size = int(rng.integers(1, max(2, max_subset + 1)))
        subset = rng.choice(n, size=size, replace=False)
        cand = cur.copy()
        cand[subset] = 1
        acc, lat = f_a(cand), f_l(cand)
        B.append(cand)
        Ya.append(acc)
        Yl.append(lat)
        if lat <= budget:
            cur = cand                 # keep growing only while feasible
        else:
            cur = np.zeros(n, np.int8)
        feas = np.asarray(Yl) <= budget
        best_acc = float(np.max(np.where(feas, np.asarray(Ya), -np.inf))) \
            if feas.any() else float("nan")
        hist.append({"iteration": len(B) - 1, "profiler_calls": len(B),
                     "best_acc": best_acc,
                     "best_lat": float(np.min(Yl)),
                     "new_acc": acc, "new_lat": lat})
    return _result(B, Ya, Yl, budget, len(B), t0, hist)
