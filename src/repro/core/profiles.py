"""Model profiles (paper Table 3) and the model-zoo description matrix V.

One profile v in R^m per zoo member: depth, width, MACs, memory, input
modality, input length, validation ROC-AUC.  The zoo is V in R^{n x m};
a model ensemble is a binary selector b in {0,1}^n.  System configuration
c in R^d carries the resource constraints the latency profiler needs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

PROFILE_FIELDS = ("depth", "width", "macs", "memory_bytes", "modality",
                  "input_len", "val_auc")


@dataclasses.dataclass
class ModelProfile:
    """Table 3: deep model description in the model zoo."""
    name: str
    depth: int                  # stacked layers / residual blocks
    width: int                  # conv filters (or d_model)
    macs: float                 # multiply-accumulates per query
    memory_bytes: float         # parameter memory
    modality: int               # ECG lead id (0..2) or modality index
    input_len: int              # samples per segment
    val_auc: float              # ROC-AUC on validation set

    def vector(self) -> np.ndarray:
        return np.asarray([self.depth, self.width, self.macs,
                           self.memory_bytes, self.modality,
                           self.input_len, self.val_auc], np.float64)


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """c in R^d (§3.3.1): resources + load the latency profiler sees."""
    n_devices: int = 2
    n_patients: int = 64
    ingest_hz: float = 250.0          # per-patient query rate
    device_flops: float = 7.8e12      # per-device peak (V100 fp32-ish)
    device_mem_bytes: float = 32e9
    window_seconds: float = 30.0      # observation window Delta-T

    def vector(self) -> np.ndarray:
        return np.asarray([self.n_devices, self.n_patients, self.ingest_hz,
                           self.device_flops, self.device_mem_bytes,
                           self.window_seconds], np.float64)


class ModelZoo:
    """Container pairing profiles with (optional) cached validation scores
    so the accuracy profiler can evaluate true bagging ensembles cheaply
    (the paper's f_a re-evaluates the ensemble on the validation set; with
    per-model score vectors cached that is exact and O(n_samples))."""

    def __init__(self, profiles: Sequence[ModelProfile],
                 val_scores: Optional[np.ndarray] = None,
                 val_labels: Optional[np.ndarray] = None):
        self.profiles: List[ModelProfile] = list(profiles)
        self.val_scores = val_scores      # [n_models, n_val] P(stable)
        self.val_labels = val_labels      # [n_val]

    def __len__(self) -> int:
        return len(self.profiles)

    @property
    def V(self) -> np.ndarray:
        """Model description matrix V in R^{n x m}."""
        return np.stack([p.vector() for p in self.profiles])

    def names(self) -> List[str]:
        return [p.name for p in self.profiles]
