"""Random forest (numpy CART ensemble).

Used in two places, exactly as in the paper:
  * §4.2: "we build two random forest as the surrogate models for accuracy
    and latency" (fit on binary selector vectors b),
  * §4.1.1: "we simply train a random forest for each vital sign".

Regression trees; classification is regression on {0,1} targets whose
prediction is the positive-class probability (Breiman 2001 bagging).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class DecisionTree:
    def __init__(self, max_depth: int = 8, min_samples_leaf: int = 2,
                 max_features: Optional[int] = None, rng=None):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng(0)
        self.nodes: List[_Node] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "DecisionTree":
        self.nodes = []
        self._grow(np.asarray(X, np.float64), np.asarray(y, np.float64), 0)
        return self

    def _grow(self, X, y, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(np.mean(y))))
        n, d = X.shape
        if depth >= self.max_depth or n < 2 * self.min_samples_leaf \
                or np.all(y == y[0]):
            return idx
        k = self.max_features or max(1, int(np.sqrt(d)))
        feats = self.rng.choice(d, size=min(k, d), replace=False)
        best = (0.0, -1, 0.0)                   # (gain, feature, threshold)
        total_sum, total_sq = y.sum(), (y ** 2).sum()
        base = total_sq - total_sum ** 2 / n
        for f in feats:
            order = np.argsort(X[:, f], kind="stable")
            xs, ys = X[order, f], y[order]
            csum = np.cumsum(ys)[:-1]
            csq = np.cumsum(ys ** 2)[:-1]
            nl = np.arange(1, n)
            valid = xs[1:] != xs[:-1]
            nl_f = nl.astype(np.float64)
            sse = ((csq - csum ** 2 / nl_f)
                   + (total_sq - csq) - (total_sum - csum) ** 2 / (n - nl_f))
            sse = np.where(valid & (nl >= self.min_samples_leaf)
                           & (n - nl >= self.min_samples_leaf), sse, np.inf)
            j = int(np.argmin(sse))
            gain = base - sse[j]
            if np.isfinite(sse[j]) and gain > best[0] + 1e-12:
                best = (gain, f, (xs[j] + xs[j + 1]) / 2.0)
        if best[1] < 0:
            return idx
        _, f, thr = best
        mask = X[:, f] <= thr
        self.nodes[idx].feature = f
        self.nodes[idx].threshold = thr
        self.nodes[idx].left = self._grow(X[mask], y[mask], depth + 1)
        self.nodes[idx].right = self._grow(X[~mask], y[~mask], depth + 1)
        return idx

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float64)
        out = np.empty(len(X))
        for i, row in enumerate(X):
            node = self.nodes[0]
            while node.feature >= 0:
                node = self.nodes[node.left if row[node.feature]
                                  <= node.threshold else node.right]
            out[i] = node.value
        return out


class RandomForest:
    """Bootstrap-aggregated regression trees (Eq. 5 bagging on trees)."""

    def __init__(self, n_trees: int = 50, max_depth: int = 8,
                 min_samples_leaf: int = 2,
                 max_features: Optional[int] = None, seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.seed = seed
        self.trees: List[DecisionTree] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForest":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        rng = np.random.default_rng(self.seed)
        self.trees = []
        n = len(X)
        for _ in range(self.n_trees):
            boot = rng.integers(0, n, size=n)
            t = DecisionTree(self.max_depth, self.min_samples_leaf,
                             self.max_features, rng)
            t.fit(X[boot], y[boot])
            self.trees.append(t)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if not self.trees:
            raise RuntimeError("RandomForest.predict before fit")
        return np.mean([t.predict(X) for t in self.trees], axis=0)

    def score_r2(self, X: np.ndarray, y: np.ndarray) -> float:
        """R² — the metric Fig. 8 tracks for the surrogates."""
        y = np.asarray(y, np.float64)
        pred = self.predict(X)
        ss_res = float(np.sum((y - pred) ** 2))
        ss_tot = float(np.sum((y - np.mean(y)) ** 2))
        return 1.0 - ss_res / max(ss_tot, 1e-12)
