"""Optimization objectives (Eq. 1-3 and the A.6 accuracy-constrained dual).

    L_a(b) = f_a(V, b) + delta(L - f_l(V, c, b))            (Eq. 2)

delta is either the hard step (Eq. 3) or a soft linear penalty (Lagrange).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

NEG_INF = -np.inf


def hard_delta(x: float) -> float:
    """Eq. 3: -inf if the constraint is violated, else 0."""
    return NEG_INF if x < 0 else 0.0


def soft_delta(lam: float) -> Callable[[float], float]:
    """Linear (Lagrange-multiplier) activation; penalizes violation but
    does not reward slack (one-sided, as a constraint should be)."""
    def delta(x: float) -> float:
        return lam * min(x, 0.0)
    return delta


@dataclasses.dataclass(frozen=True)
class LatencyConstrainedObjective:
    """max f_a  s.t.  f_l <= L  (the paper's real-time setting)."""
    latency_budget: float
    delta: Callable[[float], float] = hard_delta

    def __call__(self, acc: float, lat: float) -> float:
        return acc + self.delta(self.latency_budget - lat)

    def feasible(self, lat: float) -> bool:
        return lat <= self.latency_budget


@dataclasses.dataclass(frozen=True)
class AccuracyConstrainedObjective:
    """A.6 dual: min f_l  s.t.  f_a >= A.  Returned as a value to MAXIMIZE
    (negated latency) so the same search code optimizes both forms."""
    accuracy_floor: float
    delta: Callable[[float], float] = hard_delta

    def __call__(self, acc: float, lat: float) -> float:
        return -lat + self.delta(acc - self.accuracy_floor)

    def feasible(self, acc: float) -> bool:
        return acc >= self.accuracy_floor
