"""Bagging ensemble prediction (Eq. 5) and the paper's accuracy metrics
(ROC-AUC, PR-AUC, F1, accuracy) in plain numpy.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


def bagging_predict(scores: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Eq. 5: E[Y|x] = (1/n_sel) sum_i b_i E_{m_i}[Y|x].

    scores: [n_models, n_samples] per-model P(Y=1); b: [n_models] in {0,1}.
    """
    b = np.asarray(b, bool)
    if not b.any():
        return np.full(scores.shape[1], 0.5)
    return scores[b].mean(axis=0)


def roc_auc(y: np.ndarray, score: np.ndarray) -> float:
    y = np.asarray(y, bool)
    pos, neg = score[y], score[~y]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    # Mann-Whitney U via ranks (ties averaged)
    order = np.argsort(np.concatenate([pos, neg]), kind="stable")
    ranks = np.empty(len(order))
    ranks[order] = np.arange(1, len(order) + 1)
    s = np.concatenate([pos, neg])
    # average ranks over ties
    sorted_s = s[order]
    i = 0
    while i < len(sorted_s):
        j = i
        while j + 1 < len(sorted_s) and sorted_s[j + 1] == sorted_s[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    r_pos = ranks[:len(pos)].sum()
    u = r_pos - len(pos) * (len(pos) + 1) / 2.0
    return float(u / (len(pos) * len(neg)))


def pr_auc(y: np.ndarray, score: np.ndarray) -> float:
    """Average precision."""
    y = np.asarray(y, bool)
    order = np.argsort(-score, kind="stable")
    ys = y[order]
    tp = np.cumsum(ys)
    precision = tp / np.arange(1, len(ys) + 1)
    n_pos = ys.sum()
    if n_pos == 0:
        return 0.0
    return float(np.sum(precision * ys) / n_pos)


def f1_score(y: np.ndarray, score: np.ndarray, thr: float = 0.5) -> float:
    y = np.asarray(y, bool)
    pred = score >= thr
    tp = float(np.sum(pred & y))
    fp = float(np.sum(pred & ~y))
    fn = float(np.sum(~pred & y))
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom else 0.0


def accuracy(y: np.ndarray, score: np.ndarray, thr: float = 0.5) -> float:
    return float(np.mean((score >= thr) == np.asarray(y, bool)))


def all_metrics(y: np.ndarray, score: np.ndarray) -> Dict[str, float]:
    return {"roc_auc": roc_auc(y, score), "pr_auc": pr_auc(y, score),
            "f1": f1_score(y, score), "accuracy": accuracy(y, score)}
