"""Online adaptive control plane.

Closes the loop between live serving telemetry and ensemble
composition: ``telemetry`` taps the serving data plane
(``EnsembleServer`` / ``MicroBatcher``) for sliding-window SLO signals
and the online empirical arrival curve; ``controller`` turns those
signals into actions (degradation-ladder shed/climb, background
recomposition); ``swap`` pre-stages selector services and hot-swaps
them atomically between micro-batch flushes with zero dropped queries.
"""
from repro.control.controller import (AdaptiveController, ControllerConfig,
                                      Decision)
from repro.control.swap import HotSwapper, SelectorLadder, SwappableService
from repro.control.telemetry import SloTelemetry, TelemetrySnapshot

__all__ = ["AdaptiveController", "ControllerConfig", "Decision",
           "HotSwapper", "SelectorLadder", "SwappableService",
           "SloTelemetry", "TelemetrySnapshot"]
