"""Online adaptive control plane.

Closes the loop between live serving telemetry and ensemble
composition: ``telemetry`` taps the serving data plane
(``EnsembleServer`` / ``MicroBatcher``) for sliding-window SLO signals
and the online empirical arrival curve; ``controller`` turns those
signals into actions (degradation-ladder shed/climb, background
recomposition); ``swap`` pre-stages selector services and hot-swaps
them atomically between micro-batch flushes with zero dropped queries.

``tiers`` lifts the unit of actuation from the fleet to the acuity
TIER: per-tier (selector, placement) lanes over a shared staging cache
(``TieredEnsemble``), per-tier telemetry slices (``TieredTelemetry``),
and a priority-aware shed/climb policy (``TieredController``) under
which stable beds shed first and critical beds hold the rich ensemble
until the predicted bound leaves no alternative.

``faults`` is the chaos side of the control plane: a deterministic
``FaultPlane`` injects device loss / worker stalls / backpressure on a
declarative schedule, and its recovery wiring (quarantine + re-place,
watchdog NaN-fail + respawn, priority-aware shedding) is what the
soak harness (``benchmarks/chaos_bench.py``) holds to zero-drop,
zero-wrong-answer invariants.
"""
from repro.control.controller import (AdaptiveController, ControllerConfig,
                                      Decision, TieredController,
                                      TieredControllerConfig)
from repro.control.faults import (DeviceLostError, FaultEvent, FaultPlane,
                                  wire_controller)
from repro.control.swap import (HotSwapper, SelectorLadder, StagingCache,
                                SwappableService)
from repro.control.telemetry import (SloTelemetry, TelemetrySnapshot,
                                     TieredTelemetry)
from repro.control.tiers import TIER_ORDER, TieredEnsemble, TierRegistry

__all__ = ["AdaptiveController", "ControllerConfig", "Decision",
           "TieredController", "TieredControllerConfig",
           "DeviceLostError", "FaultEvent", "FaultPlane",
           "wire_controller",
           "HotSwapper", "SelectorLadder", "StagingCache",
           "SwappableService", "SloTelemetry", "TelemetrySnapshot",
           "TieredTelemetry", "TIER_ORDER", "TieredEnsemble",
           "TierRegistry"]
