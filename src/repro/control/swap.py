"""Zero-downtime selector hot-swap + degradation ladder (the control
plane's actuator).

``SwappableService`` is the atomically swappable facade the server's
workers call: a micro-batch flush grabs a reference to the current
``EnsembleService`` under the lock and completes on it even if a swap
lands mid-flush, while the NEXT flush sees the new service — the ingest
queue and batcher are never touched, so no query is ever dropped by a
swap.

``HotSwapper`` owns the expensive part off the hot path: building the
new selector's stacked bucket params and compiling/warming its fused
dispatch functions (``EnsembleService`` staging), so the swap itself is
a pointer flip.  It extends ``SelectorLadder`` — an ordered
cheapest-to-richest family of selectors the controller walks: ``shed``
steps down to a cheaper ensemble under overload, ``climb`` steps back
up when load recedes.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

import numpy as np


class SwappableService:
    """Atomic indirection over the live ``EnsembleService``."""

    def __init__(self, service):
        self._lock = threading.Lock()
        self._service = service
        self.swap_count = 0

    @property
    def current(self):
        with self._lock:
            return self._service

    def swap(self, new_service):
        """Atomically install ``new_service``; returns the old one.
        In-flight flushes keep their reference and finish on the old
        service — the swap lands between flushes."""
        with self._lock:
            old, self._service = self._service, new_service
            self.swap_count += 1
            return old

    # hot-path delegates (bind these as the server's handlers)
    def predict(self, windows) -> float:
        return self.current.predict(windows)

    def predict_batch(self, batch) -> List[float]:
        return self.current.predict_batch(batch)


class SelectorLadder:
    """Degradation ladder over binary selectors, cheapest -> richest.

    Subclasses implement ``_activate(selector)`` to make a selector
    live; the base class tracks the active selector and the ladder
    position.  All transitions go through ``swap_to`` so the activation
    hook is the single swap point.
    """

    def __init__(self, initial_selector: np.ndarray):
        self.active_selector = np.asarray(initial_selector, np.int8).copy()
        self._ladder: List[np.ndarray] = []
        self._pos = -1
        # reentrant: shed()/climb() read the ladder and then swap_to()
        # under the same lock, and a concurrent set_ladder (e.g. the
        # background recompose rebuilding the family) must not let them
        # index a rung that no longer exists
        self._swap_lock = threading.RLock()

    # ------------------------------------------------------------ ladder
    def set_ladder(self, selectors: Sequence[np.ndarray]) -> None:
        """Install the cheapest->richest family (the active selector
        keeps serving; its rung is found by match, -1 if off-ladder)."""
        with self._swap_lock:
            self._ladder = [np.asarray(s, np.int8).copy()
                            for s in selectors]
            self._pos = self._find(self.active_selector)

    def _find(self, selector: np.ndarray) -> int:
        for i, s in enumerate(self._ladder):
            if np.array_equal(s, selector):
                return i
        return -1

    @property
    def ladder(self) -> List[np.ndarray]:
        return [s.copy() for s in self._ladder]

    @property
    def ladder_pos(self) -> int:
        return self._pos

    def can_shed(self) -> bool:
        return self._pos > 0

    def can_climb(self) -> bool:
        return bool(self._ladder) and 0 <= self._pos < len(self._ladder) - 1

    def shed(self) -> bool:
        """Step DOWN to the next cheaper rung (overload relief)."""
        with self._swap_lock:
            if not self.can_shed():
                return False
            self.swap_to(self._ladder[self._pos - 1])
            return True

    def climb(self) -> bool:
        """Step UP to the next richer rung (load receded)."""
        with self._swap_lock:
            if not self.can_climb():
                return False
            self.swap_to(self._ladder[self._pos + 1])
            return True

    # ------------------------------------------------------------- swap
    def swap_to(self, selector: np.ndarray) -> None:
        sel = np.asarray(selector, np.int8).copy()
        with self._swap_lock:
            self._activate(sel)
            self.active_selector = sel
            self._pos = self._find(sel)

    def _activate(self, selector: np.ndarray) -> None:
        raise NotImplementedError


class HotSwapper(SelectorLadder):
    """Pre-stages ``EnsembleService``s for selectors over a shared
    member pool and swaps them into the ``facade`` atomically.

    ``stage`` is the expensive step (param stacking + jit warmup) and
    runs OFF the hot path — by the controller's background thread, or
    eagerly for every ladder rung via ``set_ladder(prestage=True)``.
    Staged services are cached by selector, so ladder oscillation
    (shed/climb/shed) never recompiles.
    """

    def __init__(self, pool: Sequence, initial_selector: np.ndarray,
                 vitals_model=None, labs_model=None,
                 warmup_batch_sizes: Sequence[int] = (1, 2, 4, 8),
                 fused: bool = True, impl: str = "xla"):
        super().__init__(initial_selector)
        self.pool = list(pool)
        self.vitals_model = vitals_model
        self.labs_model = labs_model
        self.warmup_batch_sizes = tuple(warmup_batch_sizes)
        self.fused = fused
        self.impl = impl
        self._staged: Dict[bytes, object] = {}
        self._stage_lock = threading.Lock()    # guards the cache dict
        self._build_lock = threading.Lock()    # serializes builds
        self.facade = SwappableService(self.stage(initial_selector))

    def stage(self, selector: np.ndarray):
        """Build + warm the selector's service (stacked bucket params,
        compiled fused dispatch at the pow2 flush sizes).  Idempotent:
        cached per selector; concurrent staging of the same selector
        waits on the build lock instead of duplicating the expensive
        stack-and-compile."""
        from repro.serving.pipeline import EnsembleService
        sel = np.asarray(selector, np.int8)
        key = sel.tobytes()
        with self._stage_lock:
            svc = self._staged.get(key)
        if svc is not None:
            return svc
        with self._build_lock:
            with self._stage_lock:             # built while we waited?
                svc = self._staged.get(key)
            if svc is not None:
                return svc
            svc = EnsembleService.for_selector(
                self.pool, sel, vitals_model=self.vitals_model,
                labs_model=self.labs_model, fused=self.fused,
                impl=self.impl)
            if len(svc.members):
                svc.warmup(batch_sizes=self.warmup_batch_sizes)
            with self._stage_lock:
                self._staged[key] = svc
            return svc

    def set_ladder(self, selectors: Sequence[np.ndarray],
                   prestage: bool = True) -> None:
        super().set_ladder(selectors)
        if prestage:
            for s in self._ladder:
                self.stage(s)

    def _activate(self, selector: np.ndarray) -> None:
        self.facade.swap(self.stage(selector))
        self._evict_stale(selector)

    def _evict_stale(self, active: np.ndarray) -> None:
        """Drop staged services that are neither active nor a ladder
        rung: under drifting load every recompose can yield a novel
        selector, and each staged service holds stacked param copies +
        compiled dispatch fns — without eviction a long-running
        deployment leaks until OOM.  (A service still finishing an
        in-flight flush stays alive via the flush's reference.)"""
        keep = {np.asarray(active, np.int8).tobytes()}
        with self._swap_lock:
            keep.update(s.tobytes() for s in self._ladder)
        with self._stage_lock:
            for k in [k for k in self._staged if k not in keep]:
                del self._staged[k]
