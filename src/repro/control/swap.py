"""Zero-downtime selector hot-swap + degradation ladder (the control
plane's actuator).

``SwappableService`` is the atomically swappable facade the server's
workers call: a micro-batch flush grabs a reference to the current
``EnsembleService`` under the lock and completes on it even if a swap
lands mid-flush, while the NEXT flush sees the new service — the ingest
queue and batcher are never touched, so no query is ever dropped by a
swap.

``HotSwapper`` owns the expensive part off the hot path: building the
new selector's stacked bucket params and compiling/warming its fused
dispatch functions (``EnsembleService`` staging), so the swap itself is
a pointer flip.  It extends ``SelectorLadder`` — an ordered
cheapest-to-richest family of selectors the controller walks: ``shed``
steps down to a cheaper ensemble under overload, ``climb`` steps back
up when load recedes.

Staging warms the full pow2 flush-size ladder (default ``(1, 2, 4,
8)``), and the warmup inputs are the module-shared window packs of
``pipeline._warmup_pack`` — a recomposition that stages a new
(selector, placement) pair re-uses both the cached bucket programs AND
the same (length, flush-size) window buffers, so hot-swap staging
never re-materializes windows.  The data plane's window
representation is selector-independent (one ``[Ppad, leads, L]`` pack
per flush, or ``DeviceWindowRef``s into the device-resident ingest
rings), so a swap landing mid-stream changes WHICH stacked params the
next flush dispatches against, never how its windows are built:
device-ingest refs keep flowing through ``facade.predict_batch``
across recompose / re_place with zero re-marshaling.

Placement is the second actuated dimension: with ``n_devices > 1`` (or
an explicit ``placement_fn``) ``stage`` pre-stages ``(selector,
placement)`` PAIRS — the selector's stacked bucket params sharded
across devices per an LPT plan over measured bucket costs — and
``re_place`` re-derives the plan from freshly measured costs and swaps
it in under the SAME selector (the controller's RE-PLACE action).

Tiered serving shares one ``StagingCache`` across many ladders (one
lane per acuity tier, ``control.tiers.TieredEnsemble``): two tiers
standing on the same (selector, placement) pair serve through the SAME
staged service — one param stack, one warmed dispatch set — and
eviction keeps every lane's active pair pinned, so tier A churning
through novel pairs can never evict tier B's live service.
"""
from __future__ import annotations

import logging
import threading
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.placement import Placement, placement_signature

log = logging.getLogger(__name__)


class StagingCache:
    """Shared (selector, placement)-keyed staging state for one or more
    ``HotSwapper`` lanes over the same member pool.

    Holds the staged-service / measurement-service / derived-placement
    caches plus the locks that guard them, and a per-lane PIN of each
    lane's active composite key.  Eviction (``HotSwapper._evict_stale``)
    computes its keep-set across ALL registered lanes — actives via the
    pins, ladder rungs by reading each lane's rung list — so a
    multi-tier deployment staging T tiers x R rungs reuses identical
    pairs instead of duplicating them, and no lane's churn can evict
    another lane's live pair.
    """

    def __init__(self):
        self.lock = threading.Lock()       # guards the cache dicts + pins
        self.build_lock = threading.Lock()  # serializes expensive builds
        self.staged: Dict[bytes, object] = {}
        self.measure: Dict[bytes, object] = {}
        self.placements: Dict[bytes, Optional[Placement]] = {}
        self.lanes: List["HotSwapper"] = []
        self.pins: Dict[int, bytes] = {}   # id(lane) -> active pair key

    def register(self, lane: "HotSwapper") -> None:
        with self.lock:
            self.lanes.append(lane)

    def unregister(self, lane: "HotSwapper") -> None:
        """Retire a lane (e.g. a tier being rebuilt on the shared
        cache): drop its pin and stop counting its active/ladder in
        eviction keep-sets — without this a dead lane's staged services
        would be retained forever."""
        with self.lock:
            self.lanes = [l for l in self.lanes if l is not lane]
            self.pins.pop(id(lane), None)

    def pin(self, lane: "HotSwapper", key: bytes) -> None:
        with self.lock:
            self.pins[id(lane)] = key


def rungs_monotone(lanes, order) -> bool:
    """The shed-order invariant: every lane on-ladder, rung positions
    non-decreasing along ``order`` (shed-first -> shed-last) — a stable
    bed is never on a richer rung than a critical bed.  Shared by
    ``control.tiers.TieredEnsemble`` and the tiered controller so the
    two can never disagree about what monotone means."""
    pos = [lanes[t].ladder_pos for t in order]
    return all(p >= 0 for p in pos) and all(
        a <= b for a, b in zip(pos, pos[1:]))


class SwappableService:
    """Atomic indirection over the live ``EnsembleService``."""

    def __init__(self, service):
        self._lock = threading.Lock()
        self._service = service
        self.swap_count = 0

    @property
    def current(self):
        with self._lock:
            return self._service

    def swap(self, new_service):
        """Atomically install ``new_service``; returns the old one.
        In-flight flushes keep their reference and finish on the old
        service — the swap lands between flushes."""
        with self._lock:
            old, self._service = self._service, new_service
            self.swap_count += 1
            return old

    # hot-path delegates (bind these as the server's handlers)
    def predict(self, windows) -> float:
        return self.current.predict(windows)

    def predict_batch(self, batch) -> List[float]:
        return self.current.predict_batch(batch)


class SelectorLadder:
    """Degradation ladder over binary selectors, cheapest -> richest.

    Subclasses implement ``_activate(selector)`` to make a selector
    live; the base class tracks the active selector and the ladder
    position.  All transitions go through ``swap_to`` so the activation
    hook is the single swap point.
    """

    def __init__(self, initial_selector: np.ndarray):
        self.active_selector = np.asarray(initial_selector, np.int8).copy()
        self._ladder: List[np.ndarray] = []
        self._pos = -1
        # reentrant: shed()/climb() read the ladder and then swap_to()
        # under the same lock, and a concurrent set_ladder (e.g. the
        # background recompose rebuilding the family) must not let them
        # index a rung that no longer exists
        self._swap_lock = threading.RLock()

    # ------------------------------------------------------------ ladder
    def set_ladder(self, selectors: Sequence[np.ndarray]) -> None:
        """Install the cheapest->richest family (the active selector
        keeps serving; its rung is found by match, -1 if off-ladder)."""
        with self._swap_lock:
            self._ladder = [np.asarray(s, np.int8).copy()
                            for s in selectors]
            self._pos = self._find(self.active_selector)

    def _find(self, selector: np.ndarray) -> int:
        for i, s in enumerate(self._ladder):
            if np.array_equal(s, selector):
                return i
        return -1

    @property
    def ladder(self) -> List[np.ndarray]:
        return [s.copy() for s in self._ladder]

    @property
    def ladder_pos(self) -> int:
        return self._pos

    def can_shed(self) -> bool:
        return self._pos > 0

    def can_climb(self) -> bool:
        return bool(self._ladder) and 0 <= self._pos < len(self._ladder) - 1

    def shed(self) -> bool:
        """Step DOWN to the next cheaper rung (overload relief)."""
        with self._swap_lock:
            if not self.can_shed():
                return False
            self.swap_to(self._ladder[self._pos - 1])
            return True

    def climb(self) -> bool:
        """Step UP to the next richer rung (load receded)."""
        with self._swap_lock:
            if not self.can_climb():
                return False
            self.swap_to(self._ladder[self._pos + 1])
            return True

    # ------------------------------------------------------------- swap
    def swap_to(self, selector: np.ndarray) -> None:
        sel = np.asarray(selector, np.int8).copy()
        with self._swap_lock:
            self._activate(sel)
            self.active_selector = sel
            self._pos = self._find(sel)

    def _activate(self, selector: np.ndarray) -> None:
        raise NotImplementedError


class HotSwapper(SelectorLadder):
    """Pre-stages ``EnsembleService``s for selectors over a shared
    member pool and swaps them into the ``facade`` atomically.

    ``stage`` is the expensive step (param stacking + jit warmup) and
    runs OFF the hot path — by the controller's background thread, or
    eagerly for every ladder rung via ``set_ladder(prestage=True)``.
    Staged services are cached by selector, so ladder oscillation
    (shed/climb/shed) never recompiles.
    """

    def __init__(self, pool: Sequence, initial_selector: np.ndarray,
                 vitals_model=None, labs_model=None,
                 warmup_batch_sizes: Sequence[int] = (1, 2, 4, 8),
                 fused: bool = True, impl: str = "xla",
                 n_devices: int = 1,
                 devices: Optional[Sequence] = None,
                 placement_fn: Optional[
                     Callable[[np.ndarray], Placement]] = None,
                 cost_reps: int = 3,
                 staging: Optional[StagingCache] = None,
                 speeds: Optional[Sequence[float]] = None,
                 plan_batch: Optional[int] = None):
        super().__init__(initial_selector)
        self.pool = list(pool)
        # fault-plane seam: when set, called with every service stage()
        # hands out (including cache hits), so a chaos harness can arm
        # each service's dispatch_guard no matter which swap installed it
        self.service_hook: Optional[Callable] = None
        self.quarantined: List = []        # devices removed by fault recovery
        self._devices_gen = 0              # bumped by quarantine_device
        # called as hook(device, svc) AFTER a successful quarantine
        # swap, with the survivor facade's new service — the seam a
        # SlotEngine (which holds a direct service ref, not the
        # facade) uses to learn about flush-path failovers.  Hooks may
        # run on the failover thread; they must not block on locks the
        # triggering dispatch path might hold.
        self.quarantine_hooks: List[Callable] = []
        self.vitals_model = vitals_model
        self.labs_model = labs_model
        self.warmup_batch_sizes = tuple(warmup_batch_sizes)
        self.fused = fused
        self.impl = impl
        # placement actuation: n_devices > 1 shards staged services via
        # LPT over measured bucket costs; placement_fn overrides the
        # derivation (deterministic plans for tests / external planners)
        self.n_devices = n_devices
        self.devices = list(devices) if devices is not None else None
        self.placement_fn = placement_fn
        self.cost_reps = cost_reps
        # heterogeneous pool: speeds[i] is devices[i]'s relative speed
        # (work units/s vs the reference device costs are measured on);
        # None == homogeneous.  Quarantine keeps the SURVIVOR
        # sub-vector aligned with the shrunken device list.
        self.speeds = list(speeds) if speeds is not None else None
        if self.speeds is not None and any(s <= 0 for s in self.speeds):
            raise ValueError(f"speeds must be > 0: {self.speeds}")
        # flush rung bucket costs are measured at when planning (None =
        # the pipeline's representative PLAN_BATCH default)
        self.plan_batch = plan_batch
        self.active_placement: Optional[Placement] = None
        # staging may be SHARED between lanes (per-acuity-tier ladders
        # over one pool): identical (selector, placement) pairs then
        # resolve to one staged service, and eviction is pin-aware
        # across every lane registered on the cache
        self._staging = staging if staging is not None else StagingCache()
        self._staging.register(self)
        self._placements = self._staging.placements
        self._measure_cache = self._staging.measure
        self._staged = self._staging.staged
        self._stage_lock = self._staging.lock
        self._build_lock = self._staging.build_lock
        self.facade = SwappableService(self.stage(initial_selector))
        self.active_placement = self.placement_for(initial_selector)
        self._staging.pin(self, self._skey(self.active_selector,
                                           self.active_placement))

    @property
    def sharded(self) -> bool:
        return self.placement_fn is not None or self.n_devices > 1

    # -------------------------------------------------------- placement
    def placement_for(self, selector: np.ndarray,
                      fresh: bool = False) -> Optional[Placement]:
        """The selector's device plan (None when unsharded).  Plans are
        cached per selector so ladder oscillation reuses staged shards;
        ``fresh=True`` re-measures bucket costs and re-runs LPT — the
        re-derivation recompose/RE-PLACE triggers ask for."""
        if not self.sharded:
            return None
        key = np.asarray(selector, np.int8).tobytes()
        with self._stage_lock:
            if not fresh and key in self._placements:
                return self._placements[key]
        if self.placement_fn is not None:
            pl = self.placement_fn(np.asarray(selector, np.int8))
        else:
            import jax
            # clamp to the real device pool: an n_devices beyond it
            # would plan parallelism that cannot exist (the service
            # refuses such plans rather than folding slots silently)
            avail = len(self.devices) if self.devices is not None \
                else jax.device_count()
            k = min(self.n_devices, avail)
            msvc = self._measure_service(selector)
            pl = msvc.plan_placement(k, reps=self.cost_reps,
                                     batch=self.plan_batch,
                                     speeds=self._slot_speeds(k)) \
                if len(msvc.members) else None
        with self._stage_lock:
            self._placements[key] = pl
        return pl

    def _slot_speeds(self, k: int) -> Optional[List[float]]:
        """The first ``k`` device speeds (plan slots map onto the first
        k devices of the pool); None for a homogeneous pool."""
        if self.speeds is None:
            return None
        if len(self.speeds) < k:
            raise ValueError(f"{len(self.speeds)} speeds < {k} "
                             f"plan slots")
        return list(self.speeds[:k])

    def _measure_service(self, selector: np.ndarray):
        """Unsharded service used to measure bucket costs, cached per
        selector: only the TIMING must be fresh on re-derivation —
        re-stacking the whole selected zoo's params each time would
        multiply actuation latency for an identical result."""
        from repro.serving.pipeline import EnsembleService
        key = np.asarray(selector, np.int8).tobytes()
        with self._stage_lock:
            svc = self._measure_cache.get(key)
        if svc is None:
            svc = EnsembleService.for_selector(
                self.pool, selector, fused=True, impl=self.impl)
            with self._stage_lock:
                svc = self._measure_cache.setdefault(key, svc)
        return svc

    def _skey(self, selector: np.ndarray,
              placement: Optional[Placement]) -> bytes:
        return np.asarray(selector, np.int8).tobytes() + b"|" \
            + placement_signature(placement)

    def stage(self, selector: np.ndarray,
              placement: Optional[Placement] = None):
        """Build + warm the (selector, placement) service: stacked
        bucket params (``device_put``-sharded when placed), compiled
        fused dispatch at the pow2 flush sizes.  ``placement=None``
        derives the selector's plan (or stays unsharded).  Idempotent:
        cached per pair; concurrent staging of the same pair waits on
        the build lock instead of duplicating the expensive
        stack-and-compile."""
        from repro.serving.pipeline import EnsembleService
        sel = np.asarray(selector, np.int8)
        if placement is None:
            placement = self.placement_for(sel)
        key = self._skey(sel, placement)
        with self._stage_lock:
            svc = self._staged.get(key)
        if svc is not None:
            return self._arm(svc)
        with self._build_lock:
            with self._stage_lock:             # built while we waited?
                svc = self._staged.get(key)
            if svc is not None:
                return self._arm(svc)
            svc = EnsembleService.for_selector(
                self.pool, sel, vitals_model=self.vitals_model,
                labs_model=self.labs_model, fused=self.fused,
                impl=self.impl, placement=placement,
                devices=self.devices)
            if len(svc.members):
                svc.warmup(batch_sizes=self.warmup_batch_sizes)
            with self._stage_lock:
                self._staged[key] = svc
            return self._arm(svc)

    def _arm(self, svc):
        hook = self.service_hook
        if hook is not None:
            hook(svc)
        return svc

    def set_ladder(self, selectors: Sequence[np.ndarray],
                   prestage: bool = True) -> None:
        super().set_ladder(selectors)
        if prestage:
            for s in self._ladder:
                self.stage(s)

    def _activate(self, selector: np.ndarray) -> None:
        pl = self.placement_for(selector)
        self.facade.swap(self.stage(selector, pl))
        self.active_placement = pl
        self._staging.pin(self, self._skey(selector, pl))
        self._evict_stale(selector)

    def re_place(self, placement: Optional[Placement] = None) -> bool:
        """Hot-swap the ACTIVE selector onto a new device plan — the
        controller's RE-PLACE action.  ``placement=None`` re-derives
        the LPT plan from MEASURED DRIFT first: the live service's
        per-shard retire EWMAs (``live_bucket_costs``) reflect what
        devices are actually doing right now — a device that slowed
        down shows up there, never in a fresh offline measurement pass
        on the reference device.  Only when no live costs exist yet
        (no flush observed, or a non-bucket-aligned plan) does it fall
        back to the fresh offline measurement.  Returns True iff the
        plan actually changed (a no-op re-derivation must not cost a
        swap or start a controller cooldown).

        The expensive steps — cost measurement and staging — run
        OUTSIDE ``_swap_lock``, so an emergency shed/climb is never
        blocked behind a rebalance; only the pointer flip is locked.
        """
        with self._swap_lock:
            sel = self.active_selector.copy()
            gen = self._devices_gen
        pl = placement
        if pl is None:
            pl = self._drift_placement(sel)
        if pl is None:
            pl = self.placement_for(sel, fresh=True)
        if placement_signature(pl) \
                == placement_signature(self.active_placement):
            return False
        svc = self.stage(sel, pl)          # build/warm off the lock
        with self._swap_lock:
            if not np.array_equal(sel, self.active_selector):
                return False   # raced a selector swap, whose own
                               # activation derived a fresh plan
            if gen != self._devices_gen:
                return False   # raced a device quarantine: this plan
                               # may still reference the dead device
            with self._stage_lock:
                self._placements[np.asarray(sel, np.int8).tobytes()] = pl
            self.facade.swap(svc)
            self.active_placement = pl
            self._staging.pin(self, self._skey(sel, pl))
            self._evict_stale(sel)
            return True

    def _drift_placement(self, sel: np.ndarray) -> Optional[Placement]:
        """LPT plan re-derived from the ACTIVE service's live shard
        retire EWMAs (device-independent work units — de-normalized by
        each shard's slot speed), at the current slot count and speed
        sub-vector.  None when drift can't drive a plan: an external
        ``placement_fn`` owns planning, the deployment is unsharded, or
        the live service hasn't observed every bucket yet."""
        if self.placement_fn is not None or not self.sharded:
            return None
        svc = self.facade.current
        live = getattr(svc, "live_bucket_costs", None)
        costs = live() if callable(live) else None
        if costs is None or not len(getattr(svc, "members", ())):
            return None
        import jax
        avail = len(self.devices) if self.devices is not None \
            else jax.device_count()
        k = min(self.n_devices, avail)
        return svc.plan_placement(k, bucket_costs=costs,
                                  speeds=self._slot_speeds(k))

    @staticmethod
    def _failover_placement(old: Optional[Placement],
                            dead_slot: int) -> Optional[Placement]:
        """Minimal-move interim plan after losing ``dead_slot``: every
        surviving slot keeps its members (their bucket programs are
        already compiled on their devices — same fn, same shapes, same
        device — so re-staging them is a jit-cache HIT, not a
        recompile), and only the dead slot's members move, onto the
        least-loaded survivor.  Deliberately unbalanced: failover
        optimizes time-to-first-correct-score; the controller's
        RE-PLACE rebalances in the background once the imbalance shows
        up in its service profile."""
        if old is None or not (0 <= dead_slot < old.n_slots) \
                or old.n_slots < 2:
            return None
        assignment = [list(s) for s in old.assignment]
        loads = list(old.loads)
        speeds = None if old.speeds is None else [
            s for i, s in enumerate(old.speeds) if i != dead_slot]
        moved, moved_load = assignment.pop(dead_slot), loads.pop(dead_slot)
        # least-FINISH-TIME survivor absorbs the orphans: on a
        # heterogeneous pool the least-loaded slot may be the slowest
        j = int(np.argmin([l / speeds[i] if speeds is not None else l
                           for i, l in enumerate(loads)]))
        assignment[j] = assignment[j] + moved
        loads[j] += moved_load
        return Placement(assignment=assignment, loads=loads,
                         speeds=speeds)

    def quarantine_device(self, device) -> bool:
        """Remove a dead device from the pool and hot-swap the ACTIVE
        selector onto a plan over the survivors — the device-loss
        recovery path (``control.faults.FaultPlane``).

        Two-phase: the swap lands on a MINIMAL-MOVE interim plan
        (``_failover_placement`` — only the dead slot's members change
        device, so staging re-uses the survivors' compiled bucket
        programs and recovery costs one slot's worth of compilation,
        not a full re-stage), and the proper LPT rebalance is left to
        the controller's RE-PLACE action, which sees the interim plan's
        imbalance in its service profile.  Only when no usable prior
        plan exists does failover fall back to a full fresh derivation.

        Returns False when failover is impossible: an unsharded
        deployment (everything lives on the one default device) or a
        device not in this swapper's pool.  No query is dropped on the
        way through: the ingest queue and batcher are untouched, the
        facade swap is atomic, and the flush that observed the loss
        simply retries on the recovered service.

        Every staged service and cached plan is invalidated wholesale —
        any of them may pin stacked params on the dead device; lanes
        sharing the staging cache restage lazily on their next swap.
        """
        if not self.sharded:
            return False
        import jax
        with self._swap_lock:
            devs = list(self.devices) if self.devices is not None \
                else list(jax.devices())
            if device not in devs or len(devs) <= 1:
                return False
            dead_slot = devs.index(device)
            devs.remove(device)
            self.devices = devs
            self.n_devices = min(self.n_devices, len(devs))
            if self.speeds is not None and dead_slot < len(self.speeds):
                # survivor speed sub-vector stays aligned with devices
                self.speeds = (list(self.speeds[:dead_slot])
                               + list(self.speeds[dead_slot + 1:]))
            self._devices_gen += 1
            sel = self.active_selector.copy()
            old_pl = self.active_placement
        with self._stage_lock:
            self._staged.clear()
            self._placements.clear()
        pl = self._failover_placement(old_pl, dead_slot)
        if pl is None:
            pl = self.placement_for(sel, fresh=True)
        svc = self.stage(sel, pl)          # build/warm off the swap lock
        with self._swap_lock:
            if not np.array_equal(sel, self.active_selector):
                # raced a shed/climb: restage for the NEW active so the
                # live service is guaranteed off the dead device
                sel = self.active_selector.copy()
                pl = self.placement_for(sel, fresh=True)
                svc = self.stage(sel, pl)
            with self._stage_lock:
                self._placements[np.asarray(sel, np.int8).tobytes()] = pl
            self.facade.swap(svc)
            self.active_placement = pl
            self._staging.pin(self, self._skey(sel, pl))
        self.quarantined.append(device)
        for hook in list(self.quarantine_hooks):
            try:
                hook(device, svc)
            except Exception:
                log.exception("quarantine hook failed")
        return True

    def _evict_stale(self, active: np.ndarray) -> None:
        """Drop staged services that are neither active nor a ladder
        rung: under drifting load every recompose can yield a novel
        (selector, placement) pair, and each staged service holds
        stacked param copies + compiled dispatch fns — without eviction
        a long-running deployment leaks until OOM.  (A service still
        finishing an in-flight flush stays alive via the flush's
        reference.)

        With a SHARED staging cache the keep-set spans every registered
        lane: each lane's active pair via its pin (the pin carries the
        exact composite key, so a lane whose recorded placement for a
        selector was refreshed by ANOTHER lane's re-derivation keeps its
        live pair regardless), plus every lane's ladder rungs.  Other
        lanes' rung lists are read without their swap locks — they are
        replaced wholesale under set_ladder, and a stale read can only
        over-retain for one cycle, never evict a pinned active."""
        with self._swap_lock:
            rungs = [np.asarray(active, np.int8)] + list(self._ladder)
        for lane in list(self._staging.lanes):
            if lane is self:
                continue
            rungs.append(np.asarray(lane.active_selector, np.int8))
            rungs.extend(list(lane._ladder))
        with self._stage_lock:
            keep = {s.tobytes() + b"|"
                    + placement_signature(self._placements.get(
                        s.tobytes())) for s in rungs}
            keep |= set(self._staging.pins.values())
            for k in [k for k in self._staged if k not in keep]:
                del self._staged[k]
            keep_sel = {s.tobytes() for s in rungs}
            keep_sel |= {k.split(b"|", 1)[0]
                         for k in self._staging.pins.values()}
            for k in [k for k in self._measure_cache
                      if k not in keep_sel]:
                del self._measure_cache[k]
