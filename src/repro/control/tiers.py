"""Per-acuity-tier serving state: the unit of controller actuation is a
TIER, not the fleet.

HOLMES composes ensembles "for different targets ... and potentially
personalized predictions"; clinically, not every bed deserves the same
degradation behaviour under load.  This module partitions patients into
acuity tiers (``critical``/``elevated``/``stable`` by default,
re-assignable at runtime as a patient's state evolves) and gives each
tier its OWN ``(selector, placement)`` pair and degradation-ladder rung:

* ``TierRegistry``  — thread-safe patient -> tier map with runtime
  re-assignment and one-step ``escalate`` (mid-stay acuity changes);
* ``TieredEnsemble`` — one ``HotSwapper`` lane per tier over a SHARED
  ``StagingCache``: tiers standing on the same rung serve through the
  same staged service (one param stack, one warmed dispatch set), and
  pin-aware eviction means one tier's churn can never evict another
  tier's live pair.  All lanes share one ladder family (cheapest ->
  richest), so rung indices are comparable across tiers — the
  substrate of the priority-aware shed-order invariant
  (``control.controller.TieredController``): a stable bed is never on
  a richer rung than a critical bed.

The data-plane side (per-tier query routing and within-tier
micro-batching) lives in ``serving.server``/``serving.queues``; this
module is the control-plane state those route through.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.control.swap import HotSwapper, StagingCache, rungs_monotone

# shed-first -> shed-last: the LAST tier is the highest acuity and
# holds the rich ensemble until the predicted bound leaves no choice
TIER_ORDER = ("stable", "elevated", "critical")


class TierRegistry:
    """Thread-safe patient-id -> acuity-tier map, re-assignable at
    runtime.  Unknown patients default to ``default`` (the lowest
    acuity unless configured otherwise): a bed the platform knows
    nothing about sheds first, never holds capacity hostage."""

    def __init__(self, tiers: Sequence[str] = TIER_ORDER,
                 default: Optional[str] = None):
        if not tiers:
            raise ValueError("tiers must be non-empty")
        self.tiers = tuple(tiers)
        self.default = default if default is not None else self.tiers[0]
        if self.default not in self.tiers:
            raise ValueError(f"default {self.default!r} not in "
                             f"{self.tiers}")
        self._lock = threading.Lock()
        self._tier: Dict[int, str] = {}

    def assign(self, patient: int, tier: str) -> None:
        if tier not in self.tiers:
            raise ValueError(f"unknown tier {tier!r} (have {self.tiers})")
        with self._lock:
            self._tier[patient] = tier

    def tier_of(self, patient: int) -> str:
        with self._lock:
            return self._tier.get(patient, self.default)

    def escalate(self, patient: int) -> str:
        """Move the patient one tier up (toward the last, highest-acuity
        tier); returns the new tier.  Already-top patients stay put."""
        with self._lock:
            cur = self._tier.get(patient, self.default)
            i = self.tiers.index(cur)
            new = self.tiers[min(i + 1, len(self.tiers) - 1)]
            self._tier[patient] = new
            return new

    def discharge(self, patient: int) -> None:
        with self._lock:
            self._tier.pop(patient, None)

    def census(self) -> Dict[str, int]:
        """Known patients per tier (excludes defaulted unknowns)."""
        with self._lock:
            out = {t: 0 for t in self.tiers}
            for t in self._tier.values():
                out[t] += 1
            return out


class TieredEnsemble:
    """One ``HotSwapper`` lane per acuity tier over a shared pool and a
    shared ``StagingCache``.

    Every lane walks the SAME cheapest->richest ladder family
    (``set_ladder``), each at its own rung, so rung positions are
    comparable across tiers and identical (selector, placement) pairs
    are staged ONCE regardless of how many tiers stand on them.  The
    batch-aware server routes each flush through ``predict_batch(batch,
    tier)`` — one tier per flush, so cross-patient micro-batching
    coalesces patients within a tier only.
    """

    def __init__(self, pool: Sequence,
                 initial: Union[np.ndarray,
                                Mapping[str, np.ndarray]],
                 tiers: Sequence[str] = TIER_ORDER,
                 registry: Optional[TierRegistry] = None,
                 **lane_kwargs):
        if not tiers:
            raise ValueError("tiers must be non-empty")
        self.tiers = tuple(tiers)
        self.registry = registry if registry is not None \
            else TierRegistry(self.tiers)
        self.staging = StagingCache()
        self.lanes: Dict[str, HotSwapper] = {}
        for t in self.tiers:
            sel = initial[t] if isinstance(initial, Mapping) else initial
            self.lanes[t] = HotSwapper(pool, sel, staging=self.staging,
                                       **lane_kwargs)

    # --------------------------------------------------------- ladders
    def set_ladder(self, selectors: Sequence[np.ndarray],
                   prestage: bool = True) -> None:
        """Install ONE cheapest->richest family on every lane (staged
        once thanks to the shared cache)."""
        for t in self.tiers:
            self.lanes[t].set_ladder(selectors, prestage=prestage)
            prestage = False          # first lane already staged them

    def lane(self, tier: str) -> HotSwapper:
        return self.lanes[tier]

    def rungs(self) -> Dict[str, int]:
        return {t: self.lanes[t].ladder_pos for t in self.tiers}

    def monotone(self) -> bool:
        """Shed-order invariant: rung positions are non-decreasing along
        the tier order (a stable bed never richer than a critical
        one).  Off-ladder lanes (-1) break comparability and count as a
        violation."""
        return rungs_monotone(self.lanes, self.tiers)

    def lane_metrics(self) -> Dict[str, Dict[str, float]]:
        """Per-tier lane state for the metrics exporter: current ladder
        rung and active ensemble size."""
        out: Dict[str, Dict[str, float]] = {}
        for t in self.tiers:
            lane = self.lanes[t]
            sel = getattr(lane, "active_selector", None)
            n_members = (float(np.asarray(sel).sum())
                         if sel is not None else float("nan"))
            out[t] = {"rung": float(lane.ladder_pos),
                      "n_members": n_members}
        return out

    # -------------------------------------------------------- data path
    def tier_of(self, patient: int) -> str:
        return self.registry.tier_of(patient)

    def predict(self, windows, tier: Optional[str] = None) -> float:
        return self.predict_batch([windows], tier)[0]

    def predict_batch(self, batch, tier: Optional[str] = None
                      ) -> List[float]:
        """One flush through ONE tier's live service (the tier-keyed
        batcher guarantees a flush never mixes tiers)."""
        t = tier if tier is not None else self.registry.default
        return self.lanes[t].facade.predict_batch(batch)
