"""Live SLO telemetry for the serving hot path (the control plane's
sensor).

``SloTelemetry`` is the tap the data plane feeds — pass it to
``EnsembleServer(telemetry=...)`` (every ingest is an arrival, every
retired query a latency sample, every full-queue rejection a shed) or
drive it explicitly when replaying a DES trace.  Over a sliding window
it derives:

* p50/p99 latency and the SLO violation rate;
* an arrival-rate estimate (queries/s over the window);
* the ONLINE empirical arrival curve and the network-calculus T_q
  bound — the same alpha/beta machinery ``serving/latency.py`` uses
  offline (§3.4, Fig. 5), now fed by the observed trace so the
  controller can predict queueing risk before violations materialize.

Two interchangeable engines back the same API:

* the default is a **mergeable windowed sketch**
  (``obs.sketch.WindowedSketch``): a ring of sub-window buckets, each
  holding exact event counters plus a log-spaced latency histogram,
  so memory is a CONSTANT block regardless of trace length — the
  week-long-soak / multi-host prerequisite.  Counts, violation rate
  and arrival rate stay EXACT (violations are classified at record
  time); window expiry and ``since=`` cuts resolve at bucket
  granularity (error <= one bucket width); p50/p99 carry the
  histogram's relative-error bound (``obs.sketch.REL_ERR_BOUND``,
  ~5.8%); the T_q bound is computed exactly on the bucket-grouped
  trace, over-shooting the raw-trace bound by at most one bucket
  width.  Same-shape sketches MERGE by aligned sum —
  ``SloTelemetry.merge`` — which is how ``TieredTelemetry`` now
  derives its fleet view and how multi-host telemetry will compose.

* ``exact=True`` keeps raw timestamps (head-compacted sorted lists) —
  the O(window-events) oracle the equivalence suite compares against,
  with ``since=`` cuts resolved by bisect instead of the old O(n)
  filtering under the lock.

All mutations and reads are lock-guarded; ``snapshot()`` is the
consistent view the controller consumes.  The clock is injectable so
the DES and unit tests can drive virtual time.

``TieredTelemetry`` adds the per-acuity-tier dimension: one slice per
tier, routed by the patient id every query already carries
(``tier_of``) or by an explicit ``tier=``.  The fleet view is a
DERIVED merge of the slices — there is no duplicate fleet feed to
drift out of sync with its parts.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import sketch as _sk
from repro.obs.sketch import WindowedSketch
from repro.serving.latency import arrival_curve, queueing_bound

DEFAULT_N_BUCKETS = 128
_MIN_BUCKETS, _MAX_BUCKETS = 64, 1024


def auto_n_buckets(window_seconds: float, slo_seconds: float) -> int:
    """Sub-window bucket count whose width stays <= slo/16: every
    sketch coarsening (window expiry, ``since`` cuts, T_q grouping) is
    bounded by ONE bucket width, so sizing buckets against the SLO
    keeps that error far inside the controller's decision margins
    (e.g. the 0.2*slo headroom of the predicted-latency trigger)
    regardless of how long the window is.  Clamped to [64, 1024]
    buckets — worst case ~1 MB of counters, still O(1) in trace
    length."""
    if slo_seconds <= 0 or window_seconds <= 0:
        return DEFAULT_N_BUCKETS
    want = 16.0 * window_seconds / slo_seconds
    n = _MIN_BUCKETS
    while n < want and n < _MAX_BUCKETS:
        n *= 2
    return n


@dataclasses.dataclass(frozen=True)
class TelemetrySnapshot:
    """One consistent reading of the sliding window."""
    t: float
    window_seconds: float
    n_arrivals: int
    n_served: int
    n_shed: int
    arrival_rate: float            # queries/s over the effective window
    p50: float
    p99: float
    violation_rate: float          # frac of served with latency > SLO
    ts: float = float("nan")       # active selector's T_s (if provided)
    tq_bound: float = float("nan")  # online network-calculus T_q bound
    # max/mean bucket load of the ACTIVE device placement (1.0 ==
    # balanced; nan when unsharded / no profile): the RE-PLACE signal
    placement_imbalance: float = float("nan")
    # NaN-scored retirements (poisoned / stale / stall-killed queries)
    # in the window: the chaos-drill health signal — served but carrying
    # no usable score
    n_failed: int = 0

    @property
    def predicted_latency(self) -> float:
        """T-hat = T_s + T_q from the ONLINE arrival curve (nan when no
        service profile was supplied to ``snapshot``)."""
        return self.ts + self.tq_bound


@dataclasses.dataclass
class _WindowSummary:
    n_arrivals: int
    n_served: int
    n_shed: int
    n_failed: int
    p50: float
    p99: float
    violation_rate: float
    tq_bound: float


class _EventLog:
    """Sorted timestamp log with a head offset: near-sorted feeds
    insert at (or close to) the tail, pruning advances the head by
    bisect, and the backing list is compacted only when the dead head
    outgrows the live half — O(log n) cuts, amortized O(1) prune."""

    __slots__ = ("ts", "vals", "h")

    def __init__(self, with_vals: bool = False):
        self.ts: List[float] = []
        self.vals: Optional[List[float]] = [] if with_vals else None
        self.h = 0

    def add(self, t: float, val: Optional[float] = None) -> None:
        if not self.ts or t >= self.ts[-1]:
            self.ts.append(t)
            if self.vals is not None:
                self.vals.append(val)
            return
        i = bisect.bisect_right(self.ts, t, lo=self.h)
        self.ts.insert(i, t)
        if self.vals is not None:
            self.vals.insert(i, val)

    def prune(self, cut: float) -> None:
        self.h = bisect.bisect_right(self.ts, cut, lo=self.h)
        if self.h > 32 and self.h * 2 > len(self.ts):
            del self.ts[:self.h]
            if self.vals is not None:
                del self.vals[:self.h]
            self.h = 0

    def cut_index(self, since: float) -> int:
        return bisect.bisect_right(self.ts, since, lo=self.h)

    def __len__(self) -> int:
        return len(self.ts) - self.h

    def times(self, since: Optional[float] = None) -> List[float]:
        lo = self.h if since is None else self.cut_index(since)
        return self.ts[lo:]

    def values(self, since: Optional[float] = None) -> List[float]:
        lo = self.h if since is None else self.cut_index(since)
        return self.vals[lo:]


class _ExactEngine:
    """Raw-timestamp oracle (the pre-sketch behaviour, with bisect
    ``since`` cuts).  Memory is O(window events)."""

    exact = True

    def __init__(self, window: float):
        self.window = window
        self.arrivals = _EventLog()
        self.served = _EventLog(with_vals=True)
        self.shed = _EventLog()
        self.failed = _EventLog()
        self.t0: Optional[float] = None
        self.hwm = -float("inf")

    def _in_window(self, t: float) -> bool:
        # an event already older than the window behind the high-water
        # mark is rejected at RECORD time: keeping it would dodge the
        # head prune and skew counts/rates for up to a full window
        return t > self.hwm - self.window

    def _note(self, t: float) -> None:
        if self.t0 is None:
            self.t0 = t

    def prune(self, now: float) -> None:
        # prune against the high-water mark, not the raw event time: a
        # slightly out-of-order feed (threaded taps, DES replay) must
        # never let the cut regress — memory stays O(window) behind
        # the NEWEST event
        self.hwm = now = max(self.hwm, now)
        cut = now - self.window
        for log in (self.arrivals, self.served, self.shed, self.failed):
            log.prune(cut)

    def record(self, kind: int, t: float,
               latency: Optional[float] = None,
               violated: bool = False) -> None:
        self._note(t)
        if self._in_window(t):
            if kind == _sk.SERVED:
                self.served.add(t, float(latency))
            elif kind == _sk.ARRIVALS:
                self.arrivals.add(t)
            elif kind == _sk.SHED:
                self.shed.add(t)
            else:
                self.failed.add(t)
        self.prune(t)

    # ------------------------------------------------------------- read
    def arrival_times(self, now: float,
                      since: Optional[float] = None) -> np.ndarray:
        self.prune(now)
        return np.asarray(self.arrivals.times(since), np.float64)

    def latency_values(self, now: float,
                       since: Optional[float] = None) -> np.ndarray:
        self.prune(now)
        return np.asarray(self.served.values(since), np.float64)

    def tq(self, mu: float, T0: float, now: float,
           since: Optional[float] = None) -> float:
        return queueing_bound(self.arrival_times(now, since), mu, T0)

    def summary(self, now: float, since: Optional[float],
                slo: float, mu: Optional[float]) -> _WindowSummary:
        self.prune(now)
        arr = np.asarray(self.arrivals.times(since), np.float64)
        lat = np.asarray(self.served.values(since), np.float64)
        n_shed = len(self.shed.times(since)) if since is not None \
            else len(self.shed)
        n_failed = len(self.failed.times(since)) if since is not None \
            else len(self.failed)
        p50 = float(np.percentile(lat, 50)) if len(lat) else 0.0
        p99 = float(np.percentile(lat, 99)) if len(lat) else 0.0
        viol = float(np.mean(lat > slo)) if len(lat) else 0.0
        tq = float("nan")
        if mu is not None:
            tq = queueing_bound(arr, mu, 0.0)
        return _WindowSummary(len(arr), len(lat), n_shed, n_failed,
                              p50, p99, viol, tq)

    def latency_histogram(self, now: float) -> Optional[np.ndarray]:
        return None

    def absorb(self, other: "_ExactEngine") -> None:
        hwm = max(self.hwm, other.hwm)
        for mine, theirs in ((self.arrivals, other.arrivals),
                             (self.shed, other.shed),
                             (self.failed, other.failed)):
            for t in theirs.times():
                mine.add(t)
        for t, v in zip(other.served.times(), other.served.values()):
            self.served.add(t, v)
        if other.t0 is not None:
            self.t0 = other.t0 if self.t0 is None \
                else min(self.t0, other.t0)
        self.prune(hwm)


class _SketchEngine:
    """Windowed-sketch sensor: O(1) memory, mergeable."""

    exact = False

    def __init__(self, window: float, n_buckets: int):
        self.sk = WindowedSketch(window, n_buckets=n_buckets)

    @property
    def t0(self) -> Optional[float]:
        return self.sk.t0

    @property
    def hwm(self) -> float:
        return self.sk.hwm

    def prune(self, now: float) -> None:
        pass            # expiry is resolved at read time by bucket cuts

    def record(self, kind: int, t: float,
               latency: Optional[float] = None,
               violated: bool = False) -> None:
        self.sk.add(kind, t, latency=latency, violated=violated)

    def arrival_times(self, now: float,
                      since: Optional[float] = None) -> np.ndarray:
        return self.sk.arrival_times(now, since)

    def latency_values(self, now: float,
                       since: Optional[float] = None) -> np.ndarray:
        return self.sk.latency_values(now, since)

    def tq(self, mu: float, T0: float, now: float,
           since: Optional[float] = None) -> float:
        return self.sk.queueing_bound(mu, T0, now, since)

    def summary(self, now: float, since: Optional[float],
                slo: float, mu: Optional[float]) -> _WindowSummary:
        tot = self.sk.totals(now, since)
        n_served = int(tot[_sk.SERVED])
        hist = self.sk.histogram(now, since)
        p50 = _sk.quantile_from_counts(hist, 50) if n_served else 0.0
        p99 = _sk.quantile_from_counts(hist, 99) if n_served else 0.0
        viol = float(tot[_sk.VIOLATIONS]) / n_served if n_served else 0.0
        tq = float("nan")
        if mu is not None:
            tq = self.sk.queueing_bound(mu, 0.0, now, since)
        return _WindowSummary(int(tot[_sk.ARRIVALS]), n_served,
                              int(tot[_sk.SHED]), int(tot[_sk.FAILED]),
                              p50, p99, viol, tq)

    def latency_histogram(self, now: float) -> Optional[np.ndarray]:
        return self.sk.histogram(now)

    def absorb(self, other: "_SketchEngine") -> None:
        self.sk.absorb(other.sk)


class SloTelemetry:
    def __init__(self, slo_seconds: float = 1.0,
                 window_seconds: float = 60.0,
                 clock: Callable[[], float] = time.monotonic,
                 exact: bool = False,
                 n_buckets: Optional[int] = None):
        self.slo = slo_seconds
        self.window = window_seconds
        self.clock = clock
        self.exact = bool(exact)
        self.n_buckets = int(n_buckets) if n_buckets is not None \
            else auto_n_buckets(window_seconds, slo_seconds)
        n_buckets = self.n_buckets
        self._lock = threading.Lock()
        self._eng = _ExactEngine(window_seconds) if exact \
            else _SketchEngine(window_seconds, n_buckets)

    # oracle-introspection views (exact engine only): the raw event
    # logs the pre-sketch tests poke at
    @property
    def _arrivals(self) -> List[float]:
        return self._require_exact().arrivals.times()

    @property
    def _served(self) -> List[Tuple[float, float]]:
        eng = self._require_exact()
        return list(zip(eng.served.times(), eng.served.values()))

    @property
    def _shed(self) -> List[float]:
        return self._require_exact().shed.times()

    def _require_exact(self) -> _ExactEngine:
        if not self.exact:
            raise AttributeError(
                "raw event logs exist only under exact=True (the "
                "sketch engine keeps bucket counters, not timestamps)")
        return self._eng

    @property
    def _t0(self) -> Optional[float]:
        return self._eng.t0

    @property
    def _hwm(self) -> float:
        return self._eng.hwm

    # ------------------------------------------------------------ feed
    def record_arrival(self, t: Optional[float] = None,
                       patient: Optional[int] = None) -> None:
        """``patient`` is accepted (and ignored) so the server tap can
        pass query ids uniformly; ``TieredTelemetry`` routes on it."""
        t = self.clock() if t is None else t
        with self._lock:
            self._eng.record(_sk.ARRIVALS, t)

    def record_served(self, latency: float,
                      t: Optional[float] = None,
                      patient: Optional[int] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            # violations are classified HERE, against the SLO, so the
            # sketch's violation rate is exact (never histogram-derived)
            self._eng.record(_sk.SERVED, t, latency=float(latency),
                             violated=float(latency) > self.slo)

    def record_shed(self, t: Optional[float] = None,
                    patient: Optional[int] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            self._eng.record(_sk.SHED, t)

    def record_failure(self, t: Optional[float] = None,
                       patient: Optional[int] = None) -> None:
        """A query retired with a NaN score (server NaN-isolation or a
        watchdog-killed co-batch): served for conservation purposes, but
        no usable score was delivered."""
        t = self.clock() if t is None else t
        with self._lock:
            self._eng.record(_sk.FAILED, t)

    # ------------------------------------------------------------ read
    def arrivals(self, now: Optional[float] = None) -> np.ndarray:
        """Arrival timestamps in the window (sketch mode: coarsened to
        bucket starts)."""
        now = self.clock() if now is None else now
        with self._lock:
            return self._eng.arrival_times(now)

    def latencies(self, now: Optional[float] = None) -> np.ndarray:
        """Served latencies in the window (sketch mode: reconstructed
        at histogram-bin representative values)."""
        now = self.clock() if now is None else now
        with self._lock:
            return self._eng.latency_values(now)

    def arrival_rate(self, now: Optional[float] = None) -> float:
        """Arrivals/s over the effective window (shorter than
        ``window_seconds`` until that much history exists)."""
        now = self.clock() if now is None else now
        with self._lock:
            n = len(self._eng.arrival_times(now))
            t0 = self._eng.t0
            span = self.window if t0 is None \
                else min(self.window, max(now - t0, 1e-9))
            return n / span

    def arrival_curve(self, dts: np.ndarray,
                      now: Optional[float] = None) -> np.ndarray:
        """The ONLINE empirical arrival curve alpha(dt) over the
        sliding window — the live counterpart of the profiler's
        synthetic trace."""
        return arrival_curve(self.arrivals(now), dts)

    def queueing_bound(self, mu: float, T0: float,
                       now: Optional[float] = None) -> float:
        """Online network-calculus T_q bound against the rate-latency
        service curve beta(t) = mu * (t - T0)+ of the ACTIVE ensemble."""
        now = self.clock() if now is None else now
        with self._lock:
            return self._eng.tq(mu, T0, now)

    def latency_histogram(self, now: Optional[float] = None
                          ) -> Optional[np.ndarray]:
        """Merged latency bin counts over the window (sketch mode
        only; None under ``exact=True``).  Bin edges are
        ``obs.sketch.EDGES`` — the Prometheus-exposition source."""
        now = self.clock() if now is None else now
        with self._lock:
            return self._eng.latency_histogram(now)

    def snapshot(self, mu: Optional[float] = None, ts: float = 0.0,
                 now: Optional[float] = None,
                 since: Optional[float] = None,
                 imbalance: Optional[float] = None) -> TelemetrySnapshot:
        """``since`` restricts the reading to events AFTER that time —
        the controller passes its last actuation time so decisions rest
        on post-action evidence only (a violation burst that triggered
        a shed must not re-trigger it for the rest of the window).
        Exact mode resolves the cut by bisect; sketch mode keeps whole
        buckets starting strictly after ``since``."""
        now = self.clock() if now is None else now
        with self._lock:
            s = self._eng.summary(now, since, self.slo, mu)
            t0 = self._eng.t0
        start = now if t0 is None else t0
        if since is not None:
            start = max(start, since)
        span = self.window if t0 is None \
            else min(self.window, max(now - start, 1e-9))
        return TelemetrySnapshot(
            t=now, window_seconds=self.window,
            n_arrivals=s.n_arrivals, n_served=s.n_served,
            n_shed=s.n_shed,
            arrival_rate=s.n_arrivals / span,
            p50=s.p50, p99=s.p99, violation_rate=s.violation_rate,
            ts=float(ts) if mu is not None else float("nan"),
            tq_bound=s.tq_bound,
            placement_imbalance=float(imbalance)
            if imbalance is not None else float("nan"),
            n_failed=s.n_failed)

    # ----------------------------------------------------------- merge
    @classmethod
    def merge(cls, parts: Sequence["SloTelemetry"],
              clock: Optional[Callable[[], float]] = None
              ) -> "SloTelemetry":
        """One telemetry whose window holds every part's events — the
        fleet view over tier slices today, the cross-host reduction
        tomorrow.  Parts must agree on (slo, window, engine); sketch
        parts merge in O(n_buckets), exact parts by re-sorting their
        (window-bounded) event logs."""
        parts = list(parts)
        if not parts:
            raise ValueError("nothing to merge")
        first = parts[0]
        for p in parts[1:]:
            if (p.slo != first.slo or p.window != first.window
                    or p.exact != first.exact
                    or p.n_buckets != first.n_buckets):
                raise ValueError(
                    "merge requires identical (slo_seconds, "
                    "window_seconds, exact, n_buckets) across parts")
        out = cls(first.slo, first.window,
                  clock=clock if clock is not None else first.clock,
                  exact=first.exact, n_buckets=first.n_buckets)
        for p in parts:
            with p._lock:
                out._eng.absorb(p._eng)
        return out


class TieredTelemetry:
    """Per-acuity-tier telemetry: one ``SloTelemetry`` slice per tier,
    fed through the same server-tap interface.

    Routing: an explicit ``tier=`` wins (DES replay stamps each query's
    tier at birth); otherwise ``tier_of(patient)`` maps the patient id
    the query carries; unknown/unmappable patients land in
    ``default_tier``.  A patient whose acuity escalates mid-stay starts
    feeding its NEW slice from that moment — its history stays where it
    was observed.

    ``snapshot`` is the fleet view (what overload/health decisions key
    on, since all tiers share the device pool): a DERIVED merge of the
    tier slices (``SloTelemetry.merge``), not a second feed — the
    slices are the single source of truth and the fleet can never
    drift from their sum.  ``tier_snapshot`` is one slice (per-tier
    p99/violations/arrival rate — the priority-aware controller's
    evidence for which tier absorbs a shed).
    """

    def __init__(self, tier_of: Callable[[int], str],
                 tiers: Sequence[str],
                 slo_seconds: float = 1.0,
                 window_seconds: float = 60.0,
                 default_tier: Optional[str] = None,
                 clock: Callable[[], float] = time.monotonic,
                 exact: bool = False,
                 n_buckets: Optional[int] = None):
        if not tiers:
            raise ValueError("tiers must be non-empty")
        self.tiers = tuple(tiers)
        self.tier_of = tier_of
        self.default_tier = default_tier if default_tier is not None \
            else self.tiers[0]
        if self.default_tier not in self.tiers:
            raise ValueError(f"default_tier {self.default_tier!r} not in "
                             f"{self.tiers}")
        self.slo = slo_seconds
        self.window = window_seconds
        self.clock = clock
        self.exact = bool(exact)
        self.n_buckets = int(n_buckets) if n_buckets is not None \
            else auto_n_buckets(window_seconds, slo_seconds)
        n_buckets = self.n_buckets
        self.slices: Dict[str, SloTelemetry] = {
            t: SloTelemetry(slo_seconds, window_seconds, clock,
                            exact=exact, n_buckets=n_buckets)
            for t in self.tiers}

    def _slice(self, patient: Optional[int],
               tier: Optional[str]) -> SloTelemetry:
        if tier is None and patient is not None:
            try:
                tier = self.tier_of(patient)
            except Exception:
                tier = None
        if tier not in self.slices:
            tier = self.default_tier
        return self.slices[tier]

    # ------------------------------------------------------- server tap
    def record_arrival(self, t: Optional[float] = None,
                       patient: Optional[int] = None,
                       tier: Optional[str] = None) -> None:
        t = self.clock() if t is None else t
        self._slice(patient, tier).record_arrival(t)

    def record_served(self, latency: float, t: Optional[float] = None,
                      patient: Optional[int] = None,
                      tier: Optional[str] = None) -> None:
        t = self.clock() if t is None else t
        self._slice(patient, tier).record_served(latency, t)

    def record_shed(self, t: Optional[float] = None,
                    patient: Optional[int] = None,
                    tier: Optional[str] = None) -> None:
        t = self.clock() if t is None else t
        self._slice(patient, tier).record_shed(t)

    def record_failure(self, t: Optional[float] = None,
                       patient: Optional[int] = None,
                       tier: Optional[str] = None) -> None:
        t = self.clock() if t is None else t
        self._slice(patient, tier).record_failure(t)

    # ------------------------------------------------------------ read
    def tier(self, name: str) -> SloTelemetry:
        return self.slices[name]

    @property
    def fleet(self) -> SloTelemetry:
        """The fleet-wide sensor, merged fresh from the tier slices."""
        return SloTelemetry.merge(list(self.slices.values()),
                                  clock=self.clock)

    def snapshot(self, **kwargs) -> TelemetrySnapshot:
        """Fleet-wide reading (same signature as
        ``SloTelemetry.snapshot``): merge the slices, then read."""
        return self.fleet.snapshot(**kwargs)

    def tier_snapshot(self, name: str, **kwargs) -> TelemetrySnapshot:
        return self.slices[name].snapshot(**kwargs)
