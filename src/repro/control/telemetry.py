"""Live SLO telemetry for the serving hot path (the control plane's
sensor).

``SloTelemetry`` is the tap the data plane feeds — pass it to
``EnsembleServer(telemetry=...)`` (every ingest is an arrival, every
retired query a latency sample, every full-queue rejection a shed) or
drive it explicitly when replaying a DES trace.  Over a sliding window
it derives:

* p50/p99 latency and the SLO violation rate;
* an arrival-rate estimate (queries/s over the window);
* the ONLINE empirical arrival curve and the network-calculus T_q
  bound — the same alpha/beta machinery ``serving/latency.py`` uses
  offline (§3.4, Fig. 5), now fed by the observed trace so the
  controller can predict queueing risk before violations materialize.

All mutations and reads are lock-guarded; ``snapshot()`` is the
consistent view the controller consumes.  The clock is injectable so
the DES and unit tests can drive virtual time.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Optional, Tuple

import numpy as np

from repro.serving.latency import arrival_curve, queueing_bound


@dataclasses.dataclass(frozen=True)
class TelemetrySnapshot:
    """One consistent reading of the sliding window."""
    t: float
    window_seconds: float
    n_arrivals: int
    n_served: int
    n_shed: int
    arrival_rate: float            # queries/s over the effective window
    p50: float
    p99: float
    violation_rate: float          # frac of served with latency > SLO
    ts: float = float("nan")       # active selector's T_s (if provided)
    tq_bound: float = float("nan")  # online network-calculus T_q bound
    # max/mean bucket load of the ACTIVE device placement (1.0 ==
    # balanced; nan when unsharded / no profile): the RE-PLACE signal
    placement_imbalance: float = float("nan")

    @property
    def predicted_latency(self) -> float:
        """T-hat = T_s + T_q from the ONLINE arrival curve (nan when no
        service profile was supplied to ``snapshot``)."""
        return self.ts + self.tq_bound


class SloTelemetry:
    def __init__(self, slo_seconds: float = 1.0,
                 window_seconds: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.slo = slo_seconds
        self.window = window_seconds
        self.clock = clock
        self._lock = threading.Lock()
        self._arrivals: Deque[float] = collections.deque()
        self._served: Deque[Tuple[float, float]] = collections.deque()
        self._shed: Deque[float] = collections.deque()
        self._t0: Optional[float] = None       # first event ever seen

    # ------------------------------------------------------------ feed
    def record_arrival(self, t: Optional[float] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            self._note_t0(t)
            self._arrivals.append(t)
            self._prune(t)        # amortized O(1): memory stays O(window)

    def record_served(self, latency: float,
                      t: Optional[float] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            self._note_t0(t)
            self._served.append((t, float(latency)))
            self._prune(t)

    def record_shed(self, t: Optional[float] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            self._note_t0(t)
            self._shed.append(t)
            self._prune(t)

    def _note_t0(self, t: float) -> None:
        if self._t0 is None:
            self._t0 = t

    def _prune(self, now: float) -> None:
        cut = now - self.window
        for dq in (self._arrivals, self._shed):
            while dq and dq[0] <= cut:
                dq.popleft()
        while self._served and self._served[0][0] <= cut:
            self._served.popleft()

    # ------------------------------------------------------------ read
    def arrivals(self, now: Optional[float] = None) -> np.ndarray:
        now = self.clock() if now is None else now
        with self._lock:
            self._prune(now)
            return np.asarray(self._arrivals, np.float64)

    def latencies(self, now: Optional[float] = None) -> np.ndarray:
        now = self.clock() if now is None else now
        with self._lock:
            self._prune(now)
            return np.asarray([l for _, l in self._served], np.float64)

    def arrival_rate(self, now: Optional[float] = None) -> float:
        """Arrivals/s over the effective window (shorter than
        ``window_seconds`` until that much history exists)."""
        now = self.clock() if now is None else now
        with self._lock:
            self._prune(now)
            n = len(self._arrivals)
            span = self.window if self._t0 is None \
                else min(self.window, max(now - self._t0, 1e-9))
            return n / span

    def arrival_curve(self, dts: np.ndarray,
                      now: Optional[float] = None) -> np.ndarray:
        """The ONLINE empirical arrival curve alpha(dt) over the
        sliding window — the live counterpart of the profiler's
        synthetic trace."""
        return arrival_curve(self.arrivals(now), dts)

    def queueing_bound(self, mu: float, T0: float,
                       now: Optional[float] = None) -> float:
        """Online network-calculus T_q bound against the rate-latency
        service curve beta(t) = mu * (t - T0)+ of the ACTIVE ensemble."""
        return queueing_bound(self.arrivals(now), mu, T0)

    def snapshot(self, mu: Optional[float] = None, ts: float = 0.0,
                 now: Optional[float] = None,
                 since: Optional[float] = None,
                 imbalance: Optional[float] = None) -> TelemetrySnapshot:
        """``since`` restricts the reading to events AFTER that time —
        the controller passes its last actuation time so decisions rest
        on post-action evidence only (a violation burst that triggered
        a shed must not re-trigger it for the rest of the window)."""
        now = self.clock() if now is None else now
        with self._lock:
            self._prune(now)
            if since is None:
                arr = np.asarray(self._arrivals, np.float64)
                lat = np.asarray([l for _, l in self._served],
                                 np.float64)
                n_shed = len(self._shed)
            else:
                arr = np.asarray([t for t in self._arrivals
                                  if t > since], np.float64)
                lat = np.asarray([l for t, l in self._served
                                  if t > since], np.float64)
                n_shed = sum(1 for t in self._shed if t > since)
            start = now if self._t0 is None else self._t0
            if since is not None:
                start = max(start, since)
            span = self.window if self._t0 is None \
                else min(self.window, max(now - start, 1e-9))
        p50 = float(np.percentile(lat, 50)) if len(lat) else 0.0
        p99 = float(np.percentile(lat, 99)) if len(lat) else 0.0
        viol = float(np.mean(lat > self.slo)) if len(lat) else 0.0
        tq = float("nan")
        if mu is not None:
            tq = queueing_bound(arr, mu, 0.0)
        return TelemetrySnapshot(
            t=now, window_seconds=self.window,
            n_arrivals=len(arr), n_served=len(lat), n_shed=n_shed,
            arrival_rate=len(arr) / span,
            p50=p50, p99=p99, violation_rate=viol,
            ts=float(ts) if mu is not None else float("nan"),
            tq_bound=tq,
            placement_imbalance=float(imbalance)
            if imbalance is not None else float("nan"))
