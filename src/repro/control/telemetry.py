"""Live SLO telemetry for the serving hot path (the control plane's
sensor).

``SloTelemetry`` is the tap the data plane feeds — pass it to
``EnsembleServer(telemetry=...)`` (every ingest is an arrival, every
retired query a latency sample, every full-queue rejection a shed) or
drive it explicitly when replaying a DES trace.  Over a sliding window
it derives:

* p50/p99 latency and the SLO violation rate;
* an arrival-rate estimate (queries/s over the window);
* the ONLINE empirical arrival curve and the network-calculus T_q
  bound — the same alpha/beta machinery ``serving/latency.py`` uses
  offline (§3.4, Fig. 5), now fed by the observed trace so the
  controller can predict queueing risk before violations materialize.

All mutations and reads are lock-guarded; ``snapshot()`` is the
consistent view the controller consumes.  The clock is injectable so
the DES and unit tests can drive virtual time.

Memory is O(window), never O(trace): every ``record_*`` prunes events
older than the sliding window against the HIGH-WATER-MARK timestamp
(monotone even when explicit, slightly out-of-order times are fed), so
a week-long deployment holds only the last ``window_seconds`` of raw
timestamps.  (The ROADMAP's next increment replaces even that with a
mergeable windowed-count sketch.)

``TieredTelemetry`` adds the per-acuity-tier dimension: one fleet-wide
``SloTelemetry`` plus one slice per tier, routed by the patient id every
query already carries (``tier_of``) or by an explicit ``tier=`` — the
sensor side of per-tier degradation ladders (``control.tiers``).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.serving.latency import arrival_curve, queueing_bound


@dataclasses.dataclass(frozen=True)
class TelemetrySnapshot:
    """One consistent reading of the sliding window."""
    t: float
    window_seconds: float
    n_arrivals: int
    n_served: int
    n_shed: int
    arrival_rate: float            # queries/s over the effective window
    p50: float
    p99: float
    violation_rate: float          # frac of served with latency > SLO
    ts: float = float("nan")       # active selector's T_s (if provided)
    tq_bound: float = float("nan")  # online network-calculus T_q bound
    # max/mean bucket load of the ACTIVE device placement (1.0 ==
    # balanced; nan when unsharded / no profile): the RE-PLACE signal
    placement_imbalance: float = float("nan")
    # NaN-scored retirements (poisoned / stale / stall-killed queries)
    # in the window: the chaos-drill health signal — served but carrying
    # no usable score
    n_failed: int = 0

    @property
    def predicted_latency(self) -> float:
        """T-hat = T_s + T_q from the ONLINE arrival curve (nan when no
        service profile was supplied to ``snapshot``)."""
        return self.ts + self.tq_bound


class SloTelemetry:
    def __init__(self, slo_seconds: float = 1.0,
                 window_seconds: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.slo = slo_seconds
        self.window = window_seconds
        self.clock = clock
        self._lock = threading.Lock()
        self._arrivals: Deque[float] = collections.deque()
        self._served: Deque[Tuple[float, float]] = collections.deque()
        self._shed: Deque[float] = collections.deque()
        self._failed: Deque[float] = collections.deque()
        self._t0: Optional[float] = None       # first event ever seen
        self._hwm = -float("inf")              # newest event time seen

    # ------------------------------------------------------------ feed
    def record_arrival(self, t: Optional[float] = None,
                       patient: Optional[int] = None) -> None:
        """``patient`` is accepted (and ignored) so the server tap can
        pass query ids uniformly; ``TieredTelemetry`` routes on it."""
        t = self.clock() if t is None else t
        with self._lock:
            self._note_t0(t)
            if self._in_window(t):
                self._arrivals.append(t)
            self._prune(t)        # amortized O(1): memory stays O(window)

    def record_served(self, latency: float,
                      t: Optional[float] = None,
                      patient: Optional[int] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            self._note_t0(t)
            if self._in_window(t):
                self._served.append((t, float(latency)))
            self._prune(t)

    def record_shed(self, t: Optional[float] = None,
                    patient: Optional[int] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            self._note_t0(t)
            if self._in_window(t):
                self._shed.append(t)
            self._prune(t)

    def record_failure(self, t: Optional[float] = None,
                       patient: Optional[int] = None) -> None:
        """A query retired with a NaN score (server NaN-isolation or a
        watchdog-killed co-batch): served for conservation purposes, but
        no usable score was delivered."""
        t = self.clock() if t is None else t
        with self._lock:
            self._note_t0(t)
            if self._in_window(t):
                self._failed.append(t)
            self._prune(t)

    def _note_t0(self, t: float) -> None:
        if self._t0 is None:
            self._t0 = t

    def _in_window(self, t: float) -> bool:
        # an event already older than the window behind the high-water
        # mark is rejected at RECORD time: appending it at the deque
        # tail would dodge the left-side prune (the deques are only
        # approximately sorted) and skew counts/rates for up to a full
        # window while occupying memory
        return t > self._hwm - self.window

    def _prune(self, now: float) -> None:
        # prune against the high-water mark, not the raw event time: a
        # slightly out-of-order feed (threaded taps, DES replay) must
        # never let the cut regress — the deques stay bounded by the
        # window behind the NEWEST event, i.e. memory is O(window)
        self._hwm = now = max(self._hwm, now)
        cut = now - self.window
        for dq in (self._arrivals, self._shed, self._failed):
            while dq and dq[0] <= cut:
                dq.popleft()
        while self._served and self._served[0][0] <= cut:
            self._served.popleft()

    # ------------------------------------------------------------ read
    def arrivals(self, now: Optional[float] = None) -> np.ndarray:
        now = self.clock() if now is None else now
        with self._lock:
            self._prune(now)
            return np.asarray(self._arrivals, np.float64)

    def latencies(self, now: Optional[float] = None) -> np.ndarray:
        now = self.clock() if now is None else now
        with self._lock:
            self._prune(now)
            return np.asarray([l for _, l in self._served], np.float64)

    def arrival_rate(self, now: Optional[float] = None) -> float:
        """Arrivals/s over the effective window (shorter than
        ``window_seconds`` until that much history exists)."""
        now = self.clock() if now is None else now
        with self._lock:
            self._prune(now)
            n = len(self._arrivals)
            span = self.window if self._t0 is None \
                else min(self.window, max(now - self._t0, 1e-9))
            return n / span

    def arrival_curve(self, dts: np.ndarray,
                      now: Optional[float] = None) -> np.ndarray:
        """The ONLINE empirical arrival curve alpha(dt) over the
        sliding window — the live counterpart of the profiler's
        synthetic trace."""
        return arrival_curve(self.arrivals(now), dts)

    def queueing_bound(self, mu: float, T0: float,
                       now: Optional[float] = None) -> float:
        """Online network-calculus T_q bound against the rate-latency
        service curve beta(t) = mu * (t - T0)+ of the ACTIVE ensemble."""
        return queueing_bound(self.arrivals(now), mu, T0)

    def snapshot(self, mu: Optional[float] = None, ts: float = 0.0,
                 now: Optional[float] = None,
                 since: Optional[float] = None,
                 imbalance: Optional[float] = None) -> TelemetrySnapshot:
        """``since`` restricts the reading to events AFTER that time —
        the controller passes its last actuation time so decisions rest
        on post-action evidence only (a violation burst that triggered
        a shed must not re-trigger it for the rest of the window)."""
        now = self.clock() if now is None else now
        with self._lock:
            self._prune(now)
            if since is None:
                arr = np.asarray(self._arrivals, np.float64)
                lat = np.asarray([l for _, l in self._served],
                                 np.float64)
                n_shed = len(self._shed)
                n_failed = len(self._failed)
            else:
                arr = np.asarray([t for t in self._arrivals
                                  if t > since], np.float64)
                lat = np.asarray([l for t, l in self._served
                                  if t > since], np.float64)
                n_shed = sum(1 for t in self._shed if t > since)
                n_failed = sum(1 for t in self._failed if t > since)
            start = now if self._t0 is None else self._t0
            if since is not None:
                start = max(start, since)
            span = self.window if self._t0 is None \
                else min(self.window, max(now - start, 1e-9))
        p50 = float(np.percentile(lat, 50)) if len(lat) else 0.0
        p99 = float(np.percentile(lat, 99)) if len(lat) else 0.0
        viol = float(np.mean(lat > self.slo)) if len(lat) else 0.0
        tq = float("nan")
        if mu is not None:
            tq = queueing_bound(arr, mu, 0.0)
        return TelemetrySnapshot(
            t=now, window_seconds=self.window,
            n_arrivals=len(arr), n_served=len(lat), n_shed=n_shed,
            arrival_rate=len(arr) / span,
            p50=p50, p99=p99, violation_rate=viol,
            ts=float(ts) if mu is not None else float("nan"),
            tq_bound=tq,
            placement_imbalance=float(imbalance)
            if imbalance is not None else float("nan"),
            n_failed=n_failed)


class TieredTelemetry:
    """Per-acuity-tier telemetry: a fleet-wide ``SloTelemetry`` plus one
    slice per tier, fed through the same server-tap interface.

    Routing: an explicit ``tier=`` wins (DES replay stamps each query's
    tier at birth); otherwise ``tier_of(patient)`` maps the patient id
    the query carries; unknown/unmappable patients land in
    ``default_tier``.  A patient whose acuity escalates mid-stay starts
    feeding its NEW slice from that moment — its history stays where it
    was observed.

    ``snapshot`` is the fleet view (what overload/health decisions key
    on, since all tiers share the device pool); ``tier_snapshot`` is one
    slice (per-tier p99/violations/arrival rate — the priority-aware
    controller's evidence for which tier absorbs a shed).
    """

    def __init__(self, tier_of: Callable[[int], str],
                 tiers: Sequence[str],
                 slo_seconds: float = 1.0,
                 window_seconds: float = 60.0,
                 default_tier: Optional[str] = None,
                 clock: Callable[[], float] = time.monotonic):
        if not tiers:
            raise ValueError("tiers must be non-empty")
        self.tiers = tuple(tiers)
        self.tier_of = tier_of
        self.default_tier = default_tier if default_tier is not None \
            else self.tiers[0]
        if self.default_tier not in self.tiers:
            raise ValueError(f"default_tier {self.default_tier!r} not in "
                             f"{self.tiers}")
        self.slo = slo_seconds
        self.window = window_seconds
        self.clock = clock
        self.fleet = SloTelemetry(slo_seconds, window_seconds, clock)
        self.slices: Dict[str, SloTelemetry] = {
            t: SloTelemetry(slo_seconds, window_seconds, clock)
            for t in self.tiers}

    def _slice(self, patient: Optional[int],
               tier: Optional[str]) -> SloTelemetry:
        if tier is None and patient is not None:
            try:
                tier = self.tier_of(patient)
            except Exception:
                tier = None
        if tier not in self.slices:
            tier = self.default_tier
        return self.slices[tier]

    # ------------------------------------------------------- server tap
    def record_arrival(self, t: Optional[float] = None,
                       patient: Optional[int] = None,
                       tier: Optional[str] = None) -> None:
        t = self.clock() if t is None else t
        self.fleet.record_arrival(t)
        self._slice(patient, tier).record_arrival(t)

    def record_served(self, latency: float, t: Optional[float] = None,
                      patient: Optional[int] = None,
                      tier: Optional[str] = None) -> None:
        t = self.clock() if t is None else t
        self.fleet.record_served(latency, t)
        self._slice(patient, tier).record_served(latency, t)

    def record_shed(self, t: Optional[float] = None,
                    patient: Optional[int] = None,
                    tier: Optional[str] = None) -> None:
        t = self.clock() if t is None else t
        self.fleet.record_shed(t)
        self._slice(patient, tier).record_shed(t)

    def record_failure(self, t: Optional[float] = None,
                       patient: Optional[int] = None,
                       tier: Optional[str] = None) -> None:
        t = self.clock() if t is None else t
        self.fleet.record_failure(t)
        self._slice(patient, tier).record_failure(t)

    # ------------------------------------------------------------ read
    def tier(self, name: str) -> SloTelemetry:
        return self.slices[name]

    def snapshot(self, **kwargs) -> TelemetrySnapshot:
        """Fleet-wide reading (same signature as
        ``SloTelemetry.snapshot``)."""
        return self.fleet.snapshot(**kwargs)

    def tier_snapshot(self, name: str, **kwargs) -> TelemetrySnapshot:
        return self.slices[name].snapshot(**kwargs)
