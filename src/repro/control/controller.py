"""The adaptive controller: telemetry -> decision -> actuation.

One control iteration (``step``) reads a consistent telemetry snapshot
and picks one of five actions:

* ``SHED``      — SLO is being violated NOW (violation rate above the
                  high-water mark, or observed p99 over the SLO): step
                  down the degradation ladder immediately (a pre-staged
                  pointer flip), and kick off a background recompose to
                  find the best ensemble for the new load;
* ``REPLACE``   — the live device placement is lopsided (bucket-load
                  imbalance over ``imbalance_high``) while the ensemble
                  itself is fine: re-derive the LPT plan from freshly
                  measured bucket costs and hot-swap the SAME selector
                  onto the new shards (``HotSwapper.re_place``) — far
                  cheaper than a recompose, so it is tried first;
* ``RECOMPOSE`` — predicted SLO risk (online network-calculus
                  T_s + T_q crossing the SLO) or arrival-rate drift
                  beyond the trigger factor: re-run the composer
                  warm-started from the incumbent, then hot-swap (a
                  recompose also re-derives the placement — selector
                  AND placement are the actuated state);
* ``CLIMB``     — healthy with headroom (violations under the
                  low-water mark and p99 under ``headroom_frac`` of the
                  SLO): step back up the ladder;
* ``HOLD``      — otherwise, or within the post-action cooldown.

Recomposition runs in a daemon thread (``sync=False``) so the serving
hot path never blocks on the search; the DES bench and unit tests use
``sync=True`` for determinism.  ``recompose_fn(snapshot)`` is injected:
it returns the new selector (or None to keep the incumbent) and may
also rebuild the ladder around it.

``TieredController`` lifts the same signals to per-acuity-tier
actuation: the fleet shares one device pool, so overload/health is read
fleet-wide, but the ACTION lands on one tier's ladder — stable beds
shed first and climb last, and the critical tier holds the rich
ensemble until the predicted capacity bound says even flooring every
lower tier cannot restore feasibility.  A cross-tier device budget
(``rho_max``) keeps one tier's climb from eating another's headroom.
"""
from __future__ import annotations

import dataclasses
import enum
import logging
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.control.swap import SelectorLadder, rungs_monotone
from repro.control.telemetry import SloTelemetry, TelemetrySnapshot
from repro.serving.placement import placement_signature

log = logging.getLogger(__name__)


class Decision(enum.Enum):
    HOLD = "hold"
    SHED = "shed"
    CLIMB = "climb"
    RECOMPOSE = "recompose"
    REPLACE = "replace"


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    slo_seconds: float = 1.0
    violation_high: float = 0.10   # violation rate that forces a shed
    violation_low: float = 0.01    # below this (plus headroom) => climb
    headroom_frac: float = 0.5     # p99 <= frac * SLO counts as headroom
    drift_factor: float = 1.5      # arrival-rate drift trigger (x or /x)
    # the online T_q bound is worst-case-burst conservative; require the
    # predicted T_s + T_q to exceed this multiple of the SLO before
    # treating it as risk, so a persistently tight bound cannot thrash
    # the composer while observed latency is healthy
    predicted_factor: float = 1.2
    # device-load imbalance (max/mean bucket load of the live placement)
    # above this triggers a RE-PLACE: the makespan is reducible without
    # touching the ensemble, so it pre-empts the costlier recompose
    imbalance_high: float = 1.25
    cooldown_seconds: float = 10.0
    min_samples: int = 20          # served samples needed to act


class AdaptiveController:
    def __init__(self, telemetry: SloTelemetry, swapper: SelectorLadder,
                 recompose_fn: Optional[
                     Callable[[TelemetrySnapshot],
                              Optional[np.ndarray]]] = None,
                 config: Optional[ControllerConfig] = None,
                 service_profile_fn: Optional[
                     Callable[[], Tuple[float, float]]] = None,
                 sync: bool = False,
                 clock: Callable[[], float] = time.monotonic,
                 aux_ladder=None):
        """``service_profile_fn`` returns (mu, T_s) — optionally
        (mu, T_s, placement_imbalance) — of the ACTIVE ensemble so
        snapshots carry the online T_q bound and the live device-load
        balance.

        ``aux_ladder`` is an optional SECOND, cheaper degradation
        ladder (e.g. ``serving.slots.TickLadder`` — tick rate): SHED
        walks it down before touching the member ladder, CLIMB
        restores members first and the aux ladder last (LIFO undo), so
        freshness degrades before accuracy and recovers after it."""
        self.telemetry = telemetry
        self.swapper = swapper
        self.aux_ladder = aux_ladder
        self.recompose_fn = recompose_fn
        # placement is actuatable only when the swapper exposes the
        # RE-PLACE actuator (HotSwapper does; plain ladders do not)
        self._can_replace = callable(getattr(swapper, "re_place", None))
        # signature of a plan a RE-PLACE failed to improve: while the
        # active placement still matches it, the imbalance is inherent
        # (LPT cannot do better), so REPLACE must stand aside instead
        # of re-measuring every step and starving recompose/climb.
        # The brand also records the imbalance it was issued at: live
        # finish-time imbalance GROWING past that level re-arms REPLACE
        # (drifting shard costs can make a once-unimprovable plan
        # improvable)
        self._replace_noop_sig: Optional[bytes] = None
        self._replace_noop_imb: Optional[float] = None
        if config is None:
            config = ControllerConfig(slo_seconds=telemetry.slo)
        elif abs(config.slo_seconds - telemetry.slo) > 1e-12:
            # violation_rate is computed by telemetry against ITS slo;
            # decide() compares p99 against the config's — they must be
            # the same threshold or the two signals contradict
            raise ValueError(
                f"config.slo_seconds={config.slo_seconds} != "
                f"telemetry.slo={telemetry.slo}")
        self.config = config
        self.service_profile_fn = service_profile_fn
        self.sync = sync
        self.clock = clock
        self.log: List[Tuple[float, Decision]] = []
        self.baseline_rate: Optional[float] = None  # rate at last compose
        self.n_recomposes = 0
        self._last_action_t = -float("inf")
        self._recomposing = threading.Event()
        self._recompose_thread: Optional[threading.Thread] = None
        self._replacing = threading.Event()
        self._replace_thread: Optional[threading.Thread] = None
        self._monitor: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.leaked: List[str] = []    # stragglers from the last stop()

    def _active_placement_sig(self) -> Optional[bytes]:
        return placement_signature(
            getattr(self.swapper, "active_placement", None))

    def _replace_branded(self, imbalance: float) -> bool:
        """True while REPLACE must stand aside: the active plan still
        matches the last no-op brand AND the measured imbalance has not
        grown past the level the brand was issued at.  'LPT could not
        do better' is a statement about the costs seen at brand time,
        not a permanent property of the plan — under drift-fed
        re-planning, growth means new evidence."""
        if self._active_placement_sig() != self._replace_noop_sig:
            return False
        return (self._replace_noop_imb is None
                or imbalance <= self._replace_noop_imb + 1e-9)

    # ---------------------------------------------------------- policy
    def decide(self, snap: TelemetrySnapshot) -> Decision:
        """Pure policy (no side effects) — unit-testable in isolation."""
        c = self.config
        if snap.n_served < c.min_samples:
            return Decision.HOLD
        if (snap.violation_rate >= c.violation_high
                or snap.p99 > c.slo_seconds or snap.n_shed > 0):
            return Decision.SHED if self._can_shed_any() \
                else Decision.RECOMPOSE
        if self._can_replace \
                and np.isfinite(snap.placement_imbalance) \
                and snap.placement_imbalance > c.imbalance_high \
                and not self._replace_branded(snap.placement_imbalance):
            return Decision.REPLACE        # rebalance before re-search
        if np.isfinite(snap.predicted_latency) \
                and snap.predicted_latency > c.predicted_factor \
                * c.slo_seconds:
            return Decision.RECOMPOSE          # predicted risk, act early
        if self.baseline_rate and snap.arrival_rate > 0:
            ratio = snap.arrival_rate / self.baseline_rate
            if ratio >= c.drift_factor or ratio <= 1.0 / c.drift_factor:
                return Decision.RECOMPOSE      # load drifted: re-search
        if (snap.violation_rate <= c.violation_low
                and snap.p99 <= c.headroom_frac * c.slo_seconds
                and self._can_climb_any()):
            return Decision.CLIMB
        return Decision.HOLD

    def _can_shed_any(self) -> bool:
        aux = self.aux_ladder
        return self.swapper.can_shed() \
            or (aux is not None and aux.can_shed())

    def _can_climb_any(self) -> bool:
        aux = self.aux_ladder
        return self.swapper.can_climb() \
            or (aux is not None and aux.can_climb())

    def _shed_once(self) -> bool:
        """Aux ladder (freshness) sheds before the member ladder
        (accuracy)."""
        aux = self.aux_ladder
        if aux is not None and aux.can_shed() and aux.shed():
            return True
        return self.swapper.shed()

    def _climb_once(self) -> bool:
        """Members climb back before the aux ladder — LIFO undo of
        ``_shed_once``."""
        if self.swapper.can_climb() and self.swapper.climb():
            return True
        aux = self.aux_ladder
        return aux is not None and aux.climb()

    # ------------------------------------------------------------- act
    def snapshot(self, now: Optional[float] = None) -> TelemetrySnapshot:
        mu = ts = imbalance = None
        if self.service_profile_fn is not None:
            profile = self.service_profile_fn()
            mu, ts = profile[0], profile[1]
            if len(profile) > 2:           # (mu, Ts, imbalance) profile
                imbalance = profile[2]
        # evidence must postdate the last actuation: the violation burst
        # that justified a shed stays in the sliding window for up to
        # window_seconds and must not re-trigger a shed per cooldown,
        # cascading the ladder to the floor
        since = self._last_action_t \
            if np.isfinite(self._last_action_t) else None
        return self.telemetry.snapshot(mu=mu, ts=ts or 0.0, now=now,
                                       since=since, imbalance=imbalance)

    def step(self, now: Optional[float] = None) -> Decision:
        """One control iteration: snapshot, decide, act."""
        now = self.clock() if now is None else now
        if now - self._last_action_t < self.config.cooldown_seconds:
            return Decision.HOLD
        snap = self.snapshot(now)
        if self.baseline_rate is None and snap.arrival_rate > 0:
            self.baseline_rate = snap.arrival_rate
        decision = self.decide(snap)
        acted = False
        if decision is Decision.SHED:
            acted = self._shed_once()
            # find the right ensemble for the new load in the background
            acted = self._launch_recompose(snap) or acted
        elif decision is Decision.CLIMB:
            acted = self._climb_once()
        elif decision is Decision.RECOMPOSE:
            acted = self._launch_recompose(snap)
        elif decision is Decision.REPLACE:
            acted = self._launch_replace(snap)
        if not acted:
            # nothing actually changed (rung race, recompose already in
            # flight): don't log a phantom action or start a cooldown
            # that would delay the real corrective step
            return Decision.HOLD
        self._last_action_t = now
        self.log.append((now, decision))
        return decision

    def decision_counts(self) -> Dict[str, int]:
        """Actions taken so far, keyed by decision name (the exporter's
        ``controller_decisions_total`` source)."""
        out: Dict[str, int] = {}
        for _t, d in list(self.log):
            out[d.value] = out.get(d.value, 0) + 1
        return out

    def _launch_replace(self, snap: TelemetrySnapshot) -> bool:
        """RE-PLACE: live drift costs (or fresh measurement) -> fresh
        LPT plan -> hot-swap the same selector onto the new shards.
        Like recompose, the expensive measure+stage runs in a daemon
        thread (``sync=False``) so the monitor loop stays free to SHED
        mid-rebalance; ``sync=True`` actuates inline and returns
        whether the plan actually changed (a no-op must not start a
        cooldown).

        A plan re_place could not improve is remembered by signature —
        plus the imbalance it was tried at — so REPLACE is not
        re-tried (re-measuring every step would starve recompose/
        climb) until the placement changes some other way or the
        measured imbalance grows past the branded level; a signature
        that moved underneath (re_place lost a race to a selector
        swap) means the never-tried new placement must not inherit the
        no-op brand."""
        if self._replacing.is_set():
            return False
        self._replacing.set()
        sig_before = self._active_placement_sig()
        imb_at_decision = snap.placement_imbalance

        def run() -> bool:
            try:
                acted = self.swapper.re_place()
                noop = (not acted
                        and self._active_placement_sig() == sig_before)
                self._replace_noop_sig = sig_before if noop else None
                self._replace_noop_imb = \
                    imb_at_decision if noop else None
                return acted
            finally:
                self._replacing.clear()

        if self.sync:
            return run()
        self._replace_thread = threading.Thread(
            target=run, daemon=True, name="repro-ctl-replace")
        self._replace_thread.start()
        return True

    def _launch_recompose(self, snap: TelemetrySnapshot) -> bool:
        """Returns True iff a recompose was actually started."""
        if self.recompose_fn is None or self._recomposing.is_set():
            return False
        self._recomposing.set()
        if self.sync:
            try:
                self._recompose(snap)
            finally:
                self._recomposing.clear()
            return True

        def run():
            try:
                self._recompose(snap)
            finally:
                self._recomposing.clear()
        self._recompose_thread = threading.Thread(
            target=run, daemon=True, name="repro-ctl-recompose")
        self._recompose_thread.start()
        return True

    def _recompose(self, snap: TelemetrySnapshot) -> None:
        selector = self.recompose_fn(snap)
        self.n_recomposes += 1
        self.baseline_rate = snap.arrival_rate or self.baseline_rate
        sharded = self._can_replace and getattr(self.swapper,
                                                "sharded", False)
        if selector is not None and not np.array_equal(
                np.asarray(selector, np.int8),
                self.swapper.active_selector):
            if sharded:
                # a recompose re-derives the LPT plan too: freshen the
                # new selector's placement so the swap lands on a plan
                # built from current measured costs, not a stale cache
                self.swapper.placement_for(
                    np.asarray(selector, np.int8), fresh=True)
            self.swapper.swap_to(selector)
        elif sharded:
            # incumbent kept: load still changed enough to recompose,
            # so rebalance the shards under the same selector
            self.swapper.re_place()

    def join_recompose(self, timeout: float = 60.0) -> bool:
        """Wait for the background recompose to finish.  Returns True
        iff no recompose thread is (still) running — a timed-out join is
        reported, never silently swallowed."""
        t = self._recompose_thread
        if t is None:
            return True
        t.join(timeout)
        if t.is_alive():
            log.warning("join_recompose: %s still running after %.1fs",
                        t.name, timeout)
            return False
        return True

    # --------------------------------------------------- monitor loop
    def start(self, period_seconds: float = 1.0) -> "AdaptiveController":
        """Run ``step()`` on a background monitor thread every
        ``period_seconds`` — the live control loop.  Works against any
        telemetry feed; wired to a real ``EnsembleServer`` via
        ``control.faults.wire_controller`` (the server taps telemetry,
        this loop actuates shed/climb/recompose/re-place on it)."""
        def loop():
            while not self._stop.wait(period_seconds):
                self.step()
        self._monitor = threading.Thread(target=loop, daemon=True,
                                         name="repro-ctl-monitor")
        self._monitor.start()
        return self

    def stop(self, timeout: float = 5.0) -> bool:
        """Stop the monitor loop and wait for every background thread
        (monitor, recompose, replace).  Returns True iff they all
        actually exited; stragglers are listed by name in
        ``self.leaked`` and logged — a chaos harness treats a non-empty
        list as a leaked-thread failure."""
        self._stop.set()
        leaked: List[str] = []
        if self._monitor is not None:
            self._monitor.join(timeout=timeout)
            if self._monitor.is_alive():
                leaked.append(self._monitor.name)
        if not self.join_recompose(timeout=timeout):
            leaked.append("repro-ctl-recompose")
        t = self._replace_thread
        if t is not None:
            t.join(timeout=timeout)
            if t.is_alive():
                leaked.append(t.name)
        self.leaked = leaked
        if leaked:
            log.warning("controller stop(): threads still alive: %s",
                        leaked)
        return not leaked


@dataclasses.dataclass(frozen=True)
class TieredControllerConfig(ControllerConfig):
    # cross-tier device budget: predicted utilization
    # rho = sum_t lambda_t * c_t / n_devices above this is treated as
    # overload (shed pre-emptively) and no climb may push rho past it —
    # the guard that keeps a stable-tier climb from eating the critical
    # tier's headroom
    rho_max: float = 0.85
    # shed at most this many rungs per control step when the PREDICTED
    # budget says one rung is not enough (e.g. floor the stable tier in
    # one actuation during a census spike); observed-only overload
    # (no cost model) always sheds a single rung
    max_sheds_per_step: int = 4


class TieredController:
    """Priority-aware per-tier shed/climb over a fixed ladder family.

    One control step reads the FLEET snapshot (all tiers share the
    device pool, so violations and queueing are fleet phenomena) plus
    per-tier arrival rates, and actuates ONE tier's ladder:

    * overload (observed violations / p99 / sheds, or predicted budget
      ``rho > rho_max``) — shed the LOWEST-priority tier that still
      can; the top (critical) tier sheds only when every lower tier is
      at its floor AND the predicted bound leaves no alternative
      (even lower tiers floored, ``rho >= 1``: the backlog would grow
      without bound, so T_s + T_q crosses any SLO) — or queries are
      already being dropped (``n_shed > 0``);
    * health (violations low, p99 under headroom) — climb the
      HIGHEST-priority tier first, gated by the budget (post-climb
      ``rho <= rho_max``) and by shed-order monotonicity (a tier never
      climbs past the rung of a higher-acuity tier);
    * otherwise hold.

    ``lanes`` maps tier -> ``SelectorLadder`` (a ``TieredEnsemble``'s
    lanes, or no-op DES ladders in the bench); ``tier_order`` is
    shed-first -> shed-last.  ``cost_fn(selector)`` returns device-
    seconds per query for a selector — with it the controller predicts
    rho; without it only observed signals act.  The shed-order invariant
    (monotone rungs along ``tier_order``) is re-established on every
    step and preserved by every action this controller takes.
    """

    def __init__(self, telemetry, lanes,
                 tier_order: Optional[Sequence[str]] = None,
                 config: Optional[TieredControllerConfig] = None,
                 cost_fn: Optional[
                     Callable[[np.ndarray], float]] = None,
                 n_devices: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        self.telemetry = telemetry
        self.lanes = dict(lanes)
        order = tuple(tier_order) if tier_order is not None \
            else tuple(getattr(telemetry, "tiers", ()))
        if not order:
            order = tuple(self.lanes)
        if set(order) != set(self.lanes):
            raise ValueError(f"tier_order {order} != lanes "
                             f"{tuple(self.lanes)}")
        self.order = order
        tel_slo = getattr(telemetry, "slo", None)
        if config is None:
            config = TieredControllerConfig(
                slo_seconds=tel_slo if tel_slo is not None else 1.0)
        elif tel_slo is not None \
                and abs(config.slo_seconds - tel_slo) > 1e-12:
            # violation_rate is computed by telemetry against ITS slo;
            # decide() compares p99 against the config's — mixed
            # thresholds would make the two overload signals contradict
            raise ValueError(
                f"config.slo_seconds={config.slo_seconds} != "
                f"telemetry.slo={tel_slo}")
        self.config = config
        self.cost_fn = cost_fn
        self.n_devices = max(1, n_devices)
        self.clock = clock
        self.log: List[Tuple[float, str, Decision]] = []
        self._last_action_t = -float("inf")

    # ---------------------------------------------------------- reading
    def rungs(self) -> dict:
        return {t: self.lanes[t].ladder_pos for t in self.order}

    def monotone(self) -> bool:
        return rungs_monotone(self.lanes, self.order)

    def _rho(self, rates, floor_below: Optional[str] = None) -> float:
        """Predicted device utilization.  ``floor_below=t`` prices every
        tier BELOW t at its ladder-floor cost — the 'no alternative'
        probe: would flooring all lower tiers restore feasibility?"""
        if self.cost_fn is None:
            return float("nan")
        work = 0.0
        below = set()
        if floor_below is not None:
            below = set(self.order[:self.order.index(floor_below)])
        for t in self.order:
            lane = self.lanes[t]
            if t in below and lane.ladder:
                sel = lane.ladder[0]
            else:
                sel = lane.active_selector
            work += rates.get(t, 0.0) * float(self.cost_fn(sel))
        return work / self.n_devices

    def _climb_ok(self, tier: str, rates) -> bool:
        """Budget + monotonicity gate for climbing ``tier`` one rung."""
        lane = self.lanes[tier]
        if not lane.can_climb():
            return False
        new_pos = lane.ladder_pos + 1
        for higher in self.order[self.order.index(tier) + 1:]:
            if new_pos > self.lanes[higher].ladder_pos:
                return False          # would out-rank a higher tier
        if self.cost_fn is not None:
            rungs = lane.ladder
            delta = rates.get(tier, 0.0) * (
                float(self.cost_fn(rungs[new_pos]))
                - float(self.cost_fn(lane.active_selector)))
            if self._rho(rates) + delta / self.n_devices \
                    > self.config.rho_max:
                return False          # climb would eat shared headroom
        return True

    # ----------------------------------------------------------- policy
    def decide(self, fleet: TelemetrySnapshot,
               rates: dict) -> Tuple[Decision, Optional[str]]:
        """Pure policy: (decision, tier) for the current evidence."""
        c = self.config
        if fleet.n_served < c.min_samples:
            return Decision.HOLD, None
        rho = self._rho(rates)
        observed = (fleet.violation_rate >= c.violation_high
                    or fleet.p99 > c.slo_seconds or fleet.n_shed > 0)
        predicted = np.isfinite(rho) and rho > c.rho_max
        if observed or predicted:
            for t in self.order[:-1]:          # stable beds shed first
                if self.lanes[t].can_shed():
                    return Decision.SHED, t
            top = self.order[-1]
            if self.lanes[top].can_shed() and self._no_alternative(
                    fleet, rates):
                return Decision.SHED, top
            return Decision.HOLD, None
        if (fleet.violation_rate <= c.violation_low
                and fleet.p99 <= c.headroom_frac * c.slo_seconds):
            for t in reversed(self.order):     # critical climbs first
                if self._climb_ok(t, rates):
                    return Decision.CLIMB, t
        return Decision.HOLD, None

    def _no_alternative(self, fleet: TelemetrySnapshot,
                        rates: dict) -> bool:
        """May the top tier shed?  Only when the predicted bound says
        flooring every lower tier still cannot restore feasibility
        (rho >= 1 with lower tiers priced at their floors — queueing
        would diverge, so predicted T_s + T_q crosses any SLO), or
        queries are already being dropped."""
        if fleet.n_shed > 0:
            return True
        rho_floor = self._rho(rates, floor_below=self.order[-1])
        if np.isfinite(rho_floor):
            return rho_floor >= 1.0
        # no cost model: observed overload with every lower tier at its
        # floor (decide() only reaches here in that state) must still
        # be actionable
        return (fleet.violation_rate >= self.config.violation_high
                or fleet.p99 > self.config.slo_seconds)

    # -------------------------------------------------------------- act
    def step(self, now: Optional[float] = None
             ) -> List[Tuple[Decision, str]]:
        """One control iteration; returns the (decision, tier) actions
        taken (empty == hold).  When the predicted budget is the
        overload signal, shedding repeats (priority order, up to
        ``max_sheds_per_step``) until rho fits — one census spike can
        floor the stable tier in a single actuation instead of bleeding
        a rung per step."""
        now = self.clock() if now is None else now
        if now - self._last_action_t < self.config.cooldown_seconds:
            return []
        since = self._last_action_t \
            if np.isfinite(self._last_action_t) else None
        fleet = self.telemetry.snapshot(now=now, since=since)
        rates = {t: self.telemetry.tier_snapshot(
            t, now=now, since=since).arrival_rate for t in self.order}
        actions: List[Tuple[Decision, str]] = []
        for _ in range(max(1, self.config.max_sheds_per_step)):
            decision, tier = self.decide(fleet, rates)
            if decision is Decision.HOLD or tier is None:
                break
            acted = self.lanes[tier].shed() \
                if decision is Decision.SHED else self.lanes[tier].climb()
            if not acted:
                break
            self.log.append((now, tier, decision))
            actions.append((decision, tier))
            if decision is Decision.CLIMB:
                break                  # climbs are always one at a time
            rho = self._rho(rates)
            if not np.isfinite(rho):
                break                  # observed-only: single shed
            if rho <= self.config.rho_max:
                break                  # budget restored: stop shedding
        if actions:
            self._last_action_t = now
        return actions

    def decision_counts(self) -> Dict[str, int]:
        """Actions taken so far, keyed by ``tier/decision`` (the
        exporter's ``controller_decisions_total`` source)."""
        out: Dict[str, int] = {}
        for _t, tier, d in list(self.log):
            key = f"{tier}/{d.value}"
            out[key] = out.get(key, 0) + 1
        return out
