"""The adaptive controller: telemetry -> decision -> actuation.

One control iteration (``step``) reads a consistent telemetry snapshot
and picks one of four actions:

* ``SHED``      — SLO is being violated NOW (violation rate above the
                  high-water mark, or observed p99 over the SLO): step
                  down the degradation ladder immediately (a pre-staged
                  pointer flip), and kick off a background recompose to
                  find the best ensemble for the new load;
* ``RECOMPOSE`` — predicted SLO risk (online network-calculus
                  T_s + T_q crossing the SLO) or arrival-rate drift
                  beyond the trigger factor: re-run the composer
                  warm-started from the incumbent, then hot-swap;
* ``CLIMB``     — healthy with headroom (violations under the
                  low-water mark and p99 under ``headroom_frac`` of the
                  SLO): step back up the ladder;
* ``HOLD``      — otherwise, or within the post-action cooldown.

Recomposition runs in a daemon thread (``sync=False``) so the serving
hot path never blocks on the search; the DES bench and unit tests use
``sync=True`` for determinism.  ``recompose_fn(snapshot)`` is injected:
it returns the new selector (or None to keep the incumbent) and may
also rebuild the ladder around it.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.control.swap import SelectorLadder
from repro.control.telemetry import SloTelemetry, TelemetrySnapshot


class Decision(enum.Enum):
    HOLD = "hold"
    SHED = "shed"
    CLIMB = "climb"
    RECOMPOSE = "recompose"


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    slo_seconds: float = 1.0
    violation_high: float = 0.10   # violation rate that forces a shed
    violation_low: float = 0.01    # below this (plus headroom) => climb
    headroom_frac: float = 0.5     # p99 <= frac * SLO counts as headroom
    drift_factor: float = 1.5      # arrival-rate drift trigger (x or /x)
    # the online T_q bound is worst-case-burst conservative; require the
    # predicted T_s + T_q to exceed this multiple of the SLO before
    # treating it as risk, so a persistently tight bound cannot thrash
    # the composer while observed latency is healthy
    predicted_factor: float = 1.2
    cooldown_seconds: float = 10.0
    min_samples: int = 20          # served samples needed to act


class AdaptiveController:
    def __init__(self, telemetry: SloTelemetry, swapper: SelectorLadder,
                 recompose_fn: Optional[
                     Callable[[TelemetrySnapshot],
                              Optional[np.ndarray]]] = None,
                 config: Optional[ControllerConfig] = None,
                 service_profile_fn: Optional[
                     Callable[[], Tuple[float, float]]] = None,
                 sync: bool = False,
                 clock: Callable[[], float] = time.monotonic):
        """``service_profile_fn`` returns (mu, T_s) of the ACTIVE
        ensemble so snapshots carry the online T_q bound."""
        self.telemetry = telemetry
        self.swapper = swapper
        self.recompose_fn = recompose_fn
        if config is None:
            config = ControllerConfig(slo_seconds=telemetry.slo)
        elif abs(config.slo_seconds - telemetry.slo) > 1e-12:
            # violation_rate is computed by telemetry against ITS slo;
            # decide() compares p99 against the config's — they must be
            # the same threshold or the two signals contradict
            raise ValueError(
                f"config.slo_seconds={config.slo_seconds} != "
                f"telemetry.slo={telemetry.slo}")
        self.config = config
        self.service_profile_fn = service_profile_fn
        self.sync = sync
        self.clock = clock
        self.log: List[Tuple[float, Decision]] = []
        self.baseline_rate: Optional[float] = None  # rate at last compose
        self.n_recomposes = 0
        self._last_action_t = -float("inf")
        self._recomposing = threading.Event()
        self._recompose_thread: Optional[threading.Thread] = None
        self._monitor: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---------------------------------------------------------- policy
    def decide(self, snap: TelemetrySnapshot) -> Decision:
        """Pure policy (no side effects) — unit-testable in isolation."""
        c = self.config
        if snap.n_served < c.min_samples:
            return Decision.HOLD
        if (snap.violation_rate >= c.violation_high
                or snap.p99 > c.slo_seconds or snap.n_shed > 0):
            return Decision.SHED if self.swapper.can_shed() \
                else Decision.RECOMPOSE
        if np.isfinite(snap.predicted_latency) \
                and snap.predicted_latency > c.predicted_factor \
                * c.slo_seconds:
            return Decision.RECOMPOSE          # predicted risk, act early
        if self.baseline_rate and snap.arrival_rate > 0:
            ratio = snap.arrival_rate / self.baseline_rate
            if ratio >= c.drift_factor or ratio <= 1.0 / c.drift_factor:
                return Decision.RECOMPOSE      # load drifted: re-search
        if (snap.violation_rate <= c.violation_low
                and snap.p99 <= c.headroom_frac * c.slo_seconds
                and self.swapper.can_climb()):
            return Decision.CLIMB
        return Decision.HOLD

    # ------------------------------------------------------------- act
    def snapshot(self, now: Optional[float] = None) -> TelemetrySnapshot:
        mu = ts = None
        if self.service_profile_fn is not None:
            mu, ts = self.service_profile_fn()
        # evidence must postdate the last actuation: the violation burst
        # that justified a shed stays in the sliding window for up to
        # window_seconds and must not re-trigger a shed per cooldown,
        # cascading the ladder to the floor
        since = self._last_action_t \
            if np.isfinite(self._last_action_t) else None
        return self.telemetry.snapshot(mu=mu, ts=ts or 0.0, now=now,
                                       since=since)

    def step(self, now: Optional[float] = None) -> Decision:
        """One control iteration: snapshot, decide, act."""
        now = self.clock() if now is None else now
        if now - self._last_action_t < self.config.cooldown_seconds:
            return Decision.HOLD
        snap = self.snapshot(now)
        if self.baseline_rate is None and snap.arrival_rate > 0:
            self.baseline_rate = snap.arrival_rate
        decision = self.decide(snap)
        acted = False
        if decision is Decision.SHED:
            acted = self.swapper.shed()
            # find the right ensemble for the new load in the background
            acted = self._launch_recompose(snap) or acted
        elif decision is Decision.CLIMB:
            acted = self.swapper.climb()
        elif decision is Decision.RECOMPOSE:
            acted = self._launch_recompose(snap)
        if not acted:
            # nothing actually changed (rung race, recompose already in
            # flight): don't log a phantom action or start a cooldown
            # that would delay the real corrective step
            return Decision.HOLD
        self._last_action_t = now
        self.log.append((now, decision))
        return decision

    def _launch_recompose(self, snap: TelemetrySnapshot) -> bool:
        """Returns True iff a recompose was actually started."""
        if self.recompose_fn is None or self._recomposing.is_set():
            return False
        self._recomposing.set()
        if self.sync:
            try:
                self._recompose(snap)
            finally:
                self._recomposing.clear()
            return True

        def run():
            try:
                self._recompose(snap)
            finally:
                self._recomposing.clear()
        self._recompose_thread = threading.Thread(target=run, daemon=True)
        self._recompose_thread.start()
        return True

    def _recompose(self, snap: TelemetrySnapshot) -> None:
        selector = self.recompose_fn(snap)
        self.n_recomposes += 1
        self.baseline_rate = snap.arrival_rate or self.baseline_rate
        if selector is not None and not np.array_equal(
                np.asarray(selector, np.int8),
                self.swapper.active_selector):
            self.swapper.swap_to(selector)

    def join_recompose(self, timeout: float = 60.0) -> None:
        t = self._recompose_thread
        if t is not None:
            t.join(timeout)

    # --------------------------------------------------- monitor loop
    def start(self, period_seconds: float = 1.0) -> "AdaptiveController":
        def loop():
            while not self._stop.wait(period_seconds):
                self.step()
        self._monitor = threading.Thread(target=loop, daemon=True)
        self._monitor.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=5.0)
        self.join_recompose(timeout=5.0)
