"""Deterministic fault-injection plane + recovery wiring (chaos drills).

HOLMES's claim is always-on sub-second scoring; what makes that claim
believable is how the stack behaves when something breaks at 3am.  This
module is the seeded, replayable "something breaks": a declarative
schedule of ``FaultEvent``s that a ``FaultPlane`` fires against the
live serving stack, plus the recovery wiring that turns each fault into
a bounded, fully-accounted outcome instead of a wrong or missing score.

Fault kinds and their recovery contracts:

* ``device_loss`` — the plane's ``dispatch_guard`` (armed on every
  ``EnsembleService`` a ``HotSwapper`` hands out, via ``service_hook``)
  raises ``DeviceLostError`` the moment a flush would dispatch a bucket
  onto the lost device.  ``protect()`` catches it in the server worker:
  a PERMANENT loss (duration 0) quarantines the device —
  ``HotSwapper.quarantine_device`` re-derives the placement over the
  survivors and hot-swaps the active selector onto it — then the flush
  retries on the recovered service; a TRANSIENT loss (duration > 0,
  the only recoverable shape on a single-device pool) retries until the
  plane restores the device.  Either way the co-batched queries are
  served late, never dropped and never mis-scored.

* ``worker_stall`` — ``protect()`` consumes a stall token and sleeps
  ``duration`` inside exactly one worker's handler.  The server's
  watchdog (``EnsembleServer(deadline_seconds=...)``) detects the hang,
  retires the in-flight co-batch NaN (the standard failure score),
  respawns the worker, and the staleness guards refuse any window the
  stall outlived — a stalled query yields NaN, never a stale score.

* ``backpressure`` — an advisory episode: while active, the trace
  driver overruns the ingest side (``backpressure_active()``), and the
  bounded ``ShedQueue`` + priority-aware admission shed the stable tier
  first, counting every rejection in ``ServerStats``.

* ``ticker_stall`` — the slot-engine analogue of ``worker_stall``: the
  ``SlotTicker``'s ``before_tick`` hook (wired by ``protect_engine``)
  consumes a token and sleeps it out WITHOUT heart-beating, so the
  ``TickerWatchdog`` must detect the quiet beat and respawn the ticker;
  readers ride the gap on the tick-age guard (NaN-or-stale, never a
  wrong score).

``protect()`` guards the flush/worker path; ``protect_engine()`` is the
same contract for the continuous slot path — every tick gather and
bucket dispatch runs behind ``guard``, a loss aborts the tick BEFORE
the donated fold, and recovery (quarantine + engine rebind, optionally
shedding the ``TickLadder`` while shards recompile) re-ticks onto the
survivor placement.

Everything is driven by an injectable MONOTONIC clock relative to
``arm()`` time (wall-clock steps must never shear event timing in long
soaks), so the same schedule replays identically run to run; schedules
round-trip through ``to_json``/``from_json`` as committed trace files.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

log = logging.getLogger(__name__)

FAULT_KINDS = ("device_loss", "worker_stall", "backpressure",
               "ticker_stall")


class DeviceLostError(RuntimeError):
    """Raised by the armed dispatch guard when a flush would dispatch
    onto a device the fault plane has marked lost."""

    def __init__(self, device, index: int):
        super().__init__(f"device {index} ({device}) lost")
        self.device = device
        self.index = index


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``t`` is seconds after ``arm()``;
    ``target`` is a device index for ``device_loss`` (ignored
    otherwise); ``duration`` is the stall length / backpressure episode
    length / transient-loss length — 0 makes a device loss PERMANENT
    (recovery must come from quarantine + re-placement, not from the
    device coming back)."""
    t: float
    kind: str
    target: int = 0
    duration: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")

    def to_dict(self) -> Dict:
        return {"t": self.t, "kind": self.kind, "target": self.target,
                "duration": self.duration}


class FaultPlane:
    """Seeded, declarative fault injector for the serving stack.

    Usage::

        plane = FaultPlane(schedule).arm(swapper)
        handler = plane.protect(score_fn, swapper)   # server worker path
        srv = EnsembleServer(batch_handler=..., deadline_seconds=0.25)

    ``arm`` hooks the swapper so every staged ``EnsembleService`` gets
    the plane's ``dispatch_guard`` — a swap mid-run cannot escape
    injection — and starts the schedule clock.  All state transitions
    are time-driven from the schedule (no randomness at fire time; the
    seed exists for schedule *generators*), so a run is replayable.
    """

    def __init__(self, schedule: Sequence[FaultEvent], seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.schedule = sorted(schedule, key=lambda e: e.t)
        self.seed = seed
        self.clock = clock
        self._lock = threading.RLock()
        self._armed_at: Optional[float] = None
        self._pending: List[FaultEvent] = list(self.schedule)
        self._lost: Dict[int, FaultEvent] = {}     # device idx -> event
        self._stalls: List[FaultEvent] = []        # unconsumed stall tokens
        self._ticker_stalls: List[FaultEvent] = []  # ticker stall tokens
        self._bp: List[FaultEvent] = []            # backpressure episodes
        self.devices: List = []
        self.fired: List[Tuple[float, FaultEvent]] = []
        self.recoveries: List[Dict] = []           # what recovered, when, how
        self.swapper = None
        # one failover thread ever per lost device index: the worker
        # that trips the loss starts it, every other worker (and every
        # retry) just waits on it — presence in the dict marks the
        # attempt so a failed quarantine is not re-run forever
        self._failover_threads: Dict[int, threading.Thread] = {}

    # ------------------------------------------------------------- arming
    def arm(self, swapper=None, devices: Optional[Sequence] = None
            ) -> "FaultPlane":
        """Start the schedule clock and hook the serving stack: the
        swapper's ``service_hook`` arms every service it stages (past
        and future) with this plane's dispatch guard."""
        import jax
        self.devices = list(devices) if devices is not None \
            else list(jax.devices())
        self._armed_at = self.clock()
        self.swapper = swapper
        if swapper is not None:
            swapper.service_hook = self._arm_service
            self._arm_service(swapper.facade.current)
        return self

    def _arm_service(self, svc) -> None:
        svc.dispatch_guard = self.guard

    def now(self) -> float:
        """Seconds since ``arm()`` on the plane's MONOTONIC clock —
        never wall time, so a host clock step cannot shear a schedule
        mid-soak."""
        if self._armed_at is None:
            raise RuntimeError("FaultPlane not armed")
        return self.clock() - self._armed_at

    # ------------------------------------------------------------- firing
    def _tick(self) -> None:
        with self._lock:
            if self._armed_at is None:
                return          # pre-arm probe (e.g. a ticker hook
            t = self.now()      # wired before the schedule starts)
            while self._pending and self._pending[0].t <= t:
                ev = self._pending.pop(0)
                self.fired.append((t, ev))
                log.info("fault fired at t=%.3f: %s", t, ev)
                if ev.kind == "device_loss":
                    self._lost[ev.target] = ev
                elif ev.kind == "worker_stall":
                    self._stalls.append(ev)
                elif ev.kind == "ticker_stall":
                    self._ticker_stalls.append(ev)
                else:
                    self._bp.append(ev)
            # transient losses expire on their own (the device "reboots")
            for idx, ev in list(self._lost.items()):
                if ev.duration > 0 and t >= ev.t + ev.duration:
                    del self._lost[idx]
                    self.recoveries.append(
                        {"t": t, "kind": "device_restored", "target": idx})

    def _device_of(self, index: int):
        return self.devices[index] if index < len(self.devices) else None

    def guard(self, device) -> None:
        """The ``EnsembleService.dispatch_guard``: called with the
        bucket's pinned device (None = default device) immediately
        before each stacked dispatch."""
        self._tick()
        with self._lock:
            for idx, ev in self._lost.items():
                dev = self._device_of(idx)
                if device is dev or (device is None and idx == 0):
                    raise DeviceLostError(dev, idx)

    def stall_pending(self) -> float:
        """Consume one due stall token; returns the stall duration (0.0
        when none due).  Exactly one caller gets each token, so one
        scheduled stall hangs exactly one worker."""
        self._tick()
        with self._lock:
            if self._stalls:
                return self._stalls.pop(0).duration
        return 0.0

    def ticker_stall_pending(self) -> float:
        """Consume one due ticker-stall token; returns the stall
        duration (0.0 when none due).  This IS the ``SlotTicker``'s
        ``before_tick`` hook (wired by ``protect_engine``), so it is
        safe to call before ``arm()`` — the ticker usually starts
        first."""
        self._tick()
        with self._lock:
            if self._ticker_stalls:
                return self._ticker_stalls.pop(0).duration
        return 0.0

    def backpressure_active(self) -> bool:
        """True while a backpressure episode is in progress — the trace
        driver's cue to overrun the ingest side."""
        self._tick()
        with self._lock:
            if self._armed_at is None:
                return False
            t = self.now()
            return any(ev.t <= t < ev.t + max(ev.duration, 1e-9)
                       for ev in self._bp)

    def active_losses(self) -> Dict[int, FaultEvent]:
        self._tick()
        with self._lock:
            return dict(self._lost)

    def done(self) -> bool:
        self._tick()
        with self._lock:
            return not self._pending

    # ----------------------------------------------------------- recovery
    def _failover(self, err: DeviceLostError, swapper,
                  beat: Callable[[], bool], retry_sleep: float) -> None:
        """Quarantine the lost device in a SIDE thread while the
        triggering worker heart-beats: a failover restage takes real
        seconds (the moved buckets recompile), and a worker silently
        blocked inside it would read as a hang to the server's watchdog
        — its co-batch NaN-failed mid-recovery.  Exactly one thread is
        ever started per device index; every other worker that trips
        the same loss waits on it here."""
        with self._lock:
            th = self._failover_threads.get(err.index)
            if th is None:
                def _run():
                    if swapper.quarantine_device(err.device):
                        self.recoveries.append(
                            {"t": self.now(), "kind": "quarantined",
                             "target": err.index})
                        log.info("quarantined device %d; re-placed "
                                 "onto survivors", err.index)
                    else:
                        log.warning("quarantine of device %d failed "
                                    "(no survivors?)", err.index)
                th = threading.Thread(
                    target=_run, name=f"repro-failover-{err.index}",
                    daemon=True)
                self._failover_threads[err.index] = th
                th.start()
        while th.is_alive():
            beat()
            th.join(retry_sleep)

    def protect(self, score_fn: Callable, swapper=None,
                heartbeat: Optional[Callable[[], bool]] = None,
                retry_budget_s: float = 60.0,
                retry_sleep: float = 0.02) -> Callable:
        """Wrap a batch scoring function with stall injection and
        device-loss recovery; the result is what the server's workers
        call.

        On ``DeviceLostError``: a permanent loss triggers
        ``swapper.quarantine_device`` in a side thread (minimal-move
        re-place onto survivors) and retries on the recovered facade; a
        transient loss (or a pool with no survivor) retries on a short
        sleep until the plane restores the device.  Throughout the wait
        the wrapper calls ``heartbeat`` (pass the server's
        ``heartbeat`` method) so the watchdog knows the co-batch is
        alive and recovering — an injected STALL deliberately never
        heart-beats, so the watchdog still catches real hangs.  The
        co-batch is never dropped: either a retry eventually serves it,
        or the ``retry_budget_s`` is exhausted and the raised error
        lands in the server's NaN-isolation path — still accounted,
        still never mis-scored.
        """
        swapper = swapper if swapper is not None else self.swapper

        def beat() -> bool:
            if heartbeat is None:
                return True
            try:
                return bool(heartbeat())
            except Exception:
                return True

        def guarded(windows, *rest):
            dur = self.stall_pending()
            if dur > 0:
                log.info("injected worker stall: %.3fs", dur)
                time.sleep(dur)       # silent: the watchdog MUST fire
            # the retry budget runs on the plane's injectable MONOTONIC
            # clock — same timeline as the schedule, immune to wall steps
            t_give_up = self.clock() + retry_budget_s
            last_err = None
            while True:
                try:
                    return score_fn(windows, *rest)
                except DeviceLostError as e:
                    last_err = e
                    if self.clock() >= t_give_up or not beat():
                        raise last_err  # budget gone / co-batch already
                    #                     abandoned: NaN-isolation path
                    ev = self.active_losses().get(e.index)
                    permanent = ev is not None and ev.duration == 0
                    if permanent and swapper is not None:
                        self._failover(e, swapper, beat, retry_sleep)
                    else:
                        time.sleep(retry_sleep)  # transient: wait it out

        return guarded

    def protect_engine(self, engine, swapper=None, ticker=None,
                       tick_ladder=None,
                       retry_sleep: float = 0.02) -> "FaultPlane":
        """Extend injection + recovery into the continuous slot path —
        the tick-side sibling of ``protect()``:

        * ``ticker.before_tick`` consumes ticker-stall tokens (the
          stall sleeps in the ticker loop without beating, so the
          ``TickerWatchdog`` must catch it);
        * ``engine.on_device_lost`` becomes the tick recovery hook: a
          PERMANENT loss on a sharded pool sheds the ``TickLadder``
          one rung (cheaper ticks while the moved shards recompile —
          undone right after), quarantines the device through the
          shared one-thread-per-index ``_failover`` path, rebinds the
          engine onto the survivor facade and returns True so the
          aborted tick re-runs; a TRANSIENT loss returns False — the
          tick aborts clean and the next tick retries once the device
          reboots;
        * the swapper's ``quarantine_hooks`` gain a rebind request, so
          a FLUSH-path quarantine (both engines live on one pool) also
          re-points the slot engine — lazily, at its next tick, since
          a hook firing mid-tick must not deadlock on the tick lock.
        """
        swapper = swapper if swapper is not None else self.swapper
        if ticker is not None:
            ticker.before_tick = self.ticker_stall_pending

        def _recover(err: DeviceLostError) -> bool:
            ev = self.active_losses().get(err.index)
            permanent = ev is not None and ev.duration == 0
            if not permanent or swapper is None:
                return False
            shed = tick_ladder is not None and tick_ladder.shed()
            try:
                self._failover(err, swapper, beat=lambda: True,
                               retry_sleep=retry_sleep)
            finally:
                if shed:
                    tick_ladder.climb()
            if err.device in getattr(swapper, "quarantined", []):
                engine.rebind(swapper.facade.current)
                return True
            return False

        engine.on_device_lost = _recover
        hooks = getattr(swapper, "quarantine_hooks", None)
        if hooks is not None:
            hooks.append(lambda device, svc: engine.request_rebind(svc))
        return self

    # -------------------------------------------------------- trace files
    def to_json(self, path: Optional[str] = None) -> str:
        """Serialize the SCHEDULE (not runtime state) as a replayable
        trace: committed alongside the bench results, it pins exactly
        which faults a soak survived."""
        payload = {"version": 1, "seed": self.seed,
                   "schedule": [ev.to_dict() for ev in self.schedule]}
        text = json.dumps(payload, indent=2) + "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    @classmethod
    def from_json(cls, src,
                  clock: Callable[[], float] = time.monotonic
                  ) -> "FaultPlane":
        """Rebuild a plane from ``to_json`` output — accepts the
        parsed dict, the JSON text, or a path to a trace file."""
        if isinstance(src, dict):
            payload = src
        else:
            text = str(src)
            if not text.lstrip().startswith("{"):
                with open(text) as f:
                    text = f.read()
            payload = json.loads(text)
        events = [FaultEvent(t=float(ev["t"]), kind=str(ev["kind"]),
                             target=int(ev.get("target", 0)),
                             duration=float(ev.get("duration", 0.0)))
                  for ev in payload.get("schedule", [])]
        return cls(events, seed=int(payload.get("seed", 0)),
                   clock=clock)


# ------------------------------------------------- compound schedules
def compound_schedule(n_devices: int, seed: int = 0,
                      t0: float = 0.45) -> List[FaultEvent]:
    """Flush-path compound schedule: overlapping device losses, a loss
    DURING a backpressure episode, and a worker-stall cascade.
    Deterministic in (n_devices, seed) — the seed jitters timings,
    never the shape."""
    rng = np.random.default_rng(seed)

    def j(hi: float = 0.05) -> float:
        return float(rng.uniform(0.0, hi))

    ev = [FaultEvent(t0 + j(), "worker_stall", duration=0.6),
          FaultEvent(t0 + 0.1 + j(), "worker_stall", duration=0.5)]
    bp = t0 + 0.9 + j()
    ev.append(FaultEvent(bp, "backpressure", duration=0.6))
    if n_devices >= 2:
        # permanent loss inside the backpressure episode, with a
        # transient loss of a SECOND device overlapping the quarantine
        ev.append(FaultEvent(bp + 0.15 + j(), "device_loss", target=1))
        ev.append(FaultEvent(bp + 0.2 + j(), "device_loss",
                             target=2 if n_devices > 2 else 0,
                             duration=0.5))
    else:
        ev.append(FaultEvent(bp + 0.15 + j(), "device_loss", target=0,
                             duration=0.35))
        ev.append(FaultEvent(bp + 0.85 + j(), "device_loss", target=0,
                             duration=0.25))
    return sorted(ev, key=lambda e: e.t)


def slot_compound_schedule(n_devices: int, seed: int = 0,
                           t0: float = 0.45) -> List[FaultEvent]:
    """Slot-engine compound schedule: a ticker-stall cascade (the
    watchdog must respawn through BOTH stalls), then overlapping
    device losses during a backpressure episode.  No ``worker_stall``
    — the slot path's server workers only wait on versions; the stall
    surface is the ticker itself."""
    rng = np.random.default_rng(seed)

    def j(hi: float = 0.05) -> float:
        return float(rng.uniform(0.0, hi))

    ev = [FaultEvent(t0 + j(), "ticker_stall", duration=0.7),
          FaultEvent(t0 + 0.1 + j(), "ticker_stall", duration=0.5)]
    bp = t0 + 1.1 + j()
    ev.append(FaultEvent(bp, "backpressure", duration=0.6))
    if n_devices >= 2:
        ev.append(FaultEvent(bp + 0.15 + j(), "device_loss", target=1))
        ev.append(FaultEvent(bp + 0.2 + j(), "device_loss",
                             target=2 if n_devices > 2 else 0,
                             duration=0.5))
    else:
        # single device: transient losses are the only recoverable
        # shape — one inside backpressure, one after
        ev.append(FaultEvent(bp + 0.15 + j(), "device_loss", target=0,
                             duration=0.35))
        ev.append(FaultEvent(bp + 0.85 + j(), "device_loss", target=0,
                             duration=0.25))
    return sorted(ev, key=lambda e: e.t)


def wire_controller(telemetry, swapper, member_costs=None,
                    config=None, recompose_fn=None,
                    period_seconds: float = 0.25, sync: bool = False,
                    start: bool = True, exporter=None,
                    on_step: Optional[Callable] = None,
                    aux_ladder=None):
    """Run an ``AdaptiveController`` against a REAL ``EnsembleServer``:
    the server taps ``telemetry`` (pass the same object to
    ``EnsembleServer(telemetry=...)``), and the returned controller's
    monitor loop actuates shed/climb/recompose/RE-PLACE on ``swapper``
    from that live wall-clock feed — the end-to-end loop the DES only
    simulated.

    ``member_costs`` (per-member service seconds, e.g. from
    ``EnsembleService.measured_bucket_costs``) powers the service
    profile: mu from the active selector's total cost (scaled by
    ``swapper.speeds`` on a heterogeneous pool).  T_s and imbalance
    prefer the LIVE per-slot finish times measured from shard retire
    EWMAs (``EnsembleService.measured_finish_times``) — a device that
    slowed down after planning shows up there, never in the planned
    loads — falling back to the ACTIVE placement's finish-time
    makespan/imbalance (never a fresh idealized LPT plan: a
    deliberately unbalanced post-failover plan must be profiled as
    what it is).

    ``exporter`` (an ``obs.export.MetricsExporter``) is attached to the
    returned controller so scrapes see live decision counters;
    ``on_step(decision)`` is invoked after every control iteration —
    the hook benches use to dump metrics on actuation.

    ``aux_ladder`` (a ``serving.slots.TickLadder``) adds tick RATE as
    a cheaper first degradation rung: the controller sheds the aux
    ladder before members and climbs members before the aux ladder
    (LIFO undo), so a pressured slot engine slows its ticks before it
    thins its ensemble.
    """
    from repro.control.controller import AdaptiveController

    costs = None if member_costs is None \
        else np.asarray(member_costs, np.float64)

    def profile_fn():
        from repro.serving.placement import finish_imbalance
        sel = np.asarray(swapper.active_selector, bool)
        pl = swapper.active_placement
        svc = getattr(getattr(swapper, "facade", None), "current", None)
        fin = getattr(svc, "measured_finish_times", None)
        fin = fin() if callable(fin) else None
        if fin is not None and pl is not None \
                and len(fin) == pl.n_slots:
            ts_live, imb = max(fin), finish_imbalance(fin)
        elif pl is not None:
            ts_live, imb = pl.makespan, pl.imbalance
        else:
            ts_live, imb = None, float("nan")
        if costs is None:
            return (float("inf"), ts_live or 0.0, imb)
        total = float(costs[sel].sum()) or 1e-9
        speeds = getattr(swapper, "speeds", None)
        capacity = float(np.sum(speeds)) if speeds else \
            max(1, getattr(swapper, "n_devices", 1))
        ts = ts_live if ts_live is not None else total
        return (capacity / total, ts, imb)

    ctl = AdaptiveController(telemetry, swapper, recompose_fn=recompose_fn,
                             config=config, service_profile_fn=profile_fn,
                             sync=sync, aux_ladder=aux_ladder)
    if exporter is not None:
        # scrapes read the live controller/telemetry from now on
        exporter.controller = ctl
        if exporter.telemetry is None:
            exporter.telemetry = telemetry
        ctl.exporter = exporter
    if on_step is not None:
        base_step = ctl.step

        def stepped(now=None):
            decision = base_step(now)
            try:
                on_step(decision)
            except Exception:          # an observer must never kill
                log.exception("wire_controller on_step hook failed")
            return decision

        ctl.step = stepped             # monitor loop resolves the attr
    if start:
        ctl.start(period_seconds=period_seconds)
    return ctl
