"""Flash attention (prefill/training) Pallas TPU kernel.

MXU-tiled online-softmax attention with GQA head grouping, causal and
sliding-window masking driven by explicit position vectors (so ring-buffer
caches work unchanged).

Grid: (batch, q_heads, q_blocks, k_blocks) — the k_block axis is innermost
and sequential on TPU, accumulating into VMEM scratch (m, l, acc).  Blocks
fully masked out by causality/window are skipped via @pl.when, which for
causal prefill halves the compute versus a dense sweep.

Block sizes default to 128 (MXU native); inputs are padded in the wrapper
and positions carry validity (pos < 0 = empty), so any shape works.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
            window: int, block_q: int, block_k: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp = qpos_ref[...].astype(jnp.int32)            # [block_q]
    kp = kpos_ref[...].astype(jnp.int32)            # [block_k]

    # --- structural skip: block entirely masked -------------------------
    q_min = jnp.min(qp)
    q_max = jnp.max(qp)
    k_min = jnp.min(jnp.where(kp >= 0, kp, jnp.iinfo(jnp.int32).max))
    any_valid = jnp.any(kp >= 0)
    live = any_valid
    if causal:
        live &= k_min <= q_max
    if window:
        k_max = jnp.max(kp)
        live &= k_max > q_min - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :]                       # [block_q, d]
        k = k_ref[0, :, 0, :]                       # [block_k, d]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        ok = kp[None, :] >= 0
        if causal:
            ok &= kp[None, :] <= qp[:, None]
        if window:
            ok &= qp[:, None] - kp[None, :] < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                         # [block_q]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_cur

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _pad_to(x, mult, axis, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    qpos: jax.Array, kpos: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B,S,Hq,D]; k,v: [B,T,Hkv,D]; qpos: [S]; kpos: [T] -> [B,S,Hq,D].

    Requires k/v head dim == q head dim (use MLA's non-absorbed
    materialization or the decode kernel otherwise).
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = float(scale if scale is not None else D ** -0.5)
    block_q = min(block_q, max(S, 8))
    block_k = min(block_k, max(T, 8))

    qp = _pad_to(qpos.astype(jnp.int32), block_q, 0, value=-(2 ** 30))
    kp = _pad_to(kpos.astype(jnp.int32), block_k, 0, value=-1)
    q = _pad_to(q, block_q, 1)
    k = _pad_to(k, block_k, 1)
    v = _pad_to(v, block_k, 1)
    Sp, Tp = q.shape[1], k.shape[1]

    grid = (B, Hq, Sp // block_q, Tp // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda b, h, qi, ki: (qi,)),
            pl.BlockSpec((block_k,), lambda b, h, qi, ki: (ki,)),
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki, _g=g: (b, ki, h // _g, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki, _g=g: (b, ki, h // _g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, q, k, v)
    return out[:, :S]
