"""1-D "stripe" grouped convolution Pallas TPU kernel.

The hot op of the paper's ECG ResNeXt zoo (and the Mamba short conv).
TPU adaptation (DESIGN.md §2): instead of an im2col buffer, the conv is a
K-tap sum of shifted [L, Cin_g] x [Cin_g, Cout_g] matmuls with the weight
tap held VMEM-stationary — MXU-shaped without materializing patches.

Grid: (batch, groups) — each step keeps the full (padded) length in VMEM,
which fits for waveform workloads (7500 x 128 floats = 3.8 MB).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, y_ref, *, K: int, stride: int, L_out: int):
    x = x_ref[0]                                  # [Lp, cin_g]
    acc = jnp.zeros((L_out, y_ref.shape[-1]), jnp.float32)
    for k in range(K):                            # K is small (4 or 7)
        xk = jax.lax.dynamic_slice_in_dim(x, k, (L_out - 1) * stride + 1, 0)
        xk = xk[::stride]                         # [L_out, cin_g]
        acc += jax.lax.dot_general(
            xk, w_ref[k], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    y_ref[0] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "groups", "padding",
                                             "interpret"))
def conv1d_stripe(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                  stride: int = 1, groups: int = 1, padding: str = "SAME",
                  *, interpret: bool = False) -> jax.Array:
    """x: [B, L, Cin]; w: [K, Cin//groups, Cout]; SAME or CAUSAL padding.
    Matches ref.conv1d_stripe / lax.conv_general_dilated."""
    B, L, Cin = x.shape
    K, cin_g, Cout = w.shape
    cout_g = Cout // groups
    L_out = -(-L // stride)                       # ceil, as in SAME

    if padding == "CAUSAL":
        lo, hi = K - 1, 0
    else:                                         # SAME (lax convention)
        pad_total = max((L_out - 1) * stride + K - L, 0)
        lo = pad_total // 2
        hi = pad_total - lo
    extra = (L_out - 1) * stride + K - (L + lo + hi)
    xp = jnp.pad(x, ((0, 0), (lo, hi + max(extra, 0)), (0, 0)))
    Lp = xp.shape[1]

    grid = (B, groups)
    y = pl.pallas_call(
        functools.partial(_kernel, K=K, stride=stride, L_out=L_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lp, cin_g), lambda bi, g: (bi, 0, g)),
            pl.BlockSpec((K, cin_g, cout_g), lambda bi, g: (0, 0, g)),
        ],
        out_specs=pl.BlockSpec((1, L_out, cout_g), lambda bi, g: (bi, 0, g)),
        out_shape=jax.ShapeDtypeStruct((B, L_out, Cout), x.dtype),
        interpret=interpret,
    )(xp, w)
    if b is not None:
        y = y + b
    return y
