"""1-D "stripe" grouped convolution Pallas TPU kernel.

The hot op of the paper's ECG ResNeXt zoo (and the Mamba short conv).
TPU adaptation (DESIGN.md §2): instead of an im2col buffer, the conv is a
K-tap sum of shifted [L, Cin_g] x [Cin_g, Cout_g] matmuls with the weight
tap held VMEM-stationary — MXU-shaped without materializing patches.

Grid: (batch, groups) — each step keeps the full (padded) length in VMEM,
which fits for waveform workloads (7500 x 128 floats = 3.8 MB).

``conv1d_stripe_stacked`` is the ensemble-serving variant: a leading
MEMBER axis on both activations and weights (grid ``(member, batch,
groups)``) so one kernel launch covers a whole architecture bucket of
stacked zoo members — each grid step keeps its member's weight tap
VMEM-stationary while sweeping the micro-batch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, y_ref, *, K: int, stride: int, L_out: int):
    x = x_ref[0]                                  # [Lp, cin_g]
    acc = jnp.zeros((L_out, y_ref.shape[-1]), jnp.float32)
    for k in range(K):                            # K is small (4 or 7)
        xk = jax.lax.dynamic_slice_in_dim(x, k, (L_out - 1) * stride + 1, 0)
        xk = xk[::stride]                         # [L_out, cin_g]
        acc += jax.lax.dot_general(
            xk, w_ref[k], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    y_ref[0] = acc.astype(y_ref.dtype)


def _kernel_stacked(x_ref, w_ref, y_ref, *, K: int, stride: int,
                    L_out: int):
    x = x_ref[0, 0]                               # [Lp, cin_g]
    acc = jnp.zeros((L_out, y_ref.shape[-1]), jnp.float32)
    for k in range(K):
        xk = jax.lax.dynamic_slice_in_dim(x, k, (L_out - 1) * stride + 1, 0)
        xk = xk[::stride]                         # [L_out, cin_g]
        acc += jax.lax.dot_general(
            xk, w_ref[0, k], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    y_ref[0, 0] = acc.astype(y_ref.dtype)


def _same_padding(L: int, K: int, stride: int):
    """(lo, hi, L_out) for lax-convention SAME padding."""
    L_out = -(-L // stride)                       # ceil, as in SAME
    pad_total = max((L_out - 1) * stride + K - L, 0)
    lo = pad_total // 2
    return lo, pad_total - lo, L_out


@functools.partial(jax.jit, static_argnames=("stride", "groups", "padding",
                                             "interpret"))
def conv1d_stripe(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                  stride: int = 1, groups: int = 1, padding: str = "SAME",
                  *, interpret: bool = False) -> jax.Array:
    """x: [B, L, Cin]; w: [K, Cin//groups, Cout]; SAME or CAUSAL padding.
    Matches ref.conv1d_stripe / lax.conv_general_dilated."""
    B, L, Cin = x.shape
    K, cin_g, Cout = w.shape
    cout_g = Cout // groups

    if padding == "CAUSAL":
        L_out = -(-L // stride)
        lo, hi = K - 1, 0
    else:                                         # SAME (lax convention)
        lo, hi, L_out = _same_padding(L, K, stride)
    extra = (L_out - 1) * stride + K - (L + lo + hi)
    xp = jnp.pad(x, ((0, 0), (lo, hi + max(extra, 0)), (0, 0)))
    Lp = xp.shape[1]

    grid = (B, groups)
    y = pl.pallas_call(
        functools.partial(_kernel, K=K, stride=stride, L_out=L_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lp, cin_g), lambda bi, g: (bi, 0, g)),
            pl.BlockSpec((K, cin_g, cout_g), lambda bi, g: (0, 0, g)),
        ],
        out_specs=pl.BlockSpec((1, L_out, cout_g), lambda bi, g: (bi, 0, g)),
        out_shape=jax.ShapeDtypeStruct((B, L_out, Cout), x.dtype),
        interpret=interpret,
    )(xp, w)
    if b is not None:
        y = y + b
    return y


@functools.partial(jax.jit, static_argnames=("stride", "groups", "padding",
                                             "interpret"))
def conv1d_stripe_stacked(x: jax.Array, w: jax.Array,
                          b: Optional[jax.Array] = None,
                          stride: int = 1, groups: int = 1,
                          padding: str = "SAME", *,
                          interpret: bool = False) -> jax.Array:
    """Member-stacked stripe conv for bucketed ensemble serving.

    x: [M, B, L, Cin]; w: [M, K, Cin//groups, Cout]; b: [M, Cout].
    One launch computes all M stacked members on the shared micro-batch:
    grid (member, batch, groups), each member's weight tap staying
    VMEM-stationary across its batch/group steps.  Matches
    ``jax.vmap(conv1d_stripe)`` / a vmapped ``ref.conv1d_stripe``.
    """
    M, B, L, Cin = x.shape
    Mw, K, cin_g, Cout = w.shape
    assert Mw == M, (Mw, M)
    cout_g = Cout // groups

    if padding == "CAUSAL":
        L_out = -(-L // stride)
        lo, hi = K - 1, 0
    else:
        lo, hi, L_out = _same_padding(L, K, stride)
    extra = (L_out - 1) * stride + K - (L + lo + hi)
    xp = jnp.pad(x, ((0, 0), (0, 0), (lo, hi + max(extra, 0)), (0, 0)))
    Lp = xp.shape[2]

    grid = (M, B, groups)
    y = pl.pallas_call(
        functools.partial(_kernel_stacked, K=K, stride=stride, L_out=L_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Lp, cin_g),
                         lambda m, bi, g: (m, bi, 0, g)),
            pl.BlockSpec((1, K, cin_g, cout_g),
                         lambda m, bi, g: (m, 0, 0, g)),
        ],
        out_specs=pl.BlockSpec((1, 1, L_out, cout_g),
                               lambda m, bi, g: (m, bi, 0, g)),
        out_shape=jax.ShapeDtypeStruct((M, B, L_out, Cout), x.dtype),
        interpret=interpret,
    )(xp, w)
    if b is not None:
        y = y + b[:, None, None, :]
    return y
