"""Mamba-2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

The SSD insight: within a chunk the recurrence is a (masked, decay-weighted)
attention-like quadratic form that maps onto the MXU; across chunks only a
small [P, N] state is carried.  We put the chunk axis innermost in the grid
so the carried state lives in VMEM scratch across sequential grid steps —
the TPU-native replacement for the CUDA warp-parallel scan.

Grid: (batch, heads, chunks).  Per-step blocks: x [C,P], dt [C], B/C [C,N]
(groups broadcast to heads in the index map), carried h [P,N] in scratch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
            y_ref, hT_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)       # [P, N]

    x = x_ref[0, :, 0, :].astype(jnp.float32)               # [C, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)                # [C]
    Bm = B_ref[0, :, 0, :].astype(jnp.float32)              # [C, N]
    Cm = C_ref[0, :, 0, :].astype(jnp.float32)              # [C, N]
    A = A_ref[0]                                            # scalar (<0)
    D = D_ref[0]

    dA = dt * A
    seg = jnp.cumsum(dA)                                    # [C]
    total = seg[-1]

    # within-chunk quadratic term (the "duality" matmul)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg[:, None] - seg[None, :]), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [C, C]
    W = CB * L * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # contribution of the carried state
    h = h_ref[...]                                          # [P, N]
    y += jnp.exp(seg)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h' = exp(total) h + sum_j decay_j dt_j x_j B_j^T
    wgt = jnp.exp(total - seg) * dt                         # [C]
    h_new = (jnp.exp(total) * h
             + jax.lax.dot_general(x * wgt[:, None], Bm,
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    h_ref[...] = h_new

    y_ref[0, :, 0, :] = (y + D * x).astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _finish():
        hT_ref[0, 0] = h_new.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
        C: jax.Array, D: jax.Array, chunk: int,
        h0: Optional[jax.Array] = None, *, interpret: bool = False):
    """Same contract as ref.ssd_chunked.  x: [B,S,H,P]; dt: [B,S,H];
    A,D: [H]; B_,C: [B,S,G,N]; h0: [B,H,P,N] -> (y [B,S,H,P], hT)."""
    b, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    S0 = S
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)

    grid = (b, H, S // chunk)
    y, hT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, h, ci: (bi, ci, h)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda bi, h, ci, _r=rep: (bi, ci, h // _r, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda bi, h, ci, _r=rep: (bi, ci, h // _r, 0)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), A.astype(jnp.float32), B_, C,
      D.astype(jnp.float32), h0)
    return y[:, :S0], hT
