"""Grouped expert SwiGLU FFN (MoE "grouped matmul") Pallas TPU kernel.

Computes, per expert e:  y_e = (silu(x_e W_g^e) * (x_e W_u^e)) W_d^e
for the capacity-dispatched token buffer x: [E, C, d].

Fusion rationale (vs three separate einsums): the [C, f] gate/up activations
never round-trip to HBM — each f-tile is produced, activated and immediately
contracted into the [C, d] accumulator in VMEM.  HBM traffic drops from
O(C·f·3) intermediates to just the weight streams.

Grid: (experts, token_blocks, f_blocks); f innermost, accumulating into
VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, y_ref, acc_ref):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # [bc, d]
    g = jax.lax.dot_general(x, wg_ref[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu_ref[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)       # [bc, bf]
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(fi == pl.num_programs(2) - 1)
    def _finish():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def moe_gmm(xbuf: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array, *, block_c: int = 128, block_f: int = 256,
            interpret: bool = False) -> jax.Array:
    """xbuf: [E, C, d]; w_gate/w_up: [E, d, f]; w_down: [E, f, d]
    -> [E, C, d]."""
    E, C, d = xbuf.shape
    f = w_gate.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, f)

    padc = (-C) % block_c
    if padc:
        xbuf = jnp.pad(xbuf, ((0, 0), (0, padc), (0, 0)))
    padf = (-f) % block_f
    if padf:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, padf)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, padf)))
        w_down = jnp.pad(w_down, ((0, 0), (0, padf), (0, 0)))
    Cp, fp = xbuf.shape[1], w_gate.shape[2]

    grid = (E, Cp // block_c, fp // block_f)
    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e, ci, fi: (e, ci, 0)),
            pl.BlockSpec((1, d, block_f), lambda e, ci, fi: (e, 0, fi)),
            pl.BlockSpec((1, d, block_f), lambda e, ci, fi: (e, 0, fi)),
            pl.BlockSpec((1, block_f, d), lambda e, ci, fi: (e, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda e, ci, fi: (e, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, d), xbuf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, d), jnp.float32)],
        interpret=interpret,
    )(xbuf, w_gate, w_up, w_down)
    return y[:, :C]
