"""Ring-buffer window-gather Pallas TPU kernel — the device side of the
streaming ingest hot path.

``serving.aggregator.DeviceIngest`` keeps every patient's last ``cap``
samples in one ``[N, C, cap]`` device-resident ring buffer
(``AggState``).  A micro-batch flush needs the last ``L`` samples of
each flushed patient as a dense ``[P, C, L]`` block — oldest first,
left-zero-filled where the window holds fewer than ``L`` valid samples
(sensor dropout / short first windows), all-zero for pow2 batch-padding
rows (``valid == 0``).  This kernel fuses the ring unwrap, the
zero-fill, and the batch padding into ONE gather so no host marshaling
(and no per-member H2D copy) ever touches the flush path.

Grid: ``(P,)`` — one step per flush row.  The patient id is a
data-dependent block index, so ``patients``/``ends``/``valid`` ride in
as scalar-prefetch operands (``PrefetchScalarGridSpec``) and each step
DMAs exactly its patient's ``[C, cap]`` ring stripe into VMEM.  The
ring unwrap is an on-MXU one-hot matmul ``[C, cap] @ [cap, L]`` —
positions ``(end - L + j) mod cap`` are contiguous mod ``cap``, and the
one-hot contraction is bitwise-exact for float32 (exactly one nonzero
term per output lane), which the serving equivalence suite relies on.

``kernels.ref.window_gather`` is the jnp oracle (and the XLA execution
path the CPU-backed serving pipeline uses); this kernel is validated
against it with ``interpret=True`` in ``tests/test_device_ingest.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(pts_ref, ends_ref, val_ref, x_ref, o_ref, *, L: int,
            cap: int):
    i = pl.program_id(0)
    end = ends_ref[i]
    valid = val_ref[i]
    x = x_ref[0]                                        # [C, cap]
    j = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)  # [1, L]
    pos = (end - L + j) % cap                           # [1, L]
    capi = jax.lax.broadcasted_iota(jnp.int32, (cap, L), 0)
    onehot = (capi == pos).astype(x.dtype)              # [cap, L]
    win = jax.lax.dot_general(x, onehot, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    keep = j >= (L - valid)                             # [1, L]
    o_ref[0] = jnp.where(keep, win.astype(o_ref.dtype),
                         jnp.zeros((), o_ref.dtype))


@functools.partial(jax.jit, static_argnames=("L", "interpret"))
def window_gather(buf: jax.Array, patients: jax.Array, ends: jax.Array,
                  valid: jax.Array, L: int, *,
                  interpret: bool = False) -> jax.Array:
    """buf: [N, C, cap] ring; patients/ends/valid: [P] int32.
    Returns [P, C, L], matching ``ref.window_gather`` bitwise."""
    N, C, cap = buf.shape
    P = patients.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, C, cap),
                         lambda i, pts, ends, val: (pts[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, L), lambda i, *_: (i, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, L=L, cap=cap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, C, L), buf.dtype),
        interpret=interpret,
    )(patients.astype(jnp.int32), ends.astype(jnp.int32),
      valid.astype(jnp.int32), buf)
