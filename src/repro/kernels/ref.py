"""Pure-jnp oracles for every Pallas kernel.

These are the reference implementations used (a) as the XLA execution path
for dry-runs/training on CPU and (b) as the ground truth the Pallas kernels
are validated against (interpret=True) in tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_bias(qpos: jax.Array, kpos: jax.Array, causal: bool,
               window: int) -> jax.Array:
    qp = qpos[:, None].astype(jnp.int32)
    kp = kpos[None, :].astype(jnp.int32)
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    if window:
        ok &= (qp - kp) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              qpos: jax.Array, kpos: jax.Array, *,
              causal: bool = True, window: int = 0,
              scale: Optional[float] = None) -> jax.Array:
    """Grouped-query attention oracle.

    q: [B, S, Hq, D]; k, v: [B, T, Hkv, D]; Hkv must divide Hq.
    qpos: [S], kpos: [T] absolute positions (-1 marks empty cache slots).
    Returns [B, S, Hq, D].
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[3]                      # may differ from D (MLA)
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, S, Hkv, g, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits * scale + _mask_bias(qpos, kpos, causal, window)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, Hq, Dv)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kpos: jax.Array, qpos: jax.Array, *,
                     window: int = 0) -> jax.Array:
    """Single-token decode oracle.  q: [B, Hq, D]; k,v: [B, T, Hkv, D];
    kpos: [T]; qpos: scalar position of the query token."""
    out = attention(q[:, None], k, v, jnp.asarray([qpos])
                    if jnp.ndim(qpos) == 0 else qpos[None], kpos,
                    causal=True, window=window)
    return out[:, 0]


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                      qpos: jax.Array, kpos: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      scale: Optional[float] = None,
                      chunk: int = 512, unroll: bool = False) -> jax.Array:
    """Online-softmax attention over KV chunks in pure XLA — the flash-
    attention schedule without Pallas (so it lowers on the 512-device host
    platform).  Never materializes the [S, T] score matrix; HBM traffic
    drops from O(S*T) to O(S*T/chunk-resident) per layer.  §Perf lever B.
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos.astype(jnp.int32), (0, pad),
                       constant_values=-1)
    nc = k.shape[1] // chunk
    qg = q.reshape(B, S, Hkv, g, D)
    kc = k.reshape(B, nc, chunk, Hkv, D)
    vc = v.reshape(B, nc, chunk, Hkv, Dv)
    kpc = kpos.reshape(nc, chunk)
    qp = qpos.astype(jnp.int32)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, kp = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb
                       ).astype(jnp.float32) * scale
        ok = kp[None, :] >= 0
        if causal:
            ok &= kp[None, :] <= qp[:, None]
        if window:
            ok &= qp[:, None] - kp[None, :] < window
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = (acc * alpha[..., None]
               + jnp.einsum("bkgst,btkd->bkgsd", p.astype(vb.dtype), vb))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, g, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, S, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kpc),
        unroll=True if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(B, S, Hq, Dv).astype(q.dtype)


# ------------------------------------------------------------------ SSD
def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
                C: jax.Array, D: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None):
    """Mamba-2 SSD (state-space duality) chunked scan oracle.

    x:  [B, S, H, P]   inputs per head
    dt: [B, S, H]      softplus-ed timestep (>0)
    A:  [H]            negative decay rate per head (A < 0)
    B_: [B, S, G, N]   input gates (G groups broadcast over H)
    C:  [B, S, G, N]   output gates
    D:  [H]            skip
    h0: [B, H, P, N]   initial state (optional)
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    b, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    S0 = S
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> no-op steps
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // chunk
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2)      # [B,S,H,N]
    Ch = jnp.repeat(C, rep, axis=2)

    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = Bh.reshape(b, nc, chunk, H, N)
    Cc = Ch.reshape(b, nc, chunk, H, N)

    dA = dtc * A[None, None, None, :]                   # [b,nc,c,H] (<=0)
    seg = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum
    total = seg[:, :, -1, :]                            # [b,nc,H]

    # within-chunk (quadratic) term: L[i,j] = exp(seg_i - seg_j) for i>=j
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]      # [b,nc,c,c,H]
    ii, jj = jnp.tril_indices(chunk)
    mask = jnp.zeros((chunk, chunk), bool).at[ii, jj].set(True)
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bqchs,bqkhs->bqckh", Cc, Bc)             # [b,nc,c,c,H]
    y_diag = jnp.einsum("bqckh,bqckh,bqkh,bqkhp->bqchp",
                        CB, L.astype(CB.dtype),
                        dtc.astype(CB.dtype), xc)

    # chunk input states: contribution of each chunk to its end-state
    decay_to_end = jnp.exp(total[:, :, None, :] - seg)        # [b,nc,c,H]
    states = jnp.einsum("bqchs,bqch,bqch,bqchp->bqhps",
                        Bc, decay_to_end.astype(Bc.dtype),
                        dtc.astype(Bc.dtype), xc
                        ).astype(jnp.float32)                 # [b,nc,H,P,N]

    # inter-chunk recurrence over nc chunk states (f32 carry)
    def step(h, inp):
        st, tot = inp                                          # [b,H,P,N], [b,H]
        h_new = h * jnp.exp(tot)[:, :, None, None] + st
        return h_new, h
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)
    hT, h_prev = jax.lax.scan(step,
                              h0.astype(jnp.float32),
                              (jnp.moveaxis(states, 1, 0),
                               jnp.moveaxis(total, 1, 0).astype(
                                   jnp.float32)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                        # [b,nc,H,P,N]

    # output from carried state
    decay_from_start = jnp.exp(seg)                            # [b,nc,c,H]
    y_off = jnp.einsum("bqchs,bqch,bqhps->bqchp",
                       Cc.astype(jnp.float32),
                       decay_from_start.astype(jnp.float32), h_prev)
    y = ((y_diag.astype(jnp.float32) + y_off).astype(x.dtype)
         ).reshape(b, S, H, P) + x * D[None, None, :, None].astype(x.dtype)
    return y[:, :S0], hT


def ssd_decode_step(h: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                    B_: jax.Array, C: jax.Array, D: jax.Array):
    """One recurrent SSD step.  h: [B,H,P,N]; x: [B,H,P]; dt: [B,H];
    B_, C: [B,G,N].  Returns (y [B,H,P], h_new)."""
    H, G = x.shape[1], B_.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=1)
    Ch = jnp.repeat(C, rep, axis=1)
    dA = jnp.exp(dt * A[None, :])[:, :, None, None]
    h_new = h * dA + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, x)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h_new) + x * D[None, :, None]
    return y, h_new


# ------------------------------------------------------------- MoE GMM
def moe_gmm(xbuf: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array) -> jax.Array:
    """Grouped expert SwiGLU oracle.  xbuf: [E, C, d] (capacity-dispatched
    tokens); weights: [E, d, f], [E, d, f], [E, f, d].  Returns [E, C, d]."""
    gate = jnp.einsum("ecd,edf->ecf", xbuf, w_gate)
    up = jnp.einsum("ecd,edf->ecf", xbuf, w_up)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, w_down)


# ------------------------------------------------------- window gather
def window_gather(buf: jax.Array, patients: jax.Array, ends: jax.Array,
                  valid: jax.Array, L: int) -> jax.Array:
    """Ring-buffer window gather oracle (the serving ingest hot path).

    ``buf`` is a multi-patient ring buffer ``[N, C, cap]`` (see
    ``serving.aggregator.AggState``).  For each flush row ``i`` the last
    ``L`` samples ending at ring position ``ends[i]`` (exclusive; any
    integer — reduced mod ``cap``) are gathered for patient
    ``patients[i]``, and positions older than ``valid[i]`` samples are
    zeroed — fusing the aggregator's left-zero-fill (sensor dropout /
    short windows) and the batch-row padding (``valid == 0`` rows come
    back all-zero) into the gather itself.

    Returns ``[P, C, L]``, oldest sample first.
    """
    cap = buf.shape[-1]
    j = jnp.arange(L)
    pos = (ends[:, None] - L + j[None, :]) % cap               # [P, L]
    win = buf[patients[:, None, None],
              jnp.arange(buf.shape[1])[None, :, None],
              pos[:, None, :]]                                 # [P, C, L]
    mask = j[None, None, :] >= (L - valid)[:, None, None]
    return jnp.where(mask, win, jnp.zeros((), buf.dtype))


# -------------------------------------------------------- conv1d stripe
def conv1d_stripe(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                  stride: int = 1, groups: int = 1,
                  padding: str = "SAME") -> jax.Array:
    """Grouped 1-D convolution oracle (the ResNeXt "stripe" conv and the
    Mamba short conv both lower to this).

    x: [B, L, Cin]; w: [K, Cin//groups, Cout]; padding 'SAME' or 'CAUSAL'.
    Returns [B, L_out, Cout]."""
    K = w.shape[0]
    pad = [(K - 1, 0)] if padding == "CAUSAL" else padding
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding=pad,
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=groups)
    if b is not None:
        y = y + b
    return y
