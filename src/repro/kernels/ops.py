"""Dispatching wrappers over the Pallas kernels and their jnp oracles.

``impl`` selects the execution path:
  * "xla"              — pure-jnp oracle (ref.py).  Default; used by the
                         512-device dry-run (Pallas cannot lower to the
                         host-platform placeholder devices) and CPU tests.
  * "pallas"           — the TPU kernel, compiled.
  * "pallas_interpret" — the TPU kernel body executed in Python on CPU;
                         used by the kernel-vs-oracle tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_IMPLS = ("xla", "pallas", "pallas_interpret")


def _check(impl: str) -> None:
    if impl not in _IMPLS:
        raise ValueError(f"impl={impl!r} not in {_IMPLS}")


def attention(q, k, v, qpos, kpos, *, causal: bool = True, window: int = 0,
              scale: Optional[float] = None, impl: str = "xla",
              chunk: int = 0, unroll: bool = False):
    _check(impl)
    if impl == "xla":
        if chunk:
            return ref.attention_chunked(q, k, v, qpos, kpos,
                                         causal=causal, window=window,
                                         scale=scale, chunk=chunk,
                                         unroll=unroll)
        return ref.attention(q, k, v, qpos, kpos, causal=causal,
                             window=window, scale=scale)
    from repro.kernels import flash_attention
    return flash_attention.flash_attention(
        q, k, v, qpos, kpos, causal=causal, window=window, scale=scale,
        interpret=(impl == "pallas_interpret"))


def decode_attention(q, k, v, kpos, qpos, *, window: int = 0,
                     impl: str = "xla"):
    _check(impl)
    if impl == "xla":
        return ref.decode_attention(q, k, v, kpos, qpos, window=window)
    from repro.kernels import decode_attention as da
    return da.decode_attention(q, k, v, kpos, qpos, window=window,
                               interpret=(impl == "pallas_interpret"))


def ssd(x, dt, A, B_, C, D, chunk: int, h0=None, *, impl: str = "xla"):
    _check(impl)
    if impl == "xla":
        return ref.ssd_chunked(x, dt, A, B_, C, D, chunk, h0)
    from repro.kernels import ssd_scan
    return ssd_scan.ssd(x, dt, A, B_, C, D, chunk, h0,
                        interpret=(impl == "pallas_interpret"))


def moe_gmm(xbuf, w_gate, w_up, w_down, *, impl: str = "xla"):
    _check(impl)
    if impl == "xla":
        return ref.moe_gmm(xbuf, w_gate, w_up, w_down)
    from repro.kernels import moe_gmm as gmm
    return gmm.moe_gmm(xbuf, w_gate, w_up, w_down,
                       interpret=(impl == "pallas_interpret"))


def conv1d(x, w, b=None, stride: int = 1, groups: int = 1,
           padding: str = "SAME", *, impl: str = "xla"):
    """x: [B, L, Cin] (per-member) or [M, B, L, Cin] (member-stacked
    ensemble bucket; w gains the same leading M axis, b becomes [M, Cout]).
    The stacked form keeps bucketed serving inside the custom kernel
    (grid (member, batch, groups)) instead of vmap-ping the 3-D op."""
    _check(impl)
    if x.ndim == 4:                               # member-stacked bucket
        if impl == "xla":
            y = jax.vmap(
                lambda xm, wm: ref.conv1d_stripe(xm, wm, None, stride,
                                                 groups, padding))(x, w)
            return y if b is None else y + b[:, None, None, :]
        from repro.kernels import conv1d_stripe
        return conv1d_stripe.conv1d_stripe_stacked(
            x, w, b, stride, groups, padding,
            interpret=(impl == "pallas_interpret"))
    if impl == "xla":
        return ref.conv1d_stripe(x, w, b, stride, groups, padding)
    from repro.kernels import conv1d_stripe
    return conv1d_stripe.conv1d_stripe(
        x, w, b, stride, groups, padding,
        interpret=(impl == "pallas_interpret"))
