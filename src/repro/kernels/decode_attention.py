"""Single-token decode attention Pallas TPU kernel.

One query token per sequence against a (possibly ring-buffered) KV cache.
All query heads of one KV group are processed together so the MXU sees a
[group, D] x [D, block_k] matmul instead of vector-matrix products.

Grid: (batch, kv_heads, k_blocks); k_blocks innermost, accumulating the
online softmax into VMEM scratch.  Cache validity comes from kpos (-1 =
empty slot), so partially-filled and ring caches need no special cases.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, window: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kp = kpos_ref[...].astype(jnp.int32)             # [block_k]
    qp = qpos_ref[0]

    ok = (kp >= 0) & (kp <= qp)
    if window:
        ok &= qp - kp < window

    @pl.when(jnp.any(ok))
    def _compute():
        q = q_ref[0, 0, :, :]                        # [g, D]
        k = k_ref[0, :, 0, :]                        # [block_k, D]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [g, block_k]
        s = jnp.where(ok[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_cur

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kpos: jax.Array, qpos: jax.Array, *,
                     window: int = 0, scale: Optional[float] = None,
                     block_k: int = 256,
                     interpret: bool = False) -> jax.Array:
    """q: [B,Hq,D]; k,v: [B,T,Hkv,D]; kpos: [T]; qpos: scalar -> [B,Hq,D]."""
    B, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = float(scale if scale is not None else D ** -0.5)
    block_k = min(block_k, max(T, 8))

    pad = (-T) % block_k
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
        kpos = jnp.pad(kpos.astype(jnp.int32), (0, pad), constant_values=-1)
    Tp = k.shape[1]
    qpos_arr = jnp.reshape(qpos, (1,)).astype(jnp.int32)
    qg = q.reshape(B, Hkv, g, D)

    grid = (B, Hkv, Tp // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (0,)),
            pl.BlockSpec((block_k,), lambda b, h, ki: (ki,)),
            pl.BlockSpec((1, 1, g, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_arr, kpos, qg, k, v)
    return out.reshape(B, Hq, D)
