"""Runtime (lowering-time) options, orthogonal to the architecture config."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RuntimeOptions:
    """Options chosen at jit/lower time, not part of the architecture.

    kv_mult:        duplicate KV heads by this factor so the model axis
                    divides them (DESIGN.md §5); numerics-invariant.
    impl:           kernel dispatch ("xla" | "pallas" | "pallas_interpret").
    remat:          activation checkpointing on the layer scan (train).
    window:         attention-window override; 0 keeps cfg.sliding_window.
                    long_500k sets this to cfg.long_context_window for
                    attention archs.
    absorbed_mla:   latent-space MLA attention (decode memory optimization).
    capacity_factor: MoE dispatch capacity factor.
    param_dtype / compute via dtype.
    """
    kv_mult: int = 1
    impl: str = "xla"
    remat: bool = False
    window: int = 0
    absorbed_mla: bool = False
    capacity_factor: float = 1.25
    dtype: object = jnp.float32
    # Unroll layer scans in the lowered HLO.  Needed by the roofline probes:
    # XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    # count, so accurate FLOP/byte/collective numbers require unrolling
    # (done on reduced-layer clones, then extrapolated — launch/roofline.py).
    scan_unroll: bool = False
    # ---- §Perf levers (beyond-paper optimizations) ----
    # moe_impl "shard_map": explicit collective schedule — dispatch stays
    # shard-local, ONE token-space all-reduce per MoE layer (vs GSPMD's
    # capacity-space all-reduce/all-gather storm).  Requires `mesh`.
    moe_impl: str = "gspmd"            # gspmd | shard_map
    mesh: object = None                # jax Mesh (lowering-time only)
    # attention chunking: online-softmax over KV blocks in pure XLA — the
    # flash-attention insight without Pallas, so it lowers on the host
    # platform.  0 = disabled (materialize [S,T] scores).
    attn_chunk: int = 0

    def eff_window(self, cfg) -> int:
        return self.window or cfg.sliding_window
