"""Attention variants: GQA/MQA (with KV-head duplication for sharding),
sliding-window masking, ring-buffer decode caches, MLA (DeepSeek-V2),
and encoder/decoder cross-attention.

Cache convention (per layer; the transformer scans these stacked over L):
  gqa:  {"k": [B, M, kvH, hd], "v": [B, M, kvH, hd]}
  mla:  {"ckv": [B, M, lora], "krope": [B, M, rope_dim]}
plus a model-level {"pos": [M] int32 (-1 = empty), "idx": int32 scalar}.
M = min(seq_len, window or seq_len); decode writes slot idx % M.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.layers import (apply_rope, init_linear, init_rmsnorm,
                                 linear, rms_norm, truncated_normal_init)


# ===================================================================== GQA
def init_gqa(key, cfg: ArchConfig, dtype=jnp.float32, kv_mult: int = 1):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads * kv_mult
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, nq * hd, dtype, cfg.attn_bias),
        "wk": init_linear(ks[1], d, nkv * hd, dtype, cfg.attn_bias),
        "wv": init_linear(ks[2], d, nkv * hd, dtype, cfg.attn_bias),
        "wo": init_linear(ks[3], nq * hd, d, dtype, cfg.attn_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _project_qkv(p, x, cfg: ArchConfig, kv_mult: int):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads * kv_mult, hd)
    v = linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads * kv_mult, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_apply(p, x: jax.Array, positions: jax.Array, cfg: ArchConfig, *,
              cache: Optional[dict] = None,
              cache_pos: Optional[jax.Array] = None,
              cache_idx: Optional[jax.Array] = None,
              window: int = 0, causal: bool = True,
              kv_mult: int = 1, impl: str = "xla",
              chunk: int = 0, unroll: bool = False
              ) -> Tuple[jax.Array, Optional[dict]]:
    """positions: [S] int32 absolute positions of the inputs.

    * cache=None: full-sequence attention (train/prefill); returns
      (out, {"k","v"}) with M=S so the caller may build a cache.
    * cache given: decode — S==1; writes slot cache_idx % M, attends to the
      whole buffer using cache_pos validity.
    """
    q, k, v = _project_qkv(p, x, cfg, kv_mult)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = ops.attention(q, k, v, positions, positions,
                            causal=causal, window=window, impl=impl,
                            chunk=chunk, unroll=unroll)
        new_kv = {"k": k, "v": v}
    else:
        M = cache["k"].shape[1]
        slot = cache_idx % M
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        kpos = jax.lax.dynamic_update_slice_in_dim(
            cache_pos, positions.astype(cache_pos.dtype), slot, axis=0)
        out = ops.attention(q, ck, cv, positions, kpos,
                            causal=causal, window=window, impl=impl,
                            chunk=chunk, unroll=unroll)
        new_kv = {"k": ck, "v": cv}
    B, S = x.shape[:2]
    out = linear(p["wo"], out.reshape(B, S, cfg.n_heads * cfg.head_dim))
    return out, new_kv


# ===================================================================== MLA
def init_mla(key, cfg: ArchConfig, dtype=jnp.float32):
    m = cfg.mla
    d, nq = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": init_linear(ks[0], d, nq * qk_dim, dtype),
        "w_dkv": init_linear(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim,
                             dtype),
        "ckv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        "w_uk": truncated_normal_init(
            ks[2], (m.kv_lora_rank, nq, m.qk_nope_head_dim), 1.0, dtype),
        "w_uv": truncated_normal_init(
            ks[3], (m.kv_lora_rank, nq, m.v_head_dim), 1.0, dtype),
        "wo": init_linear(ks[4], nq * m.v_head_dim, d, dtype),
    }


def _mla_compress(p, x, cfg: ArchConfig, positions):
    """x -> (q_nope, q_rope, ckv, k_rope) for this segment."""
    m = cfg.mla
    B, S, _ = x.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, qk_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    dkv = linear(p["w_dkv"], x)
    ckv = rms_norm(dkv[..., :m.kv_lora_rank], p["ckv_norm"], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:][:, :, None, :]       # [B,S,1,rope]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_apply(p, x: jax.Array, positions: jax.Array, cfg: ArchConfig, *,
              cache: Optional[dict] = None,
              cache_pos: Optional[jax.Array] = None,
              cache_idx: Optional[jax.Array] = None,
              window: int = 0, causal: bool = True,
              absorbed: bool = False, impl: str = "xla",
              chunk: int = 0, unroll: bool = False
              ) -> Tuple[jax.Array, Optional[dict]]:
    """Multi-head Latent Attention.  Cache holds the COMPRESSED kv
    (kv_lora_rank + rope_dim per token, shared across heads).

    absorbed=False materializes per-head K/V from the latent (simple);
    absorbed=True runs attention in the latent space (the memory-optimal
    decode path — see EXPERIMENTS.md §Perf).
    """
    m = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope, ckv, k_rope = _mla_compress(p, x, cfg, positions)

    if cache is None:
        ckv_all, krope_all, kpos = ckv, k_rope, positions
        new_cache = {"ckv": ckv, "krope": k_rope}
    else:
        M = cache["ckv"].shape[1]
        slot = cache_idx % M
        ckv_all = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv, slot, axis=1)
        krope_all = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope, slot, axis=1)
        kpos = jax.lax.dynamic_update_slice_in_dim(
            cache_pos, positions.astype(cache_pos.dtype), slot, axis=0)
        new_cache = {"ckv": ckv_all, "krope": krope_all}

    scale = 1.0 / (m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5
    if absorbed:
        # q~ = q_nope absorbed through w_uk: [B,S,H,lora]
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"])
        logits = (jnp.einsum("bshl,btl->bhst", q_lat, ckv_all)
                  + jnp.einsum("bshr,btr->bhst", q_rope, krope_all)) * scale
        logits = logits + _mask_bias(positions, kpos, causal, window)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1
                               ).astype(ckv_all.dtype)
        v_lat = jnp.einsum("bhst,btl->bshl", probs, ckv_all)
        out = jnp.einsum("bshl,lhv->bshv", v_lat, p["w_uv"]
                         ).astype(x.dtype)
    else:
        k_nope = jnp.einsum("btl,lhn->bthn", ckv_all, p["w_uk"])
        v = jnp.einsum("btl,lhv->bthv", ckv_all, p["w_uv"])
        k_rope_b = jnp.broadcast_to(
            krope_all[:, :, None, :],
            (B, ckv_all.shape[1], cfg.n_heads, m.qk_rope_head_dim))
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = ops.attention(q, k, v, positions, kpos,
                            causal=causal, window=window, impl=impl,
                            chunk=chunk, unroll=unroll)
    out = linear(p["wo"], out.reshape(B, S, cfg.n_heads * m.v_head_dim))
    return out, new_cache


def _mask_bias(qpos, kpos, causal: bool, window: int):
    """Additive [S,T] mask bias from 1-D position vectors."""
    qp = qpos[:, None].astype(jnp.int32)
    kp = kpos[None, :].astype(jnp.int32)
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    if window:
        ok &= (qp - kp) < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


# ============================================================ cross-attn
def init_cross(key, cfg: ArchConfig, dtype=jnp.float32, kv_mult: int = 1):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads * kv_mult
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, nq * hd, dtype),
        "wk": init_linear(ks[1], d, nkv * hd, dtype),
        "wv": init_linear(ks[2], d, nkv * hd, dtype),
        "wo": init_linear(ks[3], nq * hd, d, dtype),
    }


def cross_apply(p, x: jax.Array, enc: jax.Array, cfg: ArchConfig, *,
                kv_mult: int = 1, impl: str = "xla") -> jax.Array:
    """Decoder cross-attention over encoder output (no mask, no rope)."""
    B, S, _ = x.shape
    T = enc.shape[1]
    hd = cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = linear(p["wk"], enc).reshape(B, T, cfg.n_kv_heads * kv_mult, hd)
    v = linear(p["wv"], enc).reshape(B, T, cfg.n_kv_heads * kv_mult, hd)
    qpos = jnp.zeros((S,), jnp.int32)
    kpos = jnp.zeros((T,), jnp.int32)
    out = ops.attention(q, k, v, qpos, kpos, causal=False, window=0,
                        impl=impl)
    return linear(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
