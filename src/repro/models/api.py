"""Family-dispatching model API.

    model = get_model(cfg)
    params = model.init(key, cfg, rt)
    logits, aux = model.forward(params, tokens, cfg, rt, prefix_embeds=None)
    logits, cache = model.prefill(...)
    logits, cache = model.decode_step(params, cache, token, cfg, rt)
    cache = model.init_cache(cfg, rt, batch, seq_len)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.configs.base import ArchConfig
from repro.models import encdec, hybrid, transformer


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    forward: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


_TRANSFORMER = ModelApi(
    init=transformer.init_lm,
    forward=transformer.forward,
    prefill=transformer.prefill,
    decode_step=transformer.decode_step,
    init_cache=transformer.init_cache,
)

_HYBRID = ModelApi(
    init=hybrid.init_hybrid,
    forward=hybrid.forward,
    prefill=hybrid.prefill,
    decode_step=hybrid.decode_step,
    init_cache=hybrid.init_cache,
)

_ENCDEC = ModelApi(
    init=encdec.init_encdec,
    forward=encdec.forward,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
    init_cache=encdec.init_cache,
)


def get_model(cfg: ArchConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "vlm", "ssm"):
        return _TRANSFORMER
    if cfg.family == "hybrid":
        return _HYBRID
    if cfg.family == "encdec":
        return _ENCDEC
    raise ValueError(f"unknown family {cfg.family!r}")
