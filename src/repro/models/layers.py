"""Shared layer primitives: norms, linears, rotary embeddings, SwiGLU MLP.

Parameters are plain pytrees (nested dicts of jnp arrays).  Each primitive
exposes ``init_*`` (returns params), an apply function, and the sharding
spec builders live in ``repro.launch.sharding`` (they mirror these pytrees).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale: float, dtype) -> jax.Array:
    stddev = scale / max(1.0, (shape[0] if shape else 1)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


# ---------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x: jax.Array, p, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- linear
def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32,
                bias: bool = False):
    p = {"w": truncated_normal_init(key, (d_in, d_out), 1.0, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------- rope
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Half-rotation RoPE.  x: [..., seq, heads, head_dim]; positions
    broadcastable to x.shape[:-2] (usually [batch, seq] or [seq])."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freqs  # [..,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp
def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32,
                bias: bool = False):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype, bias),
        "up": init_linear(k2, d_model, d_ff, dtype, bias),
        "down": init_linear(k3, d_ff, d_model, dtype, bias),
    }


def swiglu(p, x: jax.Array) -> jax.Array:
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) *
                  linear(p["up"], x))


# ---------------------------------------------------------------- embed
def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32,
                   tied: bool = False):
    k1, k2 = jax.random.split(key)
    p = {"table": truncated_normal_init(k1, (vocab, d_model), 1.0, dtype)}
    if not tied:
        p["head"] = truncated_normal_init(k2, (d_model, vocab), 1.0, dtype)
    return p


def embed(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x: jax.Array) -> jax.Array:
    if "head" in p:
        return x @ p["head"]
    return x @ p["table"].T


# ---------------------------------------------------------------- loss
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 vocab_size: Optional[int] = None) -> jax.Array:
    """Mean token cross-entropy; labels < 0 are masked out (and padded
    vocab ids can never be labels)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
