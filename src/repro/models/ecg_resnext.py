"""1-D "stripe" ResNeXt ECG classifiers — the paper's model-zoo family.

§4.1.1: "a state-of-art convolutional neural network, by modifying the
kernel in the convolutional layer in ResNeXt from 2-D patch to 1-D stripe,
individually for each single lead ECG clip", varying first-layer filters
{8,16,32,64,128} and residual blocks {2,4,8,16}.

Deviation (DESIGN.md §2): BatchNorm is replaced with GroupNorm so the model
is stateless (no running statistics) — simpler to serve and numerically
equivalent for our synthetic task.

x: [B, L, 1] single-lead clip  ->  logits [B, 2] (critical / stable).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.ecg_zoo import EcgModelSpec
from repro.kernels import ops
from repro.models.layers import truncated_normal_init


def _init_conv(key, k: int, cin: int, cout: int, groups: int = 1,
               dtype=jnp.float32):
    return {"w": truncated_normal_init(key, (k, cin // groups, cout),
                                       1.0, dtype),
            "b": jnp.zeros((cout,), dtype)}


def _init_gn(c: int, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _group_norm(p, x: jax.Array, groups: int = 4,
                eps: float = 1e-5) -> jax.Array:
    B, L, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, L, g, C // g)
    mu = jnp.mean(xg, axis=(1, 3), keepdims=True)
    var = jnp.var(xg, axis=(1, 3), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(B, L, C) * p["scale"] + p["bias"]


def init_ecg(key, spec: EcgModelSpec, dtype=jnp.float32) -> Dict:
    W = spec.width
    keys = jax.random.split(key, 3 * spec.blocks + 3)
    params = {
        "stem": _init_conv(keys[0], spec.kernel_size, 1, W, dtype=dtype),
        "stem_gn": _init_gn(W, dtype),
        "blocks": [],
        "head": {"w": truncated_normal_init(keys[1], (W, 2), 1.0, dtype),
                 "b": jnp.zeros((2,), dtype)},
    }
    card = spec.cardinality
    for i in range(spec.blocks):
        k0, k1, k2 = keys[2 + 3 * i: 5 + 3 * i]
        inner = max(card, W // 2)
        inner -= inner % card
        params["blocks"].append({
            "reduce": _init_conv(k0, 1, W, inner, dtype=dtype),
            "gn1": _init_gn(inner, dtype),
            "stripe": _init_conv(k1, spec.kernel_size, inner, inner,
                                 groups=card, dtype=dtype),
            "gn2": _init_gn(inner, dtype),
            "expand": _init_conv(k2, 1, inner, W, dtype=dtype),
            "gn3": _init_gn(W, dtype),
        })
    return params


def ecg_apply(params: Dict, x: jax.Array, spec: EcgModelSpec,
              impl: str = "xla") -> jax.Array:
    """x: [B, L, 1] -> logits [B, 2]."""
    h = ops.conv1d(x, params["stem"]["w"], params["stem"]["b"], stride=2,
                   impl=impl)
    h = jax.nn.relu(_group_norm(params["stem_gn"], h))
    card = spec.cardinality
    for i, blk in enumerate(params["blocks"]):
        stride = 2 if i % 2 == 0 else 1
        r = ops.conv1d(h, blk["reduce"]["w"], blk["reduce"]["b"], impl=impl)
        r = jax.nn.relu(_group_norm(blk["gn1"], r))
        r = ops.conv1d(r, blk["stripe"]["w"], blk["stripe"]["b"],
                       stride=stride, groups=card, impl=impl)
        r = jax.nn.relu(_group_norm(blk["gn2"], r))
        r = ops.conv1d(r, blk["expand"]["w"], blk["expand"]["b"], impl=impl)
        r = _group_norm(blk["gn3"], r)
        shortcut = h[:, ::stride] if stride > 1 else h
        h = jax.nn.relu(shortcut[:, :r.shape[1]] + r)
    pooled = jnp.mean(h, axis=1)                       # [B, W]
    return pooled @ params["head"]["w"] + params["head"]["b"]


def ecg_apply_stacked(params: Dict, x: jax.Array, spec: EcgModelSpec,
                      impl: str = "xla") -> jax.Array:
    """Fused forward pass over a whole architecture bucket of stacked
    members (see configs.ecg_zoo.bucket_zoo): ``params`` is the
    ``stack_members`` pytree (leading member axis M), ``x`` is
    ``[M, B, L, 1]`` — member-specific lead slices over a shared
    micro-batch of B windows.  Returns logits ``[M, B, 2]``.

    One jitted call replaces M per-member dispatches; the convs run
    through the member-axis ``conv1d_stripe_stacked`` kernel when
    ``impl`` selects Pallas, so the stacked path never falls back to
    per-member XLA loops.  Numerics match ``ecg_apply`` per member to
    float tolerance.
    """
    gn = jax.vmap(_group_norm)
    h = ops.conv1d(x, params["stem"]["w"], params["stem"]["b"], stride=2,
                   impl=impl)
    h = jax.nn.relu(gn(params["stem_gn"], h))
    card = spec.cardinality
    for i, blk in enumerate(params["blocks"]):
        stride = 2 if i % 2 == 0 else 1
        r = ops.conv1d(h, blk["reduce"]["w"], blk["reduce"]["b"], impl=impl)
        r = jax.nn.relu(gn(blk["gn1"], r))
        r = ops.conv1d(r, blk["stripe"]["w"], blk["stripe"]["b"],
                       stride=stride, groups=card, impl=impl)
        r = jax.nn.relu(gn(blk["gn2"], r))
        r = ops.conv1d(r, blk["expand"]["w"], blk["expand"]["b"], impl=impl)
        r = gn(blk["gn3"], r)
        shortcut = h[:, :, ::stride] if stride > 1 else h
        h = jax.nn.relu(shortcut[:, :, :r.shape[2]] + r)
    pooled = jnp.mean(h, axis=2)                       # [M, B, W]
    return (jnp.einsum("mbw,mwc->mbc", pooled, params["head"]["w"])
            + params["head"]["b"][:, None, :])


def ecg_macs(spec: EcgModelSpec) -> float:
    """Analytic multiply-accumulate count (the MACS field of the paper's
    Table-3 model profile)."""
    L = spec.input_len / 2                              # after stem stride
    W, K, card = spec.width, spec.kernel_size, spec.cardinality
    macs = spec.input_len / 2 * K * W                   # stem
    for i in range(spec.blocks):
        stride = 2 if i % 2 == 0 else 1
        inner = max(card, W // 2)
        inner -= inner % card
        macs += L * W * inner                           # reduce 1x1
        L = L / stride
        macs += L * K * inner * inner / card            # grouped stripe
        macs += L * inner * W                           # expand 1x1
    macs += W * 2
    return float(macs)


def ecg_param_count(params: Dict) -> int:
    return sum(a.size for a in jax.tree.leaves(params))
