"""Mamba-2 block (SSD — state-space duality) and the pure-SSM language model.

Block layout follows arXiv:2405.21060 with one sharding-driven deviation
(DESIGN.md §5): the fused in_proj is stored as SEPARATE projections
(z, x, B, C, dt) so each output dim shards cleanly over the model axis —
a fused projection's post-split slices would cross shard boundaries and
force resharding collectives.  Numerics are identical.

Decode keeps {conv_x, conv_B, conv_C, ssm} states; no KV cache, O(1)
memory in sequence length (why SSM/hybrid archs run long_500k natively).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops, ref
from repro.models.layers import (init_rmsnorm, rms_norm,
                                 truncated_normal_init)


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_heads(d)
    G, N, K = s.n_groups, s.d_state, s.conv_width
    gn = G * N
    ks = jax.random.split(key, 9)
    return {
        "z_proj": truncated_normal_init(ks[0], (d, di), 1.0, dtype),
        "x_proj": truncated_normal_init(ks[1], (d, di), 1.0, dtype),
        "B_proj": truncated_normal_init(ks[2], (d, gn), 1.0, dtype),
        "C_proj": truncated_normal_init(ks[3], (d, gn), 1.0, dtype),
        "dt_proj": truncated_normal_init(ks[4], (d, H), 1.0, dtype),
        "conv_x": truncated_normal_init(ks[5], (K, 1, di), 1.0, dtype),
        "conv_B": truncated_normal_init(ks[6], (K, 1, gn), 1.0, dtype),
        "conv_C": truncated_normal_init(ks[7], (K, 1, gn), 1.0, dtype),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_bB": jnp.zeros((gn,), dtype),
        "conv_bC": jnp.zeros((gn,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),           # A = -exp(A_log)=-1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": truncated_normal_init(ks[8], (di, d), 1.0, dtype),
    }


def ssm_cache_init(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    gn = s.n_groups * s.d_state
    K = s.conv_width
    return {
        "conv_x": jnp.zeros((batch, K - 1, di), dtype),
        "conv_B": jnp.zeros((batch, K - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, K - 1, gn), dtype),
        "ssm": jnp.zeros((batch, s.n_heads(d), s.head_dim, s.d_state),
                         jnp.float32),
    }


def _conv_step(hist: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """hist: [B, K, ch] -> causal conv output at the last step [B, ch]."""
    return jnp.einsum("bkc,kc->bc", hist, w[:, 0, :]) + b


def mamba2_apply(p, u: jax.Array, cfg: ArchConfig, *,
                 cache: Optional[dict] = None,
                 return_cache: bool = False,
                 impl: str = "xla") -> Tuple[jax.Array, Optional[dict]]:
    """u: [B, S, d].  cache given (decode) requires S == 1.
    return_cache=True on the full-sequence path emits the post-prefill
    conv/ssm state."""
    s = cfg.ssm
    B, S, d = u.shape
    di = s.d_inner(d)
    H, P, G, N, K = s.n_heads(d), s.head_dim, s.n_groups, s.d_state, \
        s.conv_width

    z = u @ p["z_proj"]
    x_raw = u @ p["x_proj"]
    B_raw = u @ p["B_proj"]
    C_raw = u @ p["C_proj"]
    dt = jax.nn.softplus((u @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cache is None:
        xc = jax.nn.silu(ops.conv1d(x_raw, p["conv_x"], p["conv_bx"],
                                    groups=di, padding="CAUSAL", impl=impl))
        Bc = jax.nn.silu(ops.conv1d(B_raw, p["conv_B"], p["conv_bB"],
                                    groups=G * N, padding="CAUSAL",
                                    impl=impl))
        Cc = jax.nn.silu(ops.conv1d(C_raw, p["conv_C"], p["conv_bC"],
                                    groups=G * N, padding="CAUSAL",
                                    impl=impl))
        x = xc.reshape(B, S, H, P)
        Bmat = Bc.reshape(B, S, G, N)
        Cmat = Cc.reshape(B, S, G, N)
        y, hT = ops.ssd(x, dt, A, Bmat, Cmat, p["D"], s.chunk, impl=impl)
        new_cache = None
        if return_cache:
            new_cache = {"conv_x": x_raw[:, S - (K - 1):, :],
                         "conv_B": B_raw[:, S - (K - 1):, :],
                         "conv_C": C_raw[:, S - (K - 1):, :],
                         "ssm": hT.astype(jnp.float32)}
        y = y.reshape(B, S, di)
    else:
        hx = jnp.concatenate([cache["conv_x"], x_raw], axis=1)
        hB = jnp.concatenate([cache["conv_B"], B_raw], axis=1)
        hC = jnp.concatenate([cache["conv_C"], C_raw], axis=1)
        x = jax.nn.silu(_conv_step(hx, p["conv_x"], p["conv_bx"]))
        Bm = jax.nn.silu(_conv_step(hB, p["conv_B"], p["conv_bB"]))
        Cm = jax.nn.silu(_conv_step(hC, p["conv_C"], p["conv_bC"]))
        y, h_new = ref.ssd_decode_step(
            cache["ssm"], x.astype(jnp.float32).reshape(B, H, P), dt[:, 0],
            A, Bm.astype(jnp.float32).reshape(B, G, N),
            Cm.astype(jnp.float32).reshape(B, G, N), p["D"])
        new_cache = {"conv_x": hx[:, 1:], "conv_B": hB[:, 1:],
                     "conv_C": hC[:, 1:], "ssm": h_new}
        y = y.astype(u.dtype).reshape(B, 1, di)

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_cache
