"""Seamless-M4T-style encoder-decoder backbone (audio -> text).

The modality frontend is a STUB per the assignment: the encoder consumes
precomputed audio frame embeddings [B, T_a, frontend_dim].  The decoder is
a causal transformer with self-attention (cached at decode time) and
cross-attention over the encoder output (cross K/V precomputed into the
cache at prefill).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (embed, init_embedding, init_linear,
                                 init_rmsnorm, init_swiglu, linear, rms_norm,
                                 swiglu, unembed)
from repro.models.runtime import RuntimeOptions


def init_encdec(key, cfg: ArchConfig, rt: RuntimeOptions):
    keys = jax.random.split(key, 5)

    def enc_block(kk):
        k1, k2 = jax.random.split(kk)
        return {"ln1": init_rmsnorm(cfg.d_model, rt.dtype),
                "attn": attn.init_gqa(k1, cfg, rt.dtype, rt.kv_mult),
                "ln2": init_rmsnorm(cfg.d_model, rt.dtype),
                "mlp": init_swiglu(k2, cfg.d_model, cfg.d_ff, rt.dtype)}

    def dec_block(kk):
        k1, k2, k3 = jax.random.split(kk, 3)
        return {"ln1": init_rmsnorm(cfg.d_model, rt.dtype),
                "self": attn.init_gqa(k1, cfg, rt.dtype, rt.kv_mult),
                "ln_x": init_rmsnorm(cfg.d_model, rt.dtype),
                "cross": attn.init_cross(k2, cfg, rt.dtype, rt.kv_mult),
                "ln2": init_rmsnorm(cfg.d_model, rt.dtype),
                "mlp": init_swiglu(k3, cfg.d_model, cfg.d_ff, rt.dtype)}

    return {
        "frontend_proj": init_linear(keys[0], cfg.frontend_dim, cfg.d_model,
                                     rt.dtype),
        "embed": init_embedding(keys[1], cfg.padded_vocab, cfg.d_model,
                                rt.dtype, tied=cfg.tie_embeddings),
        "enc": jax.vmap(enc_block)(jax.random.split(keys[2],
                                                    cfg.enc_layers)),
        "dec": jax.vmap(dec_block)(jax.random.split(keys[3],
                                                    cfg.dec_layers)),
        "enc_norm": init_rmsnorm(cfg.d_model, rt.dtype),
        "final_norm": init_rmsnorm(cfg.d_model, rt.dtype),
    }


def encode(params, audio_embeds: jax.Array, cfg: ArchConfig,
           rt: RuntimeOptions) -> jax.Array:
    x = linear(params["frontend_proj"], audio_embeds.astype(rt.dtype))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(carry, p_l):
        h = rms_norm(carry, p_l["ln1"], cfg.norm_eps)
        y, _ = attn.gqa_apply(p_l["attn"], h, positions, cfg,
                              causal=False, window=0, kv_mult=rt.kv_mult,
                              impl=rt.impl, chunk=rt.attn_chunk,
                              unroll=rt.scan_unroll)
        xc = carry + y
        h = rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        return xc + swiglu(p_l["mlp"], h), None

    if rt.remat:
        body = jax.checkpoint(body)
    x, _ = _scan(rt, body, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(p_l, x, enc_out, positions, cfg, rt, mode, c_l, cache_pos,
               cache_idx):
    dec = mode == "decode"
    h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
    y, new_kv = attn.gqa_apply(
        p_l["self"], h, positions, cfg,
        cache=c_l if dec else None,
        cache_pos=cache_pos if dec else None,
        cache_idx=cache_idx if dec else None,
        window=rt.eff_window(cfg), causal=True, kv_mult=rt.kv_mult,
        impl=rt.impl, chunk=rt.attn_chunk, unroll=rt.scan_unroll)
    x = x + y
    h = rms_norm(x, p_l["ln_x"], cfg.norm_eps)
    x = x + attn.cross_apply(p_l["cross"], h, enc_out, cfg,
                             kv_mult=rt.kv_mult, impl=rt.impl)
    h = rms_norm(x, p_l["ln2"], cfg.norm_eps)
    x = x + swiglu(p_l["mlp"], h)
    return x, (None if mode == "train" else new_kv)


def _decoder(params, x, enc_out, positions, cfg, rt, mode, cache,
             cache_pos, cache_idx):
    c_dec = cache["self"] if cache is not None else None

    def body(carry, xs):
        p_l, c_l = xs if c_dec is not None else (xs, None)
        return _dec_block(p_l, carry, enc_out, positions, cfg, rt, mode,
                          c_l, cache_pos, cache_idx)

    if rt.remat:
        body = jax.checkpoint(body)
    xs = (params["dec"], c_dec) if c_dec is not None else params["dec"]
    return _scan(rt, body, x, xs)


def forward(params, tokens: jax.Array, cfg: ArchConfig, rt: RuntimeOptions,
            prefix_embeds: Optional[jax.Array] = None):
    """Teacher-forced: encoder over audio embeds, decoder over tokens."""
    enc_out = encode(params, prefix_embeds, cfg, rt)
    x = embed(params["embed"], tokens).astype(rt.dtype)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, _ = _decoder(params, x, enc_out, positions, cfg, rt, "train", None,
                    None, None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params["embed"], x), jnp.zeros((), jnp.float32)


def prefill(params, tokens: jax.Array, cfg: ArchConfig, rt: RuntimeOptions,
            prefix_embeds: Optional[jax.Array] = None, max_len=None):
    from repro.models.transformer import fit_kv_cache
    enc_out = encode(params, prefix_embeds, cfg, rt)
    x = embed(params["embed"], tokens).astype(rt.dtype)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x, kv = _decoder(params, x, enc_out, positions, cfg, rt, "prefill",
                     None, None, None)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]

    w = rt.eff_window(cfg)
    target = max_len or S + 128
    M = min(target, w) if w else target
    kv, pos = fit_kv_cache(kv, S, M)
    cache = {"self": kv, "enc_out": enc_out, "pos": pos,
             "idx": jnp.asarray(S, jnp.int32)}
    return logits, cache


def init_cache(cfg: ArchConfig, rt: RuntimeOptions, batch: int,
               seq_len: int, enc_len: Optional[int] = None):
    """Empty decode cache (for dry-run input_specs)."""
    w = rt.eff_window(cfg)
    M = min(seq_len, w) if w else seq_len
    enc_len = enc_len or cfg.n_prefix_tokens
    nkv = cfg.n_kv_heads * rt.kv_mult
    L = cfg.dec_layers
    return {
        "self": {"k": jnp.zeros((L, batch, M, nkv, cfg.head_dim), rt.dtype),
                 "v": jnp.zeros((L, batch, M, nkv, cfg.head_dim), rt.dtype)},
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), rt.dtype),
        "pos": jnp.full((M,), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cache, token: jax.Array, cfg: ArchConfig,
                rt: RuntimeOptions):
    x = embed(params["embed"], token[:, None]).astype(rt.dtype)
    positions = cache["idx"][None].astype(jnp.int32)
    x, kv = _decoder(params, x, cache["enc_out"], positions, cfg, rt,
                     "decode", cache, cache["pos"], cache["idx"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    M = cache["pos"].shape[0]
    new_pos = jax.lax.dynamic_update_slice(
        cache["pos"], positions, (cache["idx"] % M,))
    return logits, {"self": kv, "enc_out": cache["enc_out"],
                    "pos": new_pos, "idx": cache["idx"] + 1}


def _scan(rt, body, carry, xs, **kw):
    """lax.scan with optional full unroll (roofline probes)."""
    import jax as _jax
    return _jax.lax.scan(body, carry, xs,
                         unroll=True if getattr(rt, "scan_unroll", False)
                         else 1, **kw)
