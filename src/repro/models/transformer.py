"""Decoder language model assembly: dense / MoE / VLM / pure-SSM families.

Layers are grouped into homogeneous SEGMENTS and ``jax.lax.scan``-ned over
stacked per-layer params (bounded HLO size at 27-81 layers).  Three modes:

  forward(...)      full-sequence teacher forcing (train / eval)
  prefill(...)      full sequence, returns (last-token logits, decode cache)
  decode_step(...)  one token against the cache (ring buffer if windowed)

Cache pytree: {"segments": [per-segment stacked cache], "pos": [M] int32,
"idx": () int32}.  The hybrid (Zamba2) assembly lives in hybrid.py, the
encoder-decoder one in encdec.py.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed, init_embedding, init_linear,
                                 init_rmsnorm, init_swiglu, linear, rms_norm,
                                 swiglu, unembed)
from repro.models.runtime import RuntimeOptions


# ----------------------------------------------------------- segments
def segments(cfg: ArchConfig) -> List[Tuple[str, int, int]]:
    """[(block_type, n_layers, d_ff)] — contiguous homogeneous runs."""
    if cfg.family in ("dense", "vlm"):
        return [("attn_dense", cfg.num_layers, cfg.d_ff)]
    if cfg.family == "moe":
        m = cfg.moe
        segs = []
        if m.first_dense_layers:
            segs.append(("attn_dense", m.first_dense_layers,
                         m.dense_d_ff or cfg.d_ff))
        segs.append(("attn_moe", cfg.num_layers - m.first_dense_layers, 0))
        return segs
    if cfg.family == "ssm":
        return [("mamba", cfg.num_layers, 0)]
    raise ValueError(f"transformer.py does not assemble family "
                     f"{cfg.family!r}")


# ----------------------------------------------------------- block
def _init_block(key, cfg: ArchConfig, rt: RuntimeOptions, btype: str,
                d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    if btype == "mamba":
        return {"ln1": init_rmsnorm(cfg.d_model, rt.dtype),
                "mixer": ssm_mod.init_mamba2(k1, cfg, rt.dtype)}
    a = (attn.init_mla(k1, cfg, rt.dtype) if cfg.attn_type == "mla"
         else attn.init_gqa(k1, cfg, rt.dtype, rt.kv_mult))
    p = {"ln1": init_rmsnorm(cfg.d_model, rt.dtype), "attn": a,
         "ln2": init_rmsnorm(cfg.d_model, rt.dtype)}
    if btype == "attn_dense":
        p["mlp"] = init_swiglu(k2, cfg.d_model, d_ff, rt.dtype,
                               cfg.attn_bias)
    else:
        p["mlp"] = moe_mod.init_moe(k2, cfg, rt.dtype)
    return p


def _apply_block(p, x, btype: str, cfg: ArchConfig, rt: RuntimeOptions,
                 positions, mode: str, cache_l, cache_pos, cache_idx):
    """Returns (x, new_cache_l, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if btype == "mamba":
        y, new_c = ssm_mod.mamba2_apply(
            p["mixer"], h, cfg, cache=cache_l if mode == "decode" else None,
            return_cache=(mode == "prefill"), impl=rt.impl)
        return x + y, new_c, aux

    kw = dict(window=rt.eff_window(cfg), causal=True, impl=rt.impl,
              chunk=rt.attn_chunk, unroll=rt.scan_unroll)
    dec = mode == "decode"
    if cfg.attn_type == "mla":
        y, new_c = attn.mla_apply(
            p["attn"], h, positions, cfg,
            cache=cache_l if dec else None,
            cache_pos=cache_pos if dec else None,
            cache_idx=cache_idx if dec else None,
            absorbed=rt.absorbed_mla, **kw)
    else:
        y, new_c = attn.gqa_apply(
            p["attn"], h, positions, cfg,
            cache=cache_l if dec else None,
            cache_pos=cache_pos if dec else None,
            cache_idx=cache_idx if dec else None,
            kv_mult=rt.kv_mult, **kw)
    x = x + y
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if btype == "attn_dense":
        y = swiglu(p["mlp"], h)
    elif rt.moe_impl == "shard_map" and rt.mesh is not None:
        y, aux = moe_mod.moe_apply_sharded(
            p["mlp"], h, cfg, rt.mesh,
            capacity_factor=rt.capacity_factor, impl=rt.impl)
    else:
        y, aux = moe_mod.moe_apply(p["mlp"], h, cfg,
                                   capacity_factor=rt.capacity_factor,
                                   impl=rt.impl)
    return x + y, new_c, aux


# ----------------------------------------------------------- LM init
def init_lm(key, cfg: ArchConfig, rt: RuntimeOptions):
    segs = segments(cfg)
    keys = jax.random.split(key, len(segs) + 2)
    params = {
        "embed": init_embedding(keys[0], cfg.padded_vocab, cfg.d_model,
                                rt.dtype, tied=cfg.tie_embeddings),
        "final_norm": init_rmsnorm(cfg.d_model, rt.dtype),
        "segments": [],
    }
    if cfg.frontend_dim:
        params["frontend_proj"] = init_linear(
            keys[1], cfg.frontend_dim, cfg.d_model, rt.dtype)
    for i, (btype, n, d_ff) in enumerate(segs):
        lkeys = jax.random.split(keys[2 + i], n)
        params["segments"].append(jax.vmap(
            lambda k: _init_block(k, cfg, rt, btype, d_ff))(lkeys))
    return params


# ----------------------------------------------------------- cache init
def _layer_cache_shape(cfg: ArchConfig, rt: RuntimeOptions, btype: str,
                       batch: int, M: int):
    if btype == "mamba":
        return ssm_mod.ssm_cache_init(cfg, batch, rt.dtype)
    if cfg.attn_type == "mla":
        m = cfg.mla
        return {"ckv": jnp.zeros((batch, M, m.kv_lora_rank), rt.dtype),
                "krope": jnp.zeros((batch, M, m.qk_rope_head_dim), rt.dtype)}
    nkv = cfg.n_kv_heads * rt.kv_mult
    return {"k": jnp.zeros((batch, M, nkv, cfg.head_dim), rt.dtype),
            "v": jnp.zeros((batch, M, nkv, cfg.head_dim), rt.dtype)}


def cache_len(cfg: ArchConfig, rt: RuntimeOptions, seq_len: int) -> int:
    w = rt.eff_window(cfg)
    return min(seq_len, w) if w else seq_len


def init_cache(cfg: ArchConfig, rt: RuntimeOptions, batch: int,
               seq_len: int):
    """Empty decode cache sized for `seq_len` total positions."""
    M = cache_len(cfg, rt, seq_len)
    segs_c = []
    for (btype, n, _) in segments(cfg):
        one = _layer_cache_shape(cfg, rt, btype, batch, M)
        segs_c.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), one))
    return {"segments": segs_c,
            "pos": jnp.full((M,), -1, jnp.int32),
            "idx": jnp.zeros((), jnp.int32)}


# ----------------------------------------------------------- backbone
def _run_segments(params, x, cfg, rt, positions, mode, cache, cache_pos,
                  cache_idx):
    aux_total = jnp.zeros((), jnp.float32)
    new_seg_caches = []
    for si, (btype, n, d_ff) in enumerate(segments(cfg)):
        p_seg = params["segments"][si]
        c_seg = cache["segments"][si] if cache is not None else None

        def body(carry, xs, _btype=btype, _dff=d_ff):
            xc, auxc = carry
            p_l, c_l = xs if c_seg is not None else (xs, None)
            out, new_c, aux = _apply_block(
                p_l, xc, _btype, cfg, rt, positions, mode, c_l,
                cache_pos, cache_idx)
            return (out, auxc + aux), (None if mode == "train" else new_c)

        if rt.remat:
            body = jax.checkpoint(body)
        xs = (p_seg, c_seg) if c_seg is not None else p_seg
        (x, aux_total), ys = _scan(rt, body, (x, aux_total), xs)
        new_seg_caches.append(ys)
    return x, aux_total, new_seg_caches


def _embed_inputs(params, cfg, rt, tokens, prefix_embeds):
    x = embed(params["embed"], tokens)
    if prefix_embeds is not None:
        pe = linear(params["frontend_proj"],
                    prefix_embeds.astype(rt.dtype))
        x = jnp.concatenate([pe, x], axis=1)
    return x.astype(rt.dtype)


def forward(params, tokens: jax.Array, cfg: ArchConfig, rt: RuntimeOptions,
            prefix_embeds: Optional[jax.Array] = None):
    """Teacher-forced full-sequence logits.  tokens: [B, S_text];
    prefix_embeds: [B, P, frontend_dim] (VLM/audio stubs).
    Returns (logits [B, S_total, V], aux)."""
    x = _embed_inputs(params, cfg, rt, tokens, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, aux, _ = _run_segments(params, x, cfg, rt, positions, "train",
                              None, None, None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params["embed"], x), aux


def fit_kv_cache(kv, S: int, M: int, axis: int = 2):
    """Re-layout full-prefill K/V [.., S, ..] into a ring buffer of size M
    where slot (p % M) holds position p.  Returns (kv, pos [M])."""
    if M == S:
        return kv, jnp.arange(S, dtype=jnp.int32)
    if M > S:
        def pad(a):
            widths = [(0, 0)] * a.ndim
            widths[axis] = (0, M - S)
            return jnp.pad(a, widths)
        pos = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                               jnp.full((M - S,), -1, jnp.int32)])
        return jax.tree.map(pad, kv), pos
    kv = jax.tree.map(lambda a: a[(slice(None),) * axis + (slice(-M, None),)],
                      kv)
    pos = jnp.arange(S - M, S, dtype=jnp.int32)
    kv = jax.tree.map(lambda a: jnp.roll(a, S % M, axis=axis), kv)
    return kv, jnp.roll(pos, S % M)


def prefill(params, tokens: jax.Array, cfg: ArchConfig, rt: RuntimeOptions,
            prefix_embeds: Optional[jax.Array] = None,
            max_len: Optional[int] = None):
    """Returns (last-token logits [B, V], decode cache).  ``max_len`` sizes
    the cache for subsequent decoding (defaults to S + 128)."""
    x = _embed_inputs(params, cfg, rt, tokens, prefix_embeds)
    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, seg_caches = _run_segments(params, x, cfg, rt, positions,
                                     "prefill", None, None, None)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]

    M = cache_len(cfg, rt, max_len or S + 128)
    trimmed = []
    pos = None
    for (btype, n, _), c in zip(segments(cfg), seg_caches):
        if btype == "mamba":
            trimmed.append(c)
        else:
            c, pos = fit_kv_cache(c, S, M)
            trimmed.append(c)
    if pos is None:                       # pure-SSM: no kv ring needed
        pos = jnp.full((1,), -1, jnp.int32)
    cache = {"segments": trimmed, "pos": pos,
             "idx": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params, cache, token: jax.Array, cfg: ArchConfig,
                rt: RuntimeOptions):
    """token: [B] int32.  Returns (logits [B, V], new cache)."""
    x = embed(params["embed"], token[:, None]).astype(rt.dtype)
    positions = cache["idx"][None].astype(jnp.int32)
    x, _, seg_caches = _run_segments(
        params, x, cfg, rt, positions, "decode", cache,
        cache["pos"], cache["idx"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    M = cache["pos"].shape[0]
    new_pos = jax.lax.dynamic_update_slice(
        cache["pos"], positions, (cache["idx"] % M,))
    return logits, {"segments": seg_caches, "pos": new_pos,
                    "idx": cache["idx"] + 1}


def _scan(rt, body, carry, xs, **kw):
    """lax.scan with optional full unroll (roofline probes)."""
    import jax as _jax
    return _jax.lax.scan(body, carry, xs,
                         unroll=True if getattr(rt, "scan_unroll", False)
                         else 1, **kw)
