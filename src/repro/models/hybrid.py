"""Zamba2-style hybrid assembly: a Mamba2 backbone with ONE parameter-shared
attention+MLP block invoked every `shared_attn_every` layers.

Layer schedule for L=81, k=6:  13 super-blocks of (6 mamba + shared-attn
invocation) + 3 tail mamba layers.  The shared block's *parameters* are
reused across invocations, but each invocation has its own KV cache
(13 × [B, M, kvH, hd]).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed, init_embedding, init_rmsnorm,
                                 init_swiglu, rms_norm, swiglu, unembed)
from repro.models.runtime import RuntimeOptions


def _schedule(cfg: ArchConfig) -> Tuple[int, int, int]:
    k = cfg.shared_attn_every
    ns = cfg.num_layers // k
    tail = cfg.num_layers - ns * k
    return ns, k, tail


def init_hybrid(key, cfg: ArchConfig, rt: RuntimeOptions):
    ns, k, tail = _schedule(cfg)
    keys = jax.random.split(key, 6)

    def init_mamba_block(kk):
        return {"ln1": init_rmsnorm(cfg.d_model, rt.dtype),
                "mixer": ssm_mod.init_mamba2(kk, cfg, rt.dtype)}

    main_keys = jax.random.split(keys[0], ns * k).reshape(ns, k, 2)
    params = {
        "embed": init_embedding(keys[1], cfg.padded_vocab, cfg.d_model,
                                rt.dtype, tied=cfg.tie_embeddings),
        "final_norm": init_rmsnorm(cfg.d_model, rt.dtype),
        "mamba_main": jax.vmap(jax.vmap(init_mamba_block))(main_keys),
        "shared": {
            "ln1": init_rmsnorm(cfg.d_model, rt.dtype),
            "attn": attn.init_gqa(keys[2], cfg, rt.dtype, rt.kv_mult),
            "ln2": init_rmsnorm(cfg.d_model, rt.dtype),
            "mlp": init_swiglu(keys[3], cfg.d_model, cfg.d_ff, rt.dtype),
        },
    }
    if tail:
        tail_keys = jax.random.split(keys[4], tail)
        params["mamba_tail"] = jax.vmap(init_mamba_block)(tail_keys)
    return params


def init_cache(cfg: ArchConfig, rt: RuntimeOptions, batch: int,
               seq_len: int):
    ns, k, tail = _schedule(cfg)
    w = rt.eff_window(cfg)
    M = min(seq_len, w) if w else seq_len
    one_ssm = ssm_mod.ssm_cache_init(cfg, batch, rt.dtype)

    def stack(n, tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), tree)

    nkv = cfg.n_kv_heads * rt.kv_mult
    cache = {
        "mamba_main": stack(ns, stack(k, one_ssm)),
        "attn": {
            "k": jnp.zeros((ns, batch, M, nkv, cfg.head_dim), rt.dtype),
            "v": jnp.zeros((ns, batch, M, nkv, cfg.head_dim), rt.dtype)},
        "pos": jnp.full((M,), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }
    if tail:
        cache["mamba_tail"] = stack(tail, one_ssm)
    return cache


def _mamba_block(p, x, cfg, rt, mode, cache_l):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, new_c = ssm_mod.mamba2_apply(
        p["mixer"], h, cfg, cache=cache_l if mode == "decode" else None,
        return_cache=(mode == "prefill"), impl=rt.impl)
    return x + y, (None if mode == "train" else new_c)


def _shared_block(p, x, cfg, rt, positions, mode, cache_l, cache_pos,
                  cache_idx):
    dec = mode == "decode"
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, new_c = attn.gqa_apply(
        p["attn"], h, positions, cfg,
        cache=cache_l if dec else None,
        cache_pos=cache_pos if dec else None,
        cache_idx=cache_idx if dec else None,
        window=rt.eff_window(cfg), causal=True, kv_mult=rt.kv_mult,
        impl=rt.impl, chunk=rt.attn_chunk, unroll=rt.scan_unroll)
    x = x + y
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + swiglu(p["mlp"], h), (None if mode == "train" else new_c)


def _backbone(params, x, cfg, rt, positions, mode, cache, cache_pos,
              cache_idx):
    ns, k, tail = _schedule(cfg)

    def inner(carry, xs):
        p_l, c_l = xs
        out, new_c = _mamba_block(p_l, carry, cfg, rt, mode, c_l)
        return out, new_c

    def super_body(carry, xs):
        x_c = carry
        (p_m, c_m), c_a = xs
        x_c, new_cm = _scan(rt, inner, x_c, (p_m, c_m))
        x_c, new_ca = _shared_block(params["shared"], x_c, cfg, rt,
                                    positions, mode, c_a, cache_pos,
                                    cache_idx)
        return x_c, (new_cm, new_ca)

    if rt.remat:
        super_body = jax.checkpoint(super_body)

    c_main = cache["mamba_main"] if cache is not None else None
    c_attn = cache["attn"] if cache is not None else None
    if c_main is None:
        # scan without caches: feed params only
        def super_body_nc(carry, p_m):
            x_c = carry
            def inner_nc(c2, p_l):
                out, new_c = _mamba_block(p_l, c2, cfg, rt, mode, None)
                return out, new_c
            x_c, new_cm = _scan(rt, inner_nc, x_c, p_m)
            x_c, new_ca = _shared_block(params["shared"], x_c, cfg, rt,
                                        positions, mode, None, cache_pos,
                                        cache_idx)
            return x_c, (new_cm, new_ca)
        if rt.remat:
            super_body_nc = jax.checkpoint(super_body_nc)
        x, (new_main, new_attn) = _scan(rt, 
            super_body_nc, x, params["mamba_main"])
    else:
        x, (new_main, new_attn) = _scan(rt, 
            super_body, x, ((params["mamba_main"], c_main), c_attn))

    new_tail = None
    if tail:
        c_tail = cache["mamba_tail"] if cache is not None else None
        def tail_body(carry, xs):
            p_l, c_l = xs if c_tail is not None else (xs, None)
            return _mamba_block(p_l, carry, cfg, rt, mode, c_l)
        xs = ((params["mamba_tail"], c_tail) if c_tail is not None
              else params["mamba_tail"])
        x, new_tail = _scan(rt, tail_body, x, xs)
    return x, new_main, new_attn, new_tail


def forward(params, tokens, cfg: ArchConfig, rt: RuntimeOptions,
            prefix_embeds=None):
    x = embed(params["embed"], tokens).astype(rt.dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, *_ = _backbone(params, x, cfg, rt, positions, "train", None, None,
                      None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params["embed"], x), jnp.zeros((), jnp.float32)


def prefill(params, tokens, cfg: ArchConfig, rt: RuntimeOptions,
            prefix_embeds=None, max_len=None):
    from repro.models.transformer import fit_kv_cache
    x = embed(params["embed"], tokens).astype(rt.dtype)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x, new_main, new_attn, new_tail = _backbone(
        params, x, cfg, rt, positions, "prefill", None, None, None)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]

    ns, k, tail = _schedule(cfg)
    w = rt.eff_window(cfg)
    target = max_len or S + 128
    M = min(target, w) if w else target
    kv, pos = fit_kv_cache(new_attn, S, M)
    cache = {"mamba_main": new_main, "attn": kv, "pos": pos,
             "idx": jnp.asarray(S, jnp.int32)}
    if tail:
        cache["mamba_tail"] = new_tail
    return logits, cache


def decode_step(params, cache, token, cfg: ArchConfig, rt: RuntimeOptions):
    x = embed(params["embed"], token[:, None]).astype(rt.dtype)
    positions = cache["idx"][None].astype(jnp.int32)
    x, new_main, new_attn, new_tail = _backbone(
        params, x, cfg, rt, positions, "decode", cache, cache["pos"],
        cache["idx"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    M = cache["pos"].shape[0]
    new_pos = jax.lax.dynamic_update_slice(
        cache["pos"], positions, (cache["idx"] % M,))
    new_cache = {"mamba_main": new_main, "attn": new_attn, "pos": new_pos,
                 "idx": cache["idx"] + 1}
    if "mamba_tail" in cache:
        new_cache["mamba_tail"] = new_tail
    return logits, new_cache


def _scan(rt, body, carry, xs, **kw):
    """lax.scan with optional full unroll (roofline probes)."""
    import jax as _jax
    return _jax.lax.scan(body, carry, xs,
                         unroll=True if getattr(rt, "scan_unroll", False)
                         else 1, **kw)
