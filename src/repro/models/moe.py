"""Mixture-of-Experts layer: top-k router + capacity-based grouped dispatch.

Dispatch is sort-free scatter into a per-sequence capacity buffer
[B, E, C, d] (groups = batch, DESIGN.md §5): routing and scatter stay local
to the data shard, expert weights are f-sharded over the model axis
(tensor-parallel-within-expert).  The expert-parallel all-to-all variant is
the shard_map path in ``repro/launch/expert_parallel.py`` (§Perf).

FLOP-faithful: each token is computed by exactly its top-k experts
(capacity_factor controls drop rate, as in GShard/Switch).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.layers import init_swiglu, swiglu, truncated_normal_init


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    E, f = m.n_routed_experts, m.expert_d_ff

    def ew(k, shape):
        return truncated_normal_init(k, shape, 1.0, dtype)

    p = {
        "router": ew(ks[0], (d, E)),
        "w_gate": ew(ks[1], (E, d, f)),
        "w_up": ew(ks[2], (E, d, f)),
        "w_down": ew(ks[3], (E, f, d)),
    }
    if m.n_shared_experts:
        p["shared"] = init_swiglu(ks[4], d, m.shared_d_ff, dtype)
    return p


def _capacity(S: int, top_k: int, E: int, cf: float) -> int:
    c = int(S * top_k / E * cf) + 1
    return max(top_k, (c + 3) // 4 * 4)


def moe_apply_sharded(p, x: jax.Array, cfg: ArchConfig, mesh, *,
                      capacity_factor: float = 1.25,
                      impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    """§Perf lever A: shard_map MoE with an EXPLICIT collective schedule.

    GSPMD's auto-partitioning of the capacity-buffer formulation emits
    all-reduce/all-gather traffic proportional to the [B,E,C,d] dispatch
    buffers (the roofline baseline shows ~1e13 B/device/step on
    deepseek-v2-lite train_4k).  Here every step of routing, dispatch and
    expert compute is shard-LOCAL by construction (batch on data axes,
    expert f on the model axis), and the ONLY collectives are:
      * one token-space psum of the combined output [B_loc, S, d]
        (row-parallel down-proj, merged with the shared expert's), and
      * a scalar pmean for the aux loss.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    bt = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    bt_spec = bt if len(bt) > 1 else bt[0]
    m = cfg.moe

    pspec = {
        "router": P(),
        "w_gate": P(None, None, "model"),
        "w_up": P(None, None, "model"),
        "w_down": P(None, "model", None),
    }
    if m.n_shared_experts:
        pspec["shared"] = {
            "gate": {"w": P(None, "model")},
            "up": {"w": P(None, "model")},
            "down": {"w": P("model", None)},
        }

    def local(p_l, x_l):
        y_routed, aux = _moe_local(p_l, x_l, cfg, capacity_factor, impl)
        if m.n_shared_experts:
            y_routed = y_routed + swiglu(p_l["shared"], x_l)
        y = jax.lax.psum(y_routed, "model")
        aux = jax.lax.pmean(aux, bt)
        return y, aux

    fn = shard_map(local, mesh=mesh,
                   in_specs=(pspec, P(bt_spec, None, None)),
                   out_specs=(P(bt_spec, None, None), P()))
    return fn(p, x)


def _moe_local(p, x, cfg, capacity_factor, impl):
    """Routed-expert compute on local tokens with f-sharded weights.
    Output is the PARTIAL (pre-psum) token-space result."""
    y, aux = _moe_dispatch_compute(p, x, cfg, capacity_factor, impl)
    return y, aux


def moe_apply(p, x: jax.Array, cfg: ArchConfig, *,
              capacity_factor: float = 1.25,
              impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar).  GSPMD path."""
    y, aux = _moe_dispatch_compute(p, x, cfg, capacity_factor, impl)
    if cfg.moe.n_shared_experts:
        y = y + swiglu(p["shared"], x)
    return y, aux


def _moe_dispatch_compute(p, x: jax.Array, cfg: ArchConfig,
                          capacity_factor: float,
                          impl: str) -> Tuple[jax.Array, jax.Array]:
    """Routing + capacity dispatch + grouped expert SwiGLU (no shared
    expert, no collectives — callable from both the GSPMD path and the
    shard_map local body)."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_routed_experts, m.top_k
    C = _capacity(S, K, E, capacity_factor)

    logits = (x @ p["router"].astype(jnp.float32).astype(x.dtype)
              ).astype(jnp.float32)                      # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)               # [B,S,K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style) --------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(1, 2))  # [B,E]
    mean_prob = jnp.mean(probs, axis=1)                            # [B,E]
    aux = E * jnp.mean(jnp.sum(frac_tokens * mean_prob, axis=-1))

    # ---- position-in-expert via stable sort over choices ---------------
    flat_e = top_e.reshape(B, S * K)                     # [B, SK]
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)
    offsets = jnp.cumsum(counts, axis=-1) - counts       # [B, E] exclusive
    rank_sorted = (jnp.arange(S * K)[None, :]
                   - jnp.take_along_axis(offsets, sorted_e, axis=-1))
    inv = jnp.argsort(order, axis=-1)
    pos_in_e = jnp.take_along_axis(rank_sorted, inv, axis=-1)  # [B, SK]
    keep = pos_in_e < C
    slot = jnp.where(keep, pos_in_e, 0)

    # ---- scatter tokens into [E, C, d] per sequence ---------------------
    tok = jnp.repeat(jnp.arange(S), K)[None, :].repeat(B, 0)   # [B, SK]

    def scatter_one(xb, eb, sb, kb, tb):
        buf = jnp.zeros((E, C, d), xb.dtype)
        vals = xb[tb] * kb[:, None].astype(xb.dtype)
        return buf.at[eb, sb].add(vals)

    xbuf = jax.vmap(scatter_one)(x, flat_e, slot, keep, tok)   # [B,E,C,d]

    # ---- expert compute (grouped matmul kernel) -------------------------
    xe = xbuf.transpose(1, 0, 2, 3).reshape(E, B * C, d)
    ye = ops.moe_gmm(xe, p["w_gate"], p["w_up"], p["w_down"], impl=impl)
    ybuf = ye.reshape(E, B, C, d).transpose(1, 0, 2, 3)        # [B,E,C,d]

    # ---- gather back + combine ------------------------------------------
    def gather_one(yb, eb, sb, kb):
        return yb[eb, sb] * kb[:, None].astype(yb.dtype)       # [SK, d]

    y_choice = jax.vmap(gather_one)(ybuf, flat_e, slot, keep)
    y_choice = y_choice.reshape(B, S, K, d)
    y = jnp.sum(y_choice * top_p[..., None].astype(y_choice.dtype), axis=2)
    return y, aux.astype(jnp.float32)
