"""Low-frequency-modality models (§4.1.1): a random forest per vital sign
and a logistic regression for labs.

Per the paper these run on CPU with negligible latency, so they are NOT
model-zoo members for the latency profiler — but their scores join the
final accuracy ensemble (Eq. 5).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.forest import RandomForest


class VitalsForest:
    """One RF per vital-sign channel; predictions averaged."""

    def __init__(self, n_channels: int, n_trees: int = 25, seed: int = 0):
        self.models: List[RandomForest] = [
            RandomForest(n_trees=n_trees, max_depth=6, seed=seed + i)
            for i in range(n_channels)]

    def fit(self, X: np.ndarray, y: np.ndarray) -> "VitalsForest":
        """X: [n, n_channels, window] per-channel vitals clips."""
        for c, m in enumerate(self.models):
            m.fit(X[:, c, :], y)
        return self

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return np.clip(np.mean(
            [m.predict(X[:, c, :]) for c, m in enumerate(self.models)],
            axis=0), 0.0, 1.0)


class LogisticRegression:
    """Plain numpy logistic regression (labs model)."""

    def __init__(self, lr: float = 0.1, steps: int = 500, l2: float = 1e-3,
                 seed: int = 0):
        self.lr, self.steps, self.l2 = lr, steps, l2
        self.seed = seed
        self.w = None
        self.b = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "LogisticRegression":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        mu, sd = X.mean(0), X.std(0) + 1e-8
        self._norm = (mu, sd)
        Xn = (X - mu) / sd
        rng = np.random.default_rng(self.seed)
        self.w = rng.normal(0, 0.01, X.shape[1])
        self.b = 0.0
        for _ in range(self.steps):
            p = self._sigmoid(Xn @ self.w + self.b)
            g = Xn.T @ (p - y) / len(y) + self.l2 * self.w
            self.w -= self.lr * g
            self.b -= self.lr * float(np.mean(p - y))
        return self

    @staticmethod
    def _sigmoid(z):
        return 1.0 / (1.0 + np.exp(-np.clip(z, -30, 30)))

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        mu, sd = self._norm
        return self._sigmoid(((np.asarray(X, np.float64) - mu) / sd)
                             @ self.w + self.b)
