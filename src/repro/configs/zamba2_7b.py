"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

Assignment: 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000,
ssm_state=64 [arXiv:2411.15242].

81 Mamba2 layers; ONE parameter-shared attention+MLP block is invoked every
6 layers (Zamba's shared-block trick) — its params are reused each time.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    head_dim=112,                     # 3584 / 32
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=128),
    shared_attn_every=6,
    sliding_window=0,
    tie_embeddings=True,
)
