"""smollm-360m [dense] — llama-arch small.

Assignment: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M].

15 q-heads / 5 kv-heads do not divide a 16-way model axis: q heads are
padded 15->16 and kv 5->8; padding heads are zero-init (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49_152,
    head_dim=64,
    tie_embeddings=True,
)
