"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchConfig
from repro.configs import (
    deepseek_v2_lite_16b, zamba2_7b, phi35_moe_42b, qwen3_4b,
    seamless_m4t_medium, command_r_35b, mamba2_2p7b, internvl2_26b,
    granite_20b, smollm_360m,
)

_ARCHS: Dict[str, ArchConfig] = {
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "command-r-35b": command_r_35b.CONFIG,
    "mamba2-2.7b": mamba2_2p7b.CONFIG,
    "internvl2-26b": internvl2_26b.CONFIG,
    "granite-20b": granite_20b.CONFIG,
    "smollm-360m": smollm_360m.CONFIG,
}

ARCH_IDS: List[str] = list(_ARCHS)


def get_config(arch: str) -> ArchConfig:
    if arch.endswith("-reduced"):
        return get_config(arch[: -len("-reduced")]).reduced()
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; options: {ARCH_IDS}")
    return _ARCHS[arch]


def all_configs() -> Dict[str, ArchConfig]:
    return dict(_ARCHS)
