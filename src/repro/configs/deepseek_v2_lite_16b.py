"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 64 routed (top-6) + 2 shared.

Assignment: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6, MLA kv_lora=512 [arXiv:2405.04434].

Note (DESIGN.md §4): the assignment line also mentions "160 routed" which is
DeepSeek-V2-*full*'s expert count; we follow the primary spec (64 routed,
top-6, 2 shared).  First layer uses a dense FFN (model card: 10944), routed
expert hidden = 1408, shared expert hidden = 2×1408.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,                    # MLA: kv heads == q heads post up-proj
    d_ff=1408,
    vocab_size=102_400,
    head_dim=192,                     # qk_nope(128)+qk_rope(64)
    attn_type="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed_experts=64, n_shared_experts=2, top_k=6,
                  expert_d_ff=1408, shared_d_ff=2816,
                  first_dense_layers=1, dense_d_ff=10_944),
    rope_theta=10_000.0,
)
