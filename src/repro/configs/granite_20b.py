"""granite-20b [dense] — llama-arch, code model, MQA (kv=1).

Assignment: 52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324].

kv=1 (multi-query): the single KV head is REPLICATED across the 16-way
model axis; only Q heads shard (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24_576,
    vocab_size=49_152,
    head_dim=128,
    attn_bias=True,
)
