"""seamless-m4t-medium [audio] — encoder-decoder backbone.

Assignment: 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206,
enc-dec multimodal [arXiv:2308.11596].

Per assignment carve-out: the mel-spectrogram + conv feature extractor
frontend is a STUB — ``input_specs()`` supplies precomputed audio frame
embeddings of shape (batch, n_frames, frontend_dim); we implement the
encoder-decoder transformer that consumes them.  12L is interpreted as
12 encoder + 12 decoder layers.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    source="arXiv:2308.11596",
    num_layers=24,
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,               # padded to 256208 for 16-way sharding
    head_dim=64,
    n_prefix_tokens=1024,             # audio frames fed to the encoder
    frontend_dim=1024,
)
