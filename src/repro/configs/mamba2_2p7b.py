"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.

Assignment: 64L d_model=2560 (attn-free) vocab=50280, ssm_state=128
[arXiv:2405.21060].  vocab padded to 50288 for 16-way sharding.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    attn_type="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=128),
    tie_embeddings=True,
)
