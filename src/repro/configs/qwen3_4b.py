"""qwen3-4b [dense] — GQA with qk_norm.

Assignment: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936,
qk_norm [hf:Qwen/Qwen3-8B].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
