"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2.

Assignment: 32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064,
MoE 16e top-2 [hf:microsoft/Phi-3.5-MoE-instruct].
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32_064,
    head_dim=128,
    moe=MoEConfig(n_routed_experts=16, n_shared_experts=0, top_k=2,
                  expert_d_ff=6400),
)
