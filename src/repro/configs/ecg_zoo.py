"""The paper's own model zoo (§4.1.1): 1-D-stripe ResNeXt ECG classifiers.

Full zoo: 3 ECG leads × widths {8,16,32,64,128} × blocks {2,4,8,16} = 60
deep models.  Vitals get a random forest, labs a logistic regression; per
the paper those CPU models are NOT zoo members for latency purposes but DO
join the final accuracy ensemble.

``zoo_specs(reduced=True)`` is the CPU-friendly zoo used by tests and the
default benchmarks (3 leads × {8,16} filters × {2,4} blocks = 12 models,
shorter clips).

Architecture buckets (serving): members whose parameter pytrees are
structurally identical — same ``(width, blocks, input_len, cardinality,
kernel_size)``; the lead only selects which input slice a member consumes
— can be STACKED along a leading member axis and executed as ONE jitted
vmap-over-params call.  ``bucket_key`` / ``bucket_zoo`` define that
grouping: the reduced zoo's 12 members collapse to 4 buckets (2 widths ×
2 block counts, the 3 leads folding into each bucket) and the full zoo's
60 to 20.  ``serving.pipeline.EnsembleService`` builds its fused
dispatch plan from these buckets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class EcgModelSpec:
    name: str
    lead: int                 # 0,1,2  <-> leads I, II, III
    width: int                # filters in the first conv layer
    blocks: int               # residual blocks
    input_len: int            # samples per 30 s clip (250 Hz => 7500)
    cardinality: int = 8      # ResNeXt group count
    kernel_size: int = 7      # 1-D stripe kernel


FULL_WIDTHS = (8, 16, 32, 64, 128)
FULL_BLOCKS = (2, 4, 8, 16)
REDUCED_WIDTHS = (8, 16)
REDUCED_BLOCKS = (2, 4)


def zoo_specs(reduced: bool = True, input_len: int = None,
              widths=None, blocks=None) -> List[EcgModelSpec]:
    widths = widths or (REDUCED_WIDTHS if reduced else FULL_WIDTHS)
    blocks = blocks or (REDUCED_BLOCKS if reduced else FULL_BLOCKS)
    if input_len is None:
        input_len = 750 if reduced else 7500
    out = []
    for lead in range(3):
        for w in widths:
            for b in blocks:
                out.append(EcgModelSpec(
                    name=f"lead{lead + 1}_w{w}_b{b}",
                    lead=lead, width=w, blocks=b, input_len=input_len,
                    cardinality=min(8, w)))
    return out


BucketKey = Tuple[int, int, int, int, int]


def bucket_key(spec: EcgModelSpec) -> BucketKey:
    """Shape signature under which members share one stacked program.
    Everything but ``lead``/``name`` — two specs with equal keys have
    structurally identical parameter pytrees."""
    return (spec.width, spec.blocks, spec.input_len, spec.cardinality,
            spec.kernel_size)


def bucket_zoo(specs: Sequence[EcgModelSpec]
               ) -> Dict[BucketKey, List[int]]:
    """Group member indices by ``bucket_key`` (insertion-ordered, so
    bucket order is deterministic given spec order).  The serving path
    issues one stacked dispatch per bucket instead of one per member:
    12 -> 4 on the reduced zoo, 60 -> 20 on the full zoo."""
    out: Dict[BucketKey, List[int]] = {}
    for i, s in enumerate(specs):
        out.setdefault(bucket_key(s), []).append(i)
    return out


N_VITALS = 7     # 1 Hz vitals (mean BP, SpO2, ...)
N_LABS = 8       # irregular labs (pH, lactate, ...)
ECG_LEADS = 3    # leads I, II, III — the channel count of every ECG
                 # window (members pick ONE lead; the serving pack ships
                 # all three once and lead-selects on device)
ECG_HZ = 250
VITALS_HZ = 1
CLIP_SECONDS = 30
