"""The paper's own model zoo (§4.1.1): 1-D-stripe ResNeXt ECG classifiers.

Full zoo: 3 ECG leads × widths {8,16,32,64,128} × blocks {2,4,8,16} = 60
deep models.  Vitals get a random forest, labs a logistic regression; per
the paper those CPU models are NOT zoo members for latency purposes but DO
join the final accuracy ensemble.

``zoo_specs(reduced=True)`` is the CPU-friendly zoo used by tests and the
default benchmarks (3 leads × {8,16} filters × {2,4} blocks = 12 models,
shorter clips).
"""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class EcgModelSpec:
    name: str
    lead: int                 # 0,1,2  <-> leads I, II, III
    width: int                # filters in the first conv layer
    blocks: int               # residual blocks
    input_len: int            # samples per 30 s clip (250 Hz => 7500)
    cardinality: int = 8      # ResNeXt group count
    kernel_size: int = 7      # 1-D stripe kernel


FULL_WIDTHS = (8, 16, 32, 64, 128)
FULL_BLOCKS = (2, 4, 8, 16)
REDUCED_WIDTHS = (8, 16)
REDUCED_BLOCKS = (2, 4)


def zoo_specs(reduced: bool = True, input_len: int = None,
              widths=None, blocks=None) -> List[EcgModelSpec]:
    widths = widths or (REDUCED_WIDTHS if reduced else FULL_WIDTHS)
    blocks = blocks or (REDUCED_BLOCKS if reduced else FULL_BLOCKS)
    if input_len is None:
        input_len = 750 if reduced else 7500
    out = []
    for lead in range(3):
        for w in widths:
            for b in blocks:
                out.append(EcgModelSpec(
                    name=f"lead{lead + 1}_w{w}_b{b}",
                    lead=lead, width=w, blocks=b, input_len=input_len,
                    cardinality=min(8, w)))
    return out


N_VITALS = 7     # 1 Hz vitals (mean BP, SpO2, ...)
N_LABS = 8       # irregular labs (pH, lactate, ...)
ECG_HZ = 250
VITALS_HZ = 1
CLIP_SECONDS = 30
