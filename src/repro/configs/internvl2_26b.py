"""internvl2-26b [vlm] — InternViT + InternLM2 language backbone.

Assignment: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821].  vocab padded to 92560.

Per assignment carve-out: the InternViT-6B vision encoder + projector
frontend is a STUB — ``input_specs()`` supplies precomputed patch
embeddings (batch, n_image_tokens, frontend_dim); the backbone projects
them to d_model and interleaves with text tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,
    vocab_size=92_553,
    head_dim=128,
    n_prefix_tokens=1024,             # ViT patch tokens per image
    frontend_dim=3200,                # InternViT-6B width
)
