"""command-r-35b [dense] — GQA, no-bias.

Assignment: 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_528,
    vocab_size=256_000,
    head_dim=128,
    attn_bias=False,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
)
