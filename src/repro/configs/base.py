"""Architecture configuration dataclasses.

Every assigned architecture gets one ``ArchConfig`` describing the
transformer/SSM backbone exactly as assigned (see per-arch files).  The
same dataclass also describes the reduced smoke variants used by CPU
tests (``reduced()``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2) dims."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => direct q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    expert_d_ff: int = 0          # per-expert hidden size
    shared_d_ff: int = 0          # shared-expert hidden size (total)
    first_dense_layers: int = 0   # leading layers that use a dense FFN
    dense_d_ff: int = 0           # hidden size of those dense layers
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 128              # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim

    def conv_channels(self, d_model: int) -> int:
        return self.d_inner(d_model) + 2 * self.n_groups * self.d_state


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    source: str                   # citation from the assignment table
    num_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention details
    attn_type: str = "gqa"        # gqa | mla | none
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0       # 0 => full attention in normal shapes
    long_context_window: int = 4096   # window used for long_500k on dense archs
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a shared attention+MLP block applied every k layers
    shared_attn_every: int = 0
    # encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0
    # modality stubs
    n_prefix_tokens: int = 0      # image/audio embedding tokens prepended
    frontend_dim: int = 0         # raw embedding dim from the stub frontend
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- sharding-facing, derived at registry time ---
    vocab_pad_multiple: int = 16

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, self.vocab_pad_multiple)

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """All archs support long_500k: SSM/hybrid natively, attention archs
        via the sliding-window variant (DESIGN.md §4)."""
        return True

    def padded_heads(self, axis: int) -> Tuple[int, int]:
        """(q_heads, kv_heads) padded so the model axis divides q-heads and
        kv-heads are either sharded exactly or replicated."""
        q = pad_to_multiple(self.n_heads, axis) if self.n_heads else 0
        kv = self.n_kv_heads
        if kv and kv >= axis:
            kv = pad_to_multiple(kv, axis)
        elif kv:
            # replicated kv heads: pad to a divisor-friendly power of two
            kv = 1 << (kv - 1).bit_length()
        return q, kv

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count of the backbone (embeddings included)."""
        d = self.d_model
        n = 0
        n += self.padded_vocab * d                       # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d                   # lm head
        layers = self.num_layers if not self.is_encdec else (
            self.enc_layers + self.dec_layers)

        def attn_params() -> int:
            if self.attn_type == "mla":
                m = self.mla
                qdim = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                p = d * qdim                                       # q proj
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)     # kv down
                p += m.kv_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)             # kv up
                p += self.n_heads * m.v_head_dim * d               # o proj
                return p
            hq = self.n_heads * self.head_dim
            hkv = self.n_kv_heads * self.head_dim
            return d * hq + 2 * d * hkv + hq * d

        def mlp_params(ff: int) -> int:
            return 3 * d * ff                            # SwiGLU

        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            per = (d * (2 * di + 2 * s.n_groups * s.d_state + s.n_heads(d))
                   + s.conv_channels(d) * s.conv_width
                   + di * d + 3 * s.n_heads(d) + di)
            n += layers * per
        elif self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            per = (d * (2 * di + 2 * s.n_groups * s.d_state + s.n_heads(d))
                   + s.conv_channels(d) * s.conv_width
                   + di * d + 3 * s.n_heads(d) + di)
            n += layers * per
            # one SHARED attention+MLP block (parameters reused)
            n += attn_params() + mlp_params(self.d_ff)
        else:
            per = attn_params()
            if self.moe and self.moe.n_routed_experts:
                m = self.moe
                moe_layers = layers - m.first_dense_layers
                n += m.first_dense_layers * mlp_params(m.dense_d_ff or self.d_ff)
                n += moe_layers * (
                    m.n_routed_experts * mlp_params(m.expert_d_ff)
                    + (mlp_params(m.shared_d_ff) if m.n_shared_experts else 0)
                    + d * m.n_routed_experts)            # router
                n += layers * per
            else:
                n += layers * (per + mlp_params(self.d_ff))
        if self.n_prefix_tokens and self.frontend_dim:
            n += self.frontend_dim * d                   # projector
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if not (self.moe and self.moe.n_routed_experts):
            return self.param_count()
        m = self.moe
        full = self.param_count()
        layers = self.num_layers - m.first_dense_layers
        unused = (m.n_routed_experts - m.top_k) * 3 * self.d_model * m.expert_d_ff
        return full - layers * unused

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        d = min(self.d_model, 256)
        hd = 32
        nh = max(2, min(4, self.n_heads or 2))
        nkv = max(1, min(2, self.n_kv_heads or 1))
        kw = {}
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=0,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_routed_experts=min(4, self.moe.n_routed_experts),
                top_k=min(2, self.moe.top_k), expert_d_ff=64,
                shared_d_ff=64 if self.moe.n_shared_experts else 0,
                first_dense_layers=min(1, self.moe.first_dense_layers),
                dense_d_ff=128 if self.moe.first_dense_layers else 0)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                            chunk=16)
        return dataclasses.replace(
            self, name=self.name + "-reduced",
            num_layers=min(2, self.num_layers),
            enc_layers=min(2, self.enc_layers),
            dec_layers=min(2, self.dec_layers),
            d_model=d, n_heads=nh if self.n_heads else 0,
            n_kv_heads=nkv if self.n_kv_heads else 0,
            head_dim=hd, d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=64,
            shared_attn_every=min(self.shared_attn_every, 2)
            if self.shared_attn_every else 0,
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            **kw)

    def flops_per_token(self, seq_len: int, decode: bool = False) -> float:
        """Rough forward FLOPs/token: 2*active_params + attention term."""
        f = 2.0 * self.active_param_count()
        if self.n_heads:
            ctx = min(seq_len, self.sliding_window or seq_len)
            layers = self.num_layers if not self.is_encdec else self.dec_layers
            hd = (self.mla.v_head_dim if self.attn_type == "mla"
                  else self.head_dim)
            f += 2.0 * layers * self.n_heads * hd * (ctx if decode else ctx)
        return f
