"""Checkpointing: pytree <-> npz with path-keyed arrays + JSON metadata.

Flat path keys make checkpoints structure-stable across refactors, and the
save is atomic (tmp file + rename) so a killed run never leaves a corrupt
checkpoint behind.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree, metadata: Optional[Dict[str, Any]] = None
         ) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    meta = dict(metadata or {})
    meta["n_arrays"] = len(flat)
    with open(path + ".json", "w") as f:
        json.dump(meta, f, indent=2, default=str)


def restore(path: str, like) -> Any:
    """Restore into the structure of `like` (a template pytree)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for p, leaf in leaves_with_path:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {np.shape(leaf)}")
        new_leaves.append(arr.astype(np.asarray(leaf).dtype)
                          if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_metadata(path: str) -> Dict[str, Any]:
    with open(path + ".json") as f:
        return json.load(f)
