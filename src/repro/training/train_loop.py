"""Training loops: LM train step (assigned architectures) and the ECG-zoo
trainer that populates the paper's model zoo.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.ecg_zoo import EcgModelSpec
from repro.models.api import get_model
from repro.models.ecg_resnext import ecg_apply, init_ecg
from repro.models.layers import softmax_xent
from repro.models.runtime import RuntimeOptions
from repro.training.optimizer import AdamW, constant_schedule


# ------------------------------------------------------------- LM steps
def lm_loss(params, batch: Dict, cfg: ArchConfig, rt: RuntimeOptions,
            model=None):
    model = model or get_model(cfg)
    logits, aux = model.forward(params, batch["tokens"], cfg, rt,
                                prefix_embeds=batch.get("prefix_embeds"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:      # VLM/audio prefix positions
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    loss = softmax_xent(logits, labels)
    if cfg.moe:
        loss = loss + cfg.moe.router_aux_coef * aux
    return loss


def make_train_step(cfg: ArchConfig, rt: RuntimeOptions, opt: AdamW
                    ) -> Callable:
    model = get_model(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, cfg, rt, model))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_serve_prefill(cfg: ArchConfig, rt: RuntimeOptions) -> Callable:
    model = get_model(cfg)

    def serve_prefill(params, batch):
        logits, cache = model.prefill(
            params, batch["tokens"], cfg, rt,
            prefix_embeds=batch.get("prefix_embeds"),
            max_len=batch["tokens"].shape[1] + 1
            + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0))
        return logits

    return serve_prefill


def make_serve_step(cfg: ArchConfig, rt: RuntimeOptions) -> Callable:
    """ONE new token against an existing KV cache (decode shapes)."""
    model = get_model(cfg)

    def serve_step(params, cache, token):
        return model.decode_step(params, cache, token, cfg, rt)

    return serve_step


def train_lm(cfg: ArchConfig, rt: RuntimeOptions, batches: Iterator,
             steps: int, lr: float = 3e-4, seed: int = 0,
             log_every: int = 10, callback: Optional[Callable] = None):
    opt = AdamW(lr=constant_schedule(lr))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed), cfg, rt)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, rt, opt))
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if callback and (i % log_every == 0 or i == steps - 1):
            callback(i, losses[-1])
    return params, losses


# ------------------------------------------------------------- ECG zoo
def ecg_loss(params, x, y, spec: EcgModelSpec):
    logits = ecg_apply(params, x, spec)
    return softmax_xent(logits, y)


def train_ecg_model(spec: EcgModelSpec, x: np.ndarray, y: np.ndarray,
                    steps: int = 150, batch: int = 32, lr: float = 1e-3,
                    seed: int = 0) -> Tuple[Dict, list]:
    """x: [n, L] single-lead clips; y: [n] binary labels."""
    params = init_ecg(jax.random.PRNGKey(seed), spec)
    opt = AdamW(lr=constant_schedule(lr), weight_decay=1e-4)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, xb, yb):
        def loss_fn(p):
            logits = ecg_apply(p, xb[..., None], spec)
            return softmax_xent(logits, yb)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    rng = np.random.default_rng(seed)
    losses = []
    n = len(x)
    for i in range(steps):
        idx = rng.integers(0, n, size=min(batch, n))
        params, opt_state, loss = step(params, opt_state,
                                       jnp.asarray(x[idx]),
                                       jnp.asarray(y[idx]))
        losses.append(float(loss))
    return params, losses


def ecg_predict_proba(params, x: np.ndarray, spec: EcgModelSpec,
                      batch: int = 256) -> np.ndarray:
    """P(stable) for single-lead clips x: [n, L]."""
    fn = jax.jit(lambda xb: jax.nn.softmax(
        ecg_apply(params, xb[..., None], spec), axis=-1)[:, 1])
    out = []
    for i in range(0, len(x), batch):
        out.append(np.asarray(fn(jnp.asarray(x[i:i + batch]))))
    return np.concatenate(out) if out else np.zeros((0,))
