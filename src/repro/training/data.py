"""Data pipelines.

1. Synthetic ICU stream (the paper's data is CHOA pediatric CICU, which we
   cannot ship): class-conditional multimodal generator — 3-lead ECG-like
   waveforms at 250 Hz, 7 vitals at 1 Hz, 8 irregular labs.  "critical"
   (label 0) vs "stable" (label 1) differ in heart rate variability, noise
   level, ST-segment offset and vitals drift, so the task is learnable but
   not trivial.  Segmented into 30 s clips exactly as §4.1.1.

2. LM token pipeline for the assigned datacenter architectures (synthetic
   zipf tokens; deterministic, seedable, sharded-batch friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.ecg_zoo import (CLIP_SECONDS, ECG_HZ, N_LABS, N_VITALS,
                                   VITALS_HZ)


# ====================================================== synthetic ICU data
@dataclasses.dataclass
class PatientParams:
    heart_rate: float          # bpm
    hrv: float                 # beat-to-beat jitter (s)
    noise: float               # additive noise std
    st_offset: float           # ST-segment elevation (class signal)
    vitals_base: np.ndarray    # [N_VITALS]
    vitals_drift: np.ndarray   # [N_VITALS] per-second drift
    labs: np.ndarray           # [N_LABS]


def sample_patient(rng: np.random.Generator, label: int,
                   atypicality: float = 0.0) -> PatientParams:
    """label 0 = critical, 1 = stable.  ``atypicality`` in [0, 1] blends
    the patient's physiology toward the OTHER class (atypical
    presentations), bounding achievable single-model accuracy."""
    a = float(np.clip(atypicality, 0.0, 0.9))

    def mix(crit_lo, crit_hi, stab_lo, stab_hi):
        crit_v = rng.uniform(crit_lo, crit_hi)
        stab_v = rng.uniform(stab_lo, stab_hi)
        own, other = (crit_v, stab_v) if label == 0 else (stab_v, crit_v)
        return float((1 - a) * own + a * other)

    crit_bias, stab_bias = 0.8, -0.2
    bias = (1 - a) * (crit_bias if label == 0 else stab_bias) \
        + a * (stab_bias if label == 0 else crit_bias)
    return PatientParams(
        heart_rate=mix(130, 170, 100, 130),
        hrv=mix(0.002, 0.01, 0.02, 0.05),
        noise=mix(0.08, 0.2, 0.02, 0.08),
        st_offset=mix(0.08, 0.25, -0.02, 0.05),
        vitals_base=rng.normal(0.0, 0.5, N_VITALS) + bias,
        vitals_drift=rng.normal(0.0, (1 - a) * 0.02 + a * 0.005
                                if label == 0 else
                                (1 - a) * 0.005 + a * 0.02, N_VITALS),
        labs=rng.normal((1 - a) * (0.45 if label == 0 else -0.25)
                        + a * (-0.25 if label == 0 else 0.45), 0.45,
                        N_LABS),
    )


def _ecg_beat(t: np.ndarray, st: float) -> np.ndarray:
    """Crude PQRST morphology on t in [0, 1)."""
    p = 0.15 * np.exp(-((t - 0.15) / 0.03) ** 2)
    q = -0.2 * np.exp(-((t - 0.35) / 0.012) ** 2)
    r = 1.2 * np.exp(-((t - 0.40) / 0.015) ** 2)
    s = -0.3 * np.exp(-((t - 0.45) / 0.015) ** 2)
    tw = 0.3 * np.exp(-((t - 0.65) / 0.05) ** 2)
    st_seg = st * ((t > 0.45) & (t < 0.62)).astype(float)
    return p + q + r + s + tw + st_seg


_LEAD_GAIN = np.array([1.0, 1.35, 0.75])


def ecg_clip(rng: np.random.Generator, pp: PatientParams,
             seconds: int = CLIP_SECONDS, hz: int = ECG_HZ) -> np.ndarray:
    """[3 leads, seconds*hz] waveform clip."""
    n = seconds * hz
    beat_len = 60.0 / pp.heart_rate
    t, out = 0.0, np.zeros(n)
    phase = np.zeros(n)
    ts = np.arange(n) / hz
    starts = []
    while t < seconds + beat_len:
        starts.append(t)
        t += beat_len + rng.normal(0.0, pp.hrv)
    sig = np.zeros(n)
    for s0, s1 in zip(starts[:-1], starts[1:]):
        idx = (ts >= s0) & (ts < s1)
        if idx.any():
            sig[idx] = _ecg_beat((ts[idx] - s0) / max(s1 - s0, 1e-3),
                                 pp.st_offset)
    clips = (sig[None, :] * _LEAD_GAIN[:, None]
             + rng.normal(0.0, pp.noise, (3, n)))
    return clips.astype(np.float32)


def vitals_clip(rng: np.random.Generator, pp: PatientParams,
                seconds: int = CLIP_SECONDS) -> np.ndarray:
    """[N_VITALS, seconds] 1 Hz vitals."""
    t = np.arange(seconds * VITALS_HZ)
    base = pp.vitals_base[:, None] + pp.vitals_drift[:, None] * t[None, :]
    return (base + rng.normal(0, 0.1, base.shape)).astype(np.float32)


def labs_sample(rng: np.random.Generator, pp: PatientParams) -> np.ndarray:
    return (pp.labs + rng.normal(0, 0.2, N_LABS)).astype(np.float32)


def make_icu_dataset(n_patients: int, clips_per_patient: int,
                     seed: int = 0, seconds: int = CLIP_SECONDS,
                     hz: int = ECG_HZ, ambiguity: float = 0.35
                     ) -> Dict[str, np.ndarray]:
    """Returns {ecg [n,3,L], vitals [n,7,seconds], labs [n,8],
    label [n], patient [n]} with a 50/50 class balance of patients.

    ``ambiguity``: mean per-patient atypicality (graded blend toward the
    other class's physiology) — bounds any single model's achievable
    accuracy and creates the accuracy spread the paper's model zoo
    exhibits (ensembles then genuinely help)."""
    rng = np.random.default_rng(seed)
    ecg, vit, labs, ys, pid = [], [], [], [], []
    for p in range(n_patients):
        label = p % 2
        atyp = float(rng.beta(1.2, 3.0)) * min(1.0, ambiguity * 3)
        pp = sample_patient(rng, label, atypicality=atyp)
        for _ in range(clips_per_patient):
            ecg.append(ecg_clip(rng, pp, seconds, hz))
            vit.append(vitals_clip(rng, pp, seconds))
            labs.append(labs_sample(rng, pp))
            ys.append(label)
            pid.append(p)
    return {"ecg": np.stack(ecg), "vitals": np.stack(vit),
            "labs": np.stack(labs), "label": np.asarray(ys, np.int32),
            "patient": np.asarray(pid, np.int32)}


def split_by_patient(data: Dict[str, np.ndarray], holdout: int
                     ) -> Tuple[Dict, Dict]:
    """Paper §4.1.1: split the cohort BY PATIENT (earlier patients train,
    recent patients validate)."""
    max_p = int(data["patient"].max())
    cut = max_p + 1 - holdout
    tr = data["patient"] < cut
    return ({k: v[tr] for k, v in data.items()},
            {k: v[~tr] for k, v in data.items()})


# ====================================================== LM token pipeline
def lm_batches(vocab_size: int, batch: int, seq_len: int, seed: int = 0,
               zipf_a: float = 1.2) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic synthetic LM batches with zipf-ish marginals and a
    copy structure (second half echoes the first) so loss can decrease."""
    rng = np.random.default_rng(seed)
    while True:
        half = seq_len // 2 + 1
        first = (rng.zipf(zipf_a, size=(batch, half)) - 1) % vocab_size
        toks = np.concatenate([first, first[:, :seq_len - half]], axis=1)
        tokens = toks[:, :seq_len].astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1)
        yield {"tokens": tokens, "labels": labels}


def audio_frames(batch: int, frames: int, dim: int, seed: int = 0
                 ) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, (batch, frames, dim)).astype(np.float32)
