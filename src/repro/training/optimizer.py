"""AdamW + schedules, implemented directly in JAX (no optax here).

Optimizer state is a pytree mirroring the params (so its sharding specs are
the param specs — ZeRO-style placement falls out for free, DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]       # schedule: step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=zeros(params), nu=zeros(params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, m, n):
            u = (m / bc1) / (jnp.sqrt(n / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
                        for a in jax.tree.leaves(tree)))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(lr_value: float) -> Callable:
    return lambda step: jnp.asarray(lr_value, jnp.float32)
