"""Per-query span tracing: where did each query's second go?

The controller reasons about T_s + T_q; this module MEASURES that
decomposition per query instead of inferring it.  A query's lifecycle

    submit -> queue wait -> co-batch coalesce -> marshal/ref-gather
           -> device dispatch -> host gather -> retire

is captured as one ``SpanRecord`` built from three wall-clock stamps
the server takes anyway (submit, dequeue, flush, retire) plus
sub-stage timings the pipeline reports through a thread-local sink:

* ``queue_s``    = dequeue - submit      (ShedQueue wait)
* ``coalesce_s`` = flush - dequeue       (micro-batch hold)
* ``service_s``  = retire - flush        (handler end-to-end), further
  attributed into ``marshal_s`` (host marshal / on-device ref-gather),
  ``dispatch_s`` (device dispatch loop) and ``gather_s`` (host gather /
  block_until_ready) by ``note()`` calls inside the pipeline.

The sink is deliberately dumb: ``note(stage, seconds)`` adds into a
thread-local dict if (and only if) a ``collect()`` block is active on
this thread, so the pipeline's hot path pays one attribute load and a
truthiness check when tracing is off — the bench asserts the whole
plane stays within its overhead budget.

Failure paths are first-class: a NaN retirement carries
``status="failed"`` and a watchdog kill ``status="watchdog"``, so the
trace stream tells apart "slow but fine" from "died on device".
"""
from __future__ import annotations

import dataclasses
import json
import threading
from collections import deque
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.obs import sketch as _sk

# service-stage keys the pipeline reports via note(); queue/coalesce
# come from the server's own stamps
SERVICE_STAGES = ("marshal", "dispatch", "gather")
STAGES = ("queue", "coalesce") + SERVICE_STAGES

_tls = threading.local()


def note(stage: str, seconds: float) -> None:
    """Attribute ``seconds`` to ``stage`` for the query/batch currently
    being collected on this thread; no-op (one dict load) otherwise."""
    acc = getattr(_tls, "acc", None)
    if acc is not None:
        acc[stage] = acc.get(stage, 0.0) + seconds


@contextmanager
def collect() -> Iterator[Dict[str, float]]:
    """Open a per-thread stage sink; yields the dict the pipeline's
    ``note()`` calls accumulate into.  Reentrancy folds into the
    OUTER sink (sub-flushes attribute to the query being served)."""
    prev = getattr(_tls, "acc", None)
    if prev is not None:
        yield prev
        return
    _tls.acc = acc = {}
    try:
        yield acc
    finally:
        _tls.acc = None


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One retired query's lifecycle, stamps in ``time.monotonic``
    space, stage durations in seconds."""
    patient: int
    tier: Optional[str]
    status: str                     # "ok" | "failed" | "watchdog"
    t_submit: float
    t_dequeue: float
    t_flush: float
    t_retire: float
    batch_n: int                    # co-batch size this query rode in
    marshal_s: float
    dispatch_s: float
    gather_s: float

    @property
    def queue_s(self) -> float:
        return max(self.t_dequeue - self.t_submit, 0.0)

    @property
    def coalesce_s(self) -> float:
        return max(self.t_flush - self.t_dequeue, 0.0)

    @property
    def service_s(self) -> float:
        return max(self.t_retire - self.t_flush, 0.0)

    @property
    def e2e_s(self) -> float:
        return max(self.t_retire - self.t_submit, 0.0)

    def stage_seconds(self) -> Dict[str, float]:
        return {"queue": self.queue_s, "coalesce": self.coalesce_s,
                "marshal": self.marshal_s, "dispatch": self.dispatch_s,
                "gather": self.gather_s}

    def to_json(self) -> Dict[str, object]:
        d = {"patient": self.patient, "tier": self.tier,
             "status": self.status, "t_submit": self.t_submit,
             "t_retire": self.t_retire, "batch_n": self.batch_n,
             "e2e_s": self.e2e_s, "service_s": self.service_s}
        d.update(self.stage_seconds())
        return d


class SpanRecorder:
    """Bounded sink for retired-query spans + running per-stage
    aggregates.  ``record()`` is called from the server's retire path
    under no lock of its own (the recorder carries one); everything it
    does is O(1).

    ``attribution()`` answers the controller-facing question: across
    the retained horizon, what fraction of query-seconds went to each
    stage, and how much of measured end-to-end latency do the
    measured stages explain (``coverage`` — the bench gates this at
    >= 0.9, so attribution is checked against reality, not assumed).
    """

    def __init__(self, keep: int = 2048):
        self.keep = int(keep)
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=self.keep)
        self.n_spans = 0
        self.n_by_status: Dict[str, int] = {}
        self._stage_sum: Dict[str, float] = {s: 0.0 for s in STAGES}
        self._e2e_sum = 0.0
        self._e2e_hist = np.zeros(_sk.N_BINS)

    # ------------------------------------------------------------ write
    def record(self, span: SpanRecord) -> None:
        with self._lock:
            self._spans.append(span)
            self.n_spans += 1
            self.n_by_status[span.status] = \
                self.n_by_status.get(span.status, 0) + 1
            for stage, sec in span.stage_seconds().items():
                self._stage_sum[stage] += sec
            self._e2e_sum += span.e2e_s
            self._e2e_hist[_sk.bin_index(span.e2e_s)] += 1.0

    # ------------------------------------------------------------- read
    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._spans)

    def stage_seconds(self) -> Dict[str, float]:
        """Total seconds attributed to each stage, all spans ever."""
        with self._lock:
            return dict(self._stage_sum)

    def attribution(self) -> Dict[str, object]:
        """Per-stage share of total query-seconds + coverage of the
        measured end-to-end time."""
        with self._lock:
            sums = dict(self._stage_sum)
            e2e = self._e2e_sum
            n = self.n_spans
            by_status = dict(self.n_by_status)
        measured = sum(sums.values())
        return {
            "n_spans": n,
            "by_status": by_status,
            "stage_seconds": sums,
            "stage_frac": {s: (v / e2e if e2e > 0 else 0.0)
                           for s, v in sums.items()},
            "e2e_seconds": e2e,
            "mean_e2e_s": e2e / n if n else 0.0,
            "coverage": measured / e2e if e2e > 0 else 0.0,
        }

    def e2e_quantile(self, pct: float) -> float:
        with self._lock:
            return _sk.quantile_from_counts(self._e2e_hist, pct)

    # ------------------------------------------------------------ export
    def export_jsonl(self, path: str) -> int:
        """Dump the retained spans as JSON-lines; returns the count."""
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_json()) + "\n")
        return len(spans)
