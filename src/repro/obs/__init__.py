"""Observability plane: O(1) mergeable telemetry sketches, per-query
span tracing, and the Prometheus/JSONL export surface."""
from repro.obs.sketch import (EDGES, N_BINS, REL_ERR_BOUND,
                              WindowedSketch, quantile_from_counts)
from repro.obs.spans import (SERVICE_STAGES, STAGES, SpanRecord,
                             SpanRecorder, collect, note)
from repro.obs.export import MetricsExporter, start_metrics_server

__all__ = [
    "EDGES", "N_BINS", "REL_ERR_BOUND", "WindowedSketch",
    "quantile_from_counts",
    "SERVICE_STAGES", "STAGES", "SpanRecord", "SpanRecorder",
    "collect", "note",
    "MetricsExporter", "start_metrics_server",
]
