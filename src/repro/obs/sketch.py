"""Mergeable windowed telemetry sketch: O(1) memory per sensor.

Two primitives back the observability plane:

* ``LogHistogram`` — a fixed set of log-spaced latency bins shared by
  every histogram in the process.  Quantiles come back as the
  geometric midpoint of the hit bin, so the relative error is bounded
  by ``REL_ERR_BOUND`` (= sqrt(growth) - 1, ~5.8%) regardless of how
  many samples were folded in.  Two histograms merge by elementwise
  sum — the property that makes per-tier (and, next, per-host)
  telemetry composable.

* ``WindowedSketch`` — a ring of ``n_buckets`` sub-window buckets
  aligned to the ABSOLUTE time grid (bucket k covers
  ``[k*bucket_width, (k+1)*bucket_width)``), each holding exact event
  counters (arrivals / served / shed / failed / SLO violations /
  latency sum) plus one log histogram of served latencies.  Recording
  advances the ring against the newest bucket seen and zeroes
  overtaken slots, so memory is a CONSTANT ``n_buckets x n_bins``
  block no matter how long the trace runs — the deque window it
  replaces was O(window events).

Exactness contract: counts, violation rate and arrival rate are EXACT
for events inside the retained grid range (violations are classified
against the SLO at record time and stored as counters, never
re-derived from the histogram).  Only three things are coarsened, each
by at most ONE bucket width: window expiry, ``since=`` cuts (resolved
to whole buckets strictly after ``since``), and the network-calculus
T_q bound (each bucket's arrivals are grouped at their in-bucket MEAN
time, reconstructed from a per-bucket timestamp-sum counter, so the
bucketed bound tracks the raw-trace bound within +-``bucket_width``).
p50/p99 inherit the histogram's relative-error bound.  Because grids are absolute, two sketches with the same
(window, n_buckets) merge by aligned elementwise sum.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np

# ------------------------------------------------------ histogram bins
# log-spaced latency bins covering 100 us .. 100 s; everything in the
# serving stack (sub-ms flushes to watchdog-deadline stalls) lands in
# the core range, with explicit under/overflow bins for the rest
LAT_LO = 1e-4
LAT_HI = 100.0
GROWTH = 1.12
N_CORE = int(math.ceil(math.log(LAT_HI / LAT_LO) / math.log(GROWTH)))
# bin 0 = underflow [0, LAT_LO); bins 1..N_CORE = core; last = overflow
N_BINS = N_CORE + 2
EDGES = LAT_LO * GROWTH ** np.arange(N_CORE + 1)
# representative value per bin: geometric midpoint (worst-case
# relative error sqrt(GROWTH) - 1 for any value inside the bin)
REPS = np.empty(N_BINS)
REPS[0] = LAT_LO / 2.0
REPS[1:-1] = EDGES[:-1] * math.sqrt(GROWTH)
REPS[-1] = LAT_HI
REL_ERR_BOUND = math.sqrt(GROWTH) - 1.0


def bin_index(value: float) -> int:
    """Histogram bin for a latency value (negative values clamp to the
    underflow bin — a skewed clock must never throw off the sensor)."""
    if value < LAT_LO:
        return 0
    return int(np.searchsorted(EDGES, value, side="right"))


def quantile_from_counts(counts: np.ndarray, pct: float) -> float:
    """``np.percentile``-flavoured read of a bin-count vector: the
    representative value of the bin holding the rank-``pct`` sample."""
    total = float(counts.sum())
    if total <= 0:
        return 0.0
    rank = pct / 100.0 * (total - 1.0)
    cum = np.cumsum(counts)
    idx = int(np.searchsorted(cum, rank, side="right"))
    return float(REPS[min(idx, N_BINS - 1)])


# ------------------------------------------------------- counter layout
# ARR_T_SUM accumulates the raw arrival timestamps per bucket, so reads
# can reconstruct each bucket's arrivals at their in-bucket MEAN time —
# the two-sided (error << bucket width) grouping the T_q bound uses
# instead of the always-late bucket start
ARRIVALS, SERVED, SHED, FAILED, VIOLATIONS, LAT_SUM, ARR_T_SUM = range(7)
N_COUNTERS = 7


class WindowedSketch:
    """Ring of sub-window buckets on the absolute time grid.  All
    methods are unsynchronised — the owning telemetry object holds the
    lock."""

    __slots__ = ("window", "n_buckets", "bucket_width", "counts",
                 "hist", "k_hwm", "hwm", "t0")

    def __init__(self, window_seconds: float, n_buckets: int = 128):
        if n_buckets < 2:
            raise ValueError("n_buckets must be >= 2")
        self.window = float(window_seconds)
        self.n_buckets = int(n_buckets)
        self.bucket_width = self.window / self.n_buckets
        self.counts = np.zeros((self.n_buckets, N_COUNTERS))
        self.hist = np.zeros((self.n_buckets, N_BINS))
        self.k_hwm: Optional[int] = None   # newest bucket index seen
        self.hwm = -float("inf")           # newest raw event time seen
        self.t0: Optional[float] = None    # first event time ever seen

    # ------------------------------------------------------------ write
    def _bucket_of(self, t: float) -> int:
        return int(math.floor(t / self.bucket_width))

    def _slot(self, t: float) -> Optional[int]:
        """Ring slot for an event at ``t``; advances/zeroes the ring
        when ``t`` opens a newer bucket, returns None when the event is
        already a full window behind the newest bucket (the sketch
        analogue of the deque's record-time reject)."""
        k = self._bucket_of(t)
        if self.k_hwm is None:
            self.k_hwm = k
        elif k > self.k_hwm:
            gap = k - self.k_hwm
            if gap >= self.n_buckets:
                self.counts[:] = 0.0
                self.hist[:] = 0.0
            else:
                idx = np.arange(self.k_hwm + 1, k + 1) % self.n_buckets
                self.counts[idx] = 0.0
                self.hist[idx] = 0.0
            self.k_hwm = k
        elif k <= self.k_hwm - self.n_buckets:
            return None
        if self.t0 is None:
            self.t0 = t
        self.hwm = max(self.hwm, t)
        return k % self.n_buckets

    def add(self, kind: int, t: float, latency: Optional[float] = None,
            violated: bool = False) -> bool:
        """Record one event; returns False when it was too old to keep."""
        slot = self._slot(t)
        if slot is None:
            return False
        self.counts[slot, kind] += 1.0
        if kind == ARRIVALS:
            self.counts[slot, ARR_T_SUM] += t
        if kind == SERVED and latency is not None:
            self.counts[slot, LAT_SUM] += float(latency)
            if violated:
                self.counts[slot, VIOLATIONS] += 1.0
            self.hist[slot, bin_index(float(latency))] += 1.0
        return True

    # ------------------------------------------------------------- read
    def _live(self, now: float, since: Optional[float] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
        """(bucket indices, ring slots) retained at ``now``, optionally
        cut to buckets starting strictly after ``since``.  Both cuts
        resolve at bucket granularity (error <= one bucket width)."""
        empty = (np.empty(0, np.int64), np.empty(0, np.int64))
        if self.k_hwm is None:
            return empty
        k_hi = max(self._bucket_of(now), self.k_hwm)
        k_lo = k_hi - self.n_buckets + 1
        # data older than the ring was zeroed on advance
        k_lo = max(k_lo, self.k_hwm - self.n_buckets + 1)
        if since is not None:
            k_lo = max(k_lo, self._bucket_of(since) + 1)
        if k_lo > self.k_hwm:
            return empty
        ks = np.arange(k_lo, self.k_hwm + 1)
        return ks, ks % self.n_buckets

    def totals(self, now: float, since: Optional[float] = None
               ) -> np.ndarray:
        """Summed counter vector over the live range."""
        _, slots = self._live(now, since)
        if not len(slots):
            return np.zeros(N_COUNTERS)
        return self.counts[slots].sum(axis=0)

    def histogram(self, now: float, since: Optional[float] = None
                  ) -> np.ndarray:
        """Merged latency bin counts over the live range."""
        _, slots = self._live(now, since)
        if not len(slots):
            return np.zeros(N_BINS)
        return self.hist[slots].sum(axis=0)

    def quantile(self, pct: float, now: float,
                 since: Optional[float] = None) -> float:
        return quantile_from_counts(self.histogram(now, since), pct)

    def _bucket_arrivals(self, now: float, since: Optional[float]
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """(mean arrival time, count) per OCCUPIED live bucket.  Means
        are strictly increasing across buckets (each lies inside its
        own bucket), so the grouped trace is sorted."""
        ks, slots = self._live(now, since)
        if not len(slots):
            return np.empty(0), np.empty(0)
        n = self.counts[slots, ARRIVALS]
        occ = n > 0
        if not occ.any():
            return np.empty(0), np.empty(0)
        means = self.counts[slots, ARR_T_SUM][occ] / n[occ]
        return means, n[occ]

    def arrival_times(self, now: float,
                      since: Optional[float] = None) -> np.ndarray:
        """Coarsened reconstruction of the arrival trace: each bucket's
        arrivals placed at their in-bucket MEAN time (the same
        grouping the bucketed T_q bound uses)."""
        means, n = self._bucket_arrivals(now, since)
        return np.repeat(means, n.astype(np.int64))

    def latency_values(self, now: float,
                       since: Optional[float] = None) -> np.ndarray:
        """Approximate latency samples reconstructed from the merged
        histogram (each sample at its bin's representative value)."""
        h = self.histogram(now, since).astype(np.int64)
        return np.repeat(REPS, h)

    def queueing_bound(self, mu: float, T0: float, now: float,
                       since: Optional[float] = None) -> float:
        """Exact network-calculus T_q bound on the COARSENED trace
        (each bucket's arrivals grouped at their in-bucket mean time),
        computed straight from the bucket counters in O(n_buckets^2).

        On the grouped trace the sup over burst sizes is attained on a
        contiguous full-bucket range [i, j]: any window covering a
        partial group has the same span as the full range but fewer
        arrivals, so it is dominated.  Grouping moves each arrival by
        less than one bucket width, so the bound tracks the raw-trace
        bound within +- bucket_width (mean grouping keeps the error
        two-sided and small, where start-of-bucket grouping would bias
        it a full bucket width high)."""
        means, n = self._bucket_arrivals(now, since)
        if not len(n):
            return 0.0
        if mu <= 0:
            return float("inf")
        cum = np.concatenate([[0.0], np.cumsum(n)])
        best = 1.0 / mu
        for i in range(len(means)):
            cand = (cum[i + 1:] - cum[i]) / mu - (means[i:] - means[i])
            best = max(best, float(cand.max()))
        return float(T0 + max(best, 0.0))

    # ------------------------------------------------------------ merge
    def absorb(self, other: "WindowedSketch") -> None:
        """Fold ``other`` into self (aligned elementwise sum).  Both
        grids are absolute, so buckets align by index; whatever falls
        behind the merged ring's span is dropped, exactly as if the
        events had been fed to one sketch."""
        if (other.window != self.window
                or other.n_buckets != self.n_buckets):
            raise ValueError("can only merge sketches with identical "
                             "(window_seconds, n_buckets)")
        if other.k_hwm is None:
            return
        if self.k_hwm is None or other.k_hwm > self.k_hwm:
            # advance our ring (zeroing overtaken slots) via _slot on
            # the other's newest bucket MIDPOINT (robust to float
            # rounding at the bucket boundary)
            self._slot((other.k_hwm + 0.5) * self.bucket_width)
        self.hwm = max(self.hwm, other.hwm)
        if other.t0 is not None:
            self.t0 = other.t0 if self.t0 is None \
                else min(self.t0, other.t0)
        k_lo = max(other.k_hwm - other.n_buckets + 1,
                   self.k_hwm - self.n_buckets + 1)
        if k_lo > other.k_hwm:
            return
        ks = np.arange(k_lo, other.k_hwm + 1)
        src = ks % other.n_buckets
        dst = ks % self.n_buckets
        self.counts[dst] += other.counts[src]
        self.hist[dst] += other.hist[src]

    @classmethod
    def merged(cls, parts: Sequence["WindowedSketch"]
               ) -> "WindowedSketch":
        parts = list(parts)
        if not parts:
            raise ValueError("nothing to merge")
        out = cls(parts[0].window, parts[0].n_buckets)
        for p in parts:
            out.absorb(p)
        return out
