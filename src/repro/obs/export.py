"""Export plane: Prometheus text exposition + JSONL trace dumps.

``MetricsExporter`` is a pull-style renderer over whatever serving
objects it was attached to — any subset of

* ``server``     — ``serving.server.EnsembleServer`` (stats, queue
                   depth/admission, micro-batcher aggregates);
* ``telemetry``  — ``SloTelemetry`` or ``TieredTelemetry`` (window
                   gauges; tiered telemetry exports per-tier labeled
                   series plus the merged fleet view, and the sketch's
                   latency histogram goes out as a native Prometheus
                   cumulative ``_bucket{le=...}`` series);
* ``controller`` — ``AdaptiveController``/``TieredController``
                   (decision counters);
* ``tracer``     — ``obs.spans.SpanRecorder`` (per-stage attributed
                   seconds, span counts by status);
* ``service``    — ``EnsembleService`` (dispatch/H2D counters);
* ``tiers``      — ``control.tiers.TieredEnsemble`` (per-lane rung /
                   ensemble size gauges).

``render()`` walks the attached objects and returns the exposition
text; ``dump(path)`` writes it; ``start_metrics_server`` serves it at
``/metrics`` from a stdlib ``http.server`` thread (no third-party
dependency).  Nothing here holds long-lived state of its own — every
scrape reads the live objects, so a scrape is always current and an
exporter can be attached/dropped freely.
"""
from __future__ import annotations

import http.server
import json
import threading
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.obs import sketch as _sk

_NAMESPACE = "holmes"


def _fmt_labels(labels: Dict[str, object]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels.items())
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v != v:
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == -float("inf"):
        return "-Inf"
    return repr(float(v))


class MetricsExporter:
    def __init__(self, server=None, telemetry=None, controller=None,
                 tracer=None, service=None, tiers=None,
                 namespace: str = _NAMESPACE):
        self.server = server
        self.telemetry = telemetry
        self.controller = controller
        self.tracer = tracer
        self.service = service
        self.tiers = tiers
        self.namespace = namespace

    # ------------------------------------------------------- rendering
    def _emit(self, lines: List[str], name: str, mtype: str, help_: str,
              samples: Iterable[Tuple[Dict[str, object], float]],
              suffix: str = "") -> None:
        full = f"{self.namespace}_{name}"
        lines.append(f"# HELP {full} {help_}")
        lines.append(f"# TYPE {full} {mtype}")
        for labels, value in samples:
            lines.append(
                f"{full}{suffix}{_fmt_labels(labels)} {_fmt_value(value)}")

    def _server_lines(self, lines: List[str]) -> None:
        s = self.server
        st = s.stats
        self._emit(lines, "served_total", "counter",
                   "Retired queries (including failures)",
                   [({}, st.served)])
        self._emit(lines, "slo_violations_total", "counter",
                   "Retired queries over the SLO", [({}, st.slo_violations)])
        self._emit(lines, "failed_total", "counter",
                   "NaN-scored retirements", [({}, st.failed)])
        self._emit(lines, "stalls_total", "counter",
                   "Watchdog-killed co-batches", [({}, st.stalls)])
        self._emit(lines, "shed_total", "counter",
                   "Rejected queries by tier",
                   [({"tier": str(t)}, n)
                    for t, n in sorted(st.rejected.items(),
                                       key=lambda kv: str(kv[0]))]
                   or [({}, st.shed)])
        q = s.q
        self._emit(lines, "queue_depth", "gauge",
                   "Queued ingest items", [({}, q.qsize())])
        self._emit(lines, "queue_unfinished", "gauge",
                   "Outstanding work (queued + coalescing + in-flight)",
                   [({}, q.unfinished_tasks)])
        self._emit(lines, "queue_admitted_total", "counter",
                   "Admissions into the shed queue", [({}, q.n_admitted)])
        self._emit(lines, "queue_evicted_total", "counter",
                   "Priority evictions under overrun", [({}, q.n_evicted)])
        self._emit(lines, "queue_rejected_total", "counter",
                   "Refused admissions", [({}, q.n_rejected)])
        b = s.batcher.stats
        self._emit(lines, "batch_flushes_total", "counter",
                   "Micro-batch flushes", [({}, b.n_flushes)])
        self._emit(lines, "batch_items_total", "counter",
                   "Queries through the micro-batcher", [({}, b.n_items)])
        self._emit(lines, "batch_mean_size", "gauge",
                   "Mean co-batch size", [({}, b.mean_batch)])

    def _telemetry_lines(self, lines: List[str]) -> None:
        tel = self.telemetry
        slices = getattr(tel, "slices", None)
        views = ([("fleet", tel.fleet)] + sorted(slices.items())) \
            if slices is not None else [("fleet", tel)]
        gauges = []
        for name, view in views:
            snap = view.snapshot()
            labels = {"tier": name}
            gauges.append((labels, snap))
        for key, help_ in (
                ("arrival_rate", "Arrivals/s over the sliding window"),
                ("violation_rate", "SLO violation fraction (window)"),
                ("p50", "Median served latency (window, seconds)"),
                ("p99", "p99 served latency (window, seconds)")):
            self._emit(lines, f"window_{key}", "gauge", help_,
                       [(labels, getattr(snap, key))
                        for labels, snap in gauges])
        self._emit(lines, "window_served", "gauge",
                   "Served queries in the window",
                   [(labels, snap.n_served) for labels, snap in gauges])
        self._emit(lines, "window_shed", "gauge",
                   "Shed queries in the window",
                   [(labels, snap.n_shed) for labels, snap in gauges])
        self._emit(lines, "window_failed", "gauge",
                   "NaN retirements in the window",
                   [(labels, snap.n_failed) for labels, snap in gauges])
        # sketch-native latency histogram (fleet view), as a Prometheus
        # cumulative bucket series
        fleet = views[0][1]
        hist = None
        tap = getattr(fleet, "latency_histogram", None)
        if tap is not None:
            hist = tap()
        if hist is not None:
            cum = np.cumsum(hist)
            samples = [({"le": f"{edge:.6g}"}, cum[i])
                       for i, edge in enumerate(_sk.EDGES)]
            samples.append(({"le": "+Inf"}, cum[-1]))
            full = f"{self.namespace}_latency_seconds"
            lines.append(f"# HELP {full} Served latency (window)")
            lines.append(f"# TYPE {full} histogram")
            for labels, value in samples:
                lines.append(
                    f"{full}_bucket{_fmt_labels(labels)} "
                    f"{_fmt_value(value)}")
            lines.append(f"{full}_count {_fmt_value(cum[-1])}")

    def _controller_lines(self, lines: List[str]) -> None:
        counts = self.controller.decision_counts()
        self._emit(lines, "controller_decisions_total", "counter",
                   "Actions taken by the adaptive controller",
                   [({"decision": k}, v)
                    for k, v in sorted(counts.items())])

    def _tracer_lines(self, lines: List[str]) -> None:
        att = self.tracer.attribution()
        self._emit(lines, "spans_total", "counter",
                   "Retired-query spans by status",
                   [({"status": k}, v)
                    for k, v in sorted(att["by_status"].items())])
        self._emit(lines, "span_stage_seconds_total", "counter",
                   "Query-seconds attributed to each lifecycle stage",
                   [({"stage": k}, v)
                    for k, v in sorted(att["stage_seconds"].items())])
        self._emit(lines, "span_coverage", "gauge",
                   "Fraction of e2e latency explained by measured stages",
                   [({}, att["coverage"])])

    def _service_lines(self, lines: List[str]) -> None:
        svc = self.service
        self._emit(lines, "dispatches_total", "counter",
                   "Device dispatches issued",
                   [({}, getattr(svc, "dispatch_count", 0))])
        self._emit(lines, "h2d_bytes_total", "counter",
                   "Host-to-device bytes shipped by marshaling",
                   [({}, getattr(svc, "h2d_bytes", 0))])
        self._emit(lines, "marshal_seconds_total", "counter",
                   "Seconds spent marshaling flushes",
                   [({}, getattr(svc, "marshal_seconds", 0.0))])

    def _tiers_lines(self, lines: List[str]) -> None:
        metrics = self.tiers.lane_metrics()
        self._emit(lines, "lane_rung", "gauge",
                   "Ladder rung per tier lane",
                   [({"tier": t}, m["rung"])
                    for t, m in sorted(metrics.items())])
        self._emit(lines, "lane_members", "gauge",
                   "Active ensemble size per tier lane",
                   [({"tier": t}, m["n_members"])
                    for t, m in sorted(metrics.items())])

    def render(self) -> str:
        lines: List[str] = []
        if self.server is not None:
            self._server_lines(lines)
        if self.telemetry is not None:
            self._telemetry_lines(lines)
        if self.controller is not None:
            self._controller_lines(lines)
        if self.tracer is not None:
            self._tracer_lines(lines)
        if self.service is not None:
            self._service_lines(lines)
        if self.tiers is not None:
            self._tiers_lines(lines)
        return "\n".join(lines) + "\n"

    # ---------------------------------------------------------- outputs
    def dump(self, path: str) -> str:
        text = self.render()
        with open(path, "w") as f:
            f.write(text)
        return text

    def summary(self) -> Dict[str, object]:
        """Machine-readable digest for benches (the BENCH_obs source)."""
        out: Dict[str, object] = {}
        if self.tracer is not None:
            out["attribution"] = self.tracer.attribution()
        if self.server is not None:
            st = self.server.stats
            out["server"] = {"served": st.served, "shed": st.shed,
                             "failed": st.failed, "stalls": st.stalls}
        if self.controller is not None:
            out["decisions"] = self.controller.decision_counts()
        return out


class _MetricsHandler(http.server.BaseHTTPRequestHandler):
    exporter: Optional[MetricsExporter] = None

    def do_GET(self):                                 # noqa: N802
        if self.path.rstrip("/") not in ("", "/metrics"):
            self.send_response(404)
            self.end_headers()
            return
        body = self.server.exporter.render().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):                     # quiet scrapes
        pass


def start_metrics_server(exporter: MetricsExporter, port: int = 0,
                         host: str = "127.0.0.1"):
    """Serve ``exporter.render()`` at ``/metrics`` on a daemon thread;
    returns the ``HTTPServer`` (``server_port`` has the bound port,
    call ``.shutdown()`` to stop)."""
    httpd = http.server.ThreadingHTTPServer((host, port), _MetricsHandler)
    httpd.exporter = exporter
    thread = threading.Thread(target=httpd.serve_forever, daemon=True,
                              name="repro-metrics")
    thread.start()
    return httpd


def write_spans_jsonl(tracer, path: str) -> int:
    """JSONL trace export (one span per line); returns the span count."""
    return tracer.export_jsonl(path)
