"""Version-compat shims over the jax API surface.

``shard_map`` moved from ``jax.experimental.shard_map`` to a top-level
export around jax 0.5; resolve it once here so every call site stays on
one import path.
"""
from __future__ import annotations

try:                                     # jax >= 0.5
    from jax import shard_map
except ImportError:                      # older jax
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
