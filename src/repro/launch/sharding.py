"""PartitionSpec rule engine: maps param/cache/input pytrees to
NamedShardings for the production mesh (DESIGN.md §5).

Megatron-style tensor parallelism on the "model" axis (column-sharded
QKV/up/gate, row-sharded O/down), vocab-sharded embeddings, expert
f-sharding for MoE, head-sharded SSD; batch shards over ("pod","data").

Every rule is divisibility-guarded: a dim that the model axis does not
divide falls back to replication (e.g. smollm's 15 query heads), so ANY
architecture lowers — suboptimally sharded beats un-lowerable.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# leaf-w parents whose LAST dim is column-sharded over "model"
_COL = {"wq", "wk", "wv", "gate", "up"}
# leaf-w parents whose -2 dim is row-sharded over "model"
_ROW = {"wo", "down"}
# replicated small params
_REPL = {"router", "w_dkv", "ckv_norm", "B_proj", "C_proj", "conv_B",
         "conv_C", "conv_bB", "conv_bC", "frontend_proj"}


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _mk(ndim: int, assignments) -> P:
    """assignments: {dim_index (may be negative): axis-or-tuple}"""
    spec = [None] * ndim
    for d, ax in assignments.items():
        spec[d % ndim] = ax
    return P(*spec)


def _div(shape, dim: int, size: int) -> bool:
    return size > 0 and shape[dim % len(shape)] % size == 0


def param_spec(path_keys: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ArchConfig, model_size: int) -> P:
    if not shape:
        return P()
    keys = path_keys
    leaf = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    nd = len(shape)

    def col(dim=-1):
        return _mk(nd, {dim: "model"}) if _div(shape, dim, model_size) \
            else P()

    if leaf in _REPL or parent in _REPL:
        return P()
    if leaf == "table":                       # [V, d] (possibly stacked)
        return col(-2)
    if leaf == "head":                        # [d, V]
        return col(-1)
    if leaf in ("w", "b") and parent in _COL:
        return col(-1)
    if leaf == "w" and parent in _ROW:
        return col(-2)
    if leaf == "b" and parent in _ROW:
        return P()
    if leaf == "wq":                          # MLA direct q [d, H*qk]
        return col(-1)
    if leaf == "wo":                          # MLA o proj [H*v, d]
        return col(-2)
    if leaf in ("w_uk", "w_uv"):              # [lora, H, dim]
        return _mk(nd, {-2: "model"}) if _div(shape, -2, model_size) \
            else P()
    if leaf in ("w_gate", "w_up"):            # [E, d, f]
        return col(-1)
    if leaf == "w_down":                      # [E, f, d]
        return col(-2)
    if leaf in ("z_proj", "x_proj", "dt_proj", "conv_x", "conv_bx",
                "A_log", "D", "dt_bias"):
        return col(-1)
    if leaf == "out_proj":                    # [di, d]
        return col(-2)
    if leaf == "scale" and parent == "norm" and "mixer" in keys:
        return col(-1)                        # mamba gated-norm over di
    return P()


def partition_params(shape_tree, cfg: ArchConfig, mesh,
                     model_size: Optional[int] = None):
    """model_size=1 => pure data parallelism (params fully replicated);
    the dp_only §Perf variant for small models shards the batch over the
    model axis instead (see _batch_axes_spec(dp_only=True))."""
    model_size = model_size if model_size is not None \
        else mesh.shape["model"]

    def f(path, leaf):
        spec = param_spec(_path_keys(path), tuple(leaf.shape), cfg,
                          model_size)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, shape_tree)


# ------------------------------------------------------------- caches
def _batch_axes_spec(mesh, batch: int, dp_only: bool = False):
    names = (("pod", "data", "model") if dp_only else ("pod", "data"))
    axes = tuple(a for a in mesh.axis_names if a in names)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % total == 0:
        return axes if len(axes) > 1 else axes[0]
    return None                               # e.g. long_500k batch=1


def cache_spec(path_keys: Tuple[str, ...], shape: Tuple[int, ...],
               mesh, batch: int, dp_only: bool = False) -> P:
    leaf = path_keys[-1]
    nd = len(shape)
    b_ax = _batch_axes_spec(mesh, batch, dp_only)
    model = mesh.shape["model"]

    def mk(assign):
        ok = {}
        for d, ax in assign.items():
            if ax is None:
                continue
            if ax == "model" and (dp_only or not _div(shape, d, model)):
                continue           # dp_only: model axis carries batch
            ok[d] = ax
        return _mk(nd, ok)

    if leaf in ("k", "v"):                    # [.., B, M, kvH, hd]
        return mk({-4: b_ax, -2: "model"})
    if leaf in ("ckv", "krope"):              # [.., B, M, r]
        return mk({-3: b_ax})
    if leaf == "conv_x":                      # [.., B, K-1, di]
        return mk({-3: b_ax, -1: "model"})
    if leaf in ("conv_B", "conv_C"):
        return mk({-3: b_ax})
    if leaf == "ssm":                         # [.., B, H, P, N]
        return mk({-4: b_ax, -3: "model"})
    if leaf == "enc_out":                     # [B, T, d]
        return mk({-3: b_ax})
    return P()                                # pos, idx


def partition_cache(shape_tree, mesh, batch: int, dp_only: bool = False):
    def f(path, leaf):
        spec = cache_spec(_path_keys(path), tuple(leaf.shape), mesh,
                          batch, dp_only)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, shape_tree)


# ------------------------------------------------------------- inputs
def batch_input_sharding(mesh, batch: int, ndim: int,
                         dp_only: bool = False) -> NamedSharding:
    b_ax = _batch_axes_spec(mesh, batch, dp_only)
    spec = [None] * ndim
    if b_ax is not None:
        spec[0] = b_ax
    return NamedSharding(mesh, P(*spec))


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def partition_batch(batch_tree, mesh, dp_only: bool = False):
    def f(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        return batch_input_sharding(mesh, b, leaf.ndim, dp_only)
    return jax.tree.map(f, batch_tree)
