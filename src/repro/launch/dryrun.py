import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, with zero allocation (ShapeDtypeStruct inputs).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--out results.json]

Proves the distribution config is coherent: sharding mismatches, compile
OOMs and unsupported collectives all surface here.  Emits
memory_analysis / cost_analysis / collective-bytes for §Roofline.
"""
import argparse
import json
import re
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, get_shape
from repro.launch import sharding as shd
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_model
from repro.training.optimizer import AdamWState
from repro.training.train_loop import (make_serve_prefill, make_serve_step,
                                       make_train_step)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the (partitioned)
    HLO.  Keyed by op kind."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*)", line)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVES:
            # match the op name with word boundaries: "all-reduce(",
            # "all-reduce-start(" etc., but not fusion names
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                shape_part = rhs.split(kind)[0]
                out[kind] += _shape_bytes(shape_part)
                break
    return out


def build_step(cfg, shape, rt):
    if shape.kind == "train":
        return make_train_step(cfg, rt, sp.default_optimizer())
    if shape.kind == "prefill":
        return make_serve_prefill(cfg, rt)
    return make_serve_step(cfg, rt)


def build_shardings(cfg, shape, rt, mesh, abstract_args,
                    dp_only: bool = False):
    """dp_only (§Perf): pure data parallelism — params replicated, batch
    sharded over EVERY mesh axis (the right layout for small models whose
    head/ff dims do not usefully shard 16 ways)."""
    model_size = 1 if dp_only else None
    if shape.kind == "train":
        params, opt_state, batch = abstract_args
        p_sh = shd.partition_params(params, cfg, mesh, model_size)
        o_sh = AdamWState(step=shd.replicated(mesh),
                          mu=p_sh, nu=p_sh)
        return (p_sh, o_sh, shd.partition_batch(batch, mesh, dp_only))
    if shape.kind == "prefill":
        params, batch = abstract_args
        return (shd.partition_params(params, cfg, mesh, model_size),
                shd.partition_batch(batch, mesh, dp_only))
    params, cache, token = abstract_args
    return (shd.partition_params(params, cfg, mesh, model_size),
            shd.partition_cache(cache, mesh, shape.global_batch, dp_only),
            shd.batch_input_sharding(mesh, shape.global_batch, 1,
                                     dp_only))


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               verbose: bool = True, absorbed_mla: bool = False,
               unroll: bool = False, dp_only: bool = False,
               rt_overrides: Optional[Dict] = None) -> Dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rt = sp.runtime_for(cfg, shape, mesh.shape["model"],
                        absorbed_mla=absorbed_mla)
    if unroll:
        rt = _dc.replace(rt, scan_unroll=True)
    if rt_overrides:
        rt = _dc.replace(rt, **rt_overrides)
    if rt.moe_impl == "shard_map":
        rt = _dc.replace(rt, mesh=mesh)
    t0 = time.time()
    abstract_args = sp.input_specs(cfg, shape, rt)
    step = build_step(cfg, shape, rt)
    in_sh = build_shardings(cfg, shape, rt, mesh, abstract_args, dp_only)

    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh).lower(*abstract_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0))
        if cost else 0.0,
        "collective_bytes": coll,
        "collective_total": float(sum(coll.values())),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "kv_mult": rt.kv_mult, "window": rt.window,
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: OK  "
              f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"coll={rec['collective_total']:.3e} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: args={rec['argument_bytes']:.3e} "
              f"out={rec['output_bytes']:.3e} temp={rec['temp_bytes']:.3e} "
              f"peak={rec['peak_bytes']:.3e}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_IDS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {sorted(SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--absorbed-mla", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = sorted(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(dryrun_one(
                        arch, shape, mp, absorbed_mla=args.absorbed_mla))
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((arch, shape, mp, repr(e)[:500]))
                    print(f"[dryrun] {arch} x {shape} x "
                          f"{'2x16x16' if mp else '16x16'}: FAIL {e!r}",
                          file=sys.stderr)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results,
                       "failures": [list(f_) for f_ in failures]}, f,
                      indent=1)
    print(f"[dryrun] {len(results)} OK, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
