import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Roofline analysis from the compiled dry-run (EXPERIMENTS.md §Roofline).

Terms per (arch x shape) on the single-pod mesh, all PER-DEVICE:
    compute    = HLO_FLOPs / 197e12           (TPU v5e bf16 peak)
    memory     = HLO_bytes / 819e9            (HBM bandwidth)
    collective = collective_bytes / 50e9      (ICI per link)

Methodology note: XLA cost_analysis counts a while-loop body ONCE
regardless of trip count, so layer-scanned models under-report.  We
therefore lower two reduced-layer clones with scan_unroll=True and
extrapolate linearly in the repeating-unit count:
    m(full) = m(A) + (units_full - units_A) * (m(B) - m(A)) / (uB - uA)
which is exact for the per-layer terms and keeps embed/logits in the
intercept.  (Calibrated: a [1024,512]x[512,2048] sharded matmul reports
exactly flops/n_devices; scan bodies report once.)
"""
import argparse
import dataclasses
import json
import sys
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # TPU v5e bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

from repro.configs.base import ArchConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, get_shape


def probe_pair(cfg: ArchConfig) -> Tuple[ArchConfig, float, ArchConfig,
                                         float, float]:
    """(cfg_A, units_A, cfg_B, units_B, units_full)."""
    r = dataclasses.replace
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        return (r(cfg, num_layers=k), 1.0, r(cfg, num_layers=2 * k), 2.0,
                cfg.num_layers / k)
    if cfg.family == "encdec":
        return (r(cfg, enc_layers=2, dec_layers=2, num_layers=4), 2.0,
                r(cfg, enc_layers=4, dec_layers=4, num_layers=8), 4.0,
                float(cfg.enc_layers))
    if cfg.family == "moe" and cfg.moe.first_dense_layers:
        fd = cfg.moe.first_dense_layers
        return (r(cfg, num_layers=fd + 2), 2.0, r(cfg, num_layers=fd + 4),
                4.0, float(cfg.num_layers - fd))
    return (r(cfg, num_layers=2), 2.0, r(cfg, num_layers=4), 4.0,
            float(cfg.num_layers))


_METRICS = ("flops", "bytes_accessed", "collective_total")


def _extrapolate(mA: Dict, uA: float, mB: Dict, uB: float,
                 uF: float) -> Dict:
    out = {}
    for k in _METRICS:
        slope = (mB[k] - mA[k]) / (uB - uA)
        out[k] = mA[k] + (uF - uA) * slope
        out[k + "_per_layer"] = slope
    coll = {}
    for kind in mA["collective_bytes"]:
        slope = (mB["collective_bytes"][kind]
                 - mA["collective_bytes"][kind]) / (uB - uA)
        coll[kind] = mA["collective_bytes"][kind] + (uF - uA) * slope
    out["collective_bytes"] = coll
    return out


def model_flops(cfg: ArchConfig, shape) -> float:
    """MODEL_FLOPS (global): 6*N_active*D for train, 2*N_active*D for
    prefill, 2*N_active*B for one decode step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def roofline_one(arch: str, shape_name: str, multi_pod: bool = False,
                 verbose: bool = True, variant: str = "",
                 **dryrun_kw) -> Dict:
    from repro.launch import dryrun as dr
    from repro.configs import registry

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    cfgA, uA, cfgB, uB, uF = probe_pair(cfg)

    def run(probe_cfg):
        # temporarily register the probe clone under its own name
        registry._ARCHS[probe_cfg.name] = probe_cfg
        try:
            return dr.dryrun_one(probe_cfg.name, shape_name, multi_pod,
                                 verbose=False, unroll=True, **dryrun_kw)
        finally:
            registry._ARCHS.pop(probe_cfg.name, None)

    mA = run(dataclasses.replace(cfgA, name=arch + "#probeA"))
    mB = run(dataclasses.replace(cfgB, name=arch + "#probeB"))
    full = _extrapolate(mA, uA, mB, uB, uF)

    n_dev = 512 if multi_pod else 256
    terms = {
        "compute_s": full["flops"] / PEAK_FLOPS,
        "memory_s": full["bytes_accessed"] / HBM_BW,
        "collective_s": full["collective_total"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / n_dev
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "variant": variant,
        "hlo_flops_per_dev": full["flops"],
        "hlo_bytes_per_dev": full["bytes_accessed"],
        "collective_bytes_per_dev": full["collective_total"],
        "collective_breakdown": full["collective_bytes"],
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_dev": mf,
        "useful_ratio": mf / full["flops"] if full["flops"] else 0.0,
        "step_time_bound_s": max(terms.values()),
        "mfu_bound": mf / PEAK_FLOPS / max(terms.values())
        if max(terms.values()) else 0.0,
        "probe_compile_s": mA["compile_s"] + mB["compile_s"],
    }
    if verbose:
        print(f"[roofline] {arch} x {shape_name}"
              + (f" [{variant}]" if variant else "") + ": "
              f"compute={terms['compute_s']:.3e}s "
              f"memory={terms['memory_s']:.3e}s "
              f"collective={terms['collective_s']:.3e}s "
              f"dominant={rec['dominant']} "
              f"useful={rec['useful_ratio']:.2f} "
              f"mfu_bound={rec['mfu_bound']:.2%}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = sorted(SHAPES) if args.shape == "all" else [args.shape]
    results, failures = [], []
    for a in archs:
        for s in shapes:
            try:
                results.append(roofline_one(a, s, args.multi_pod))
            except Exception as e:  # noqa: BLE001
                failures.append((a, s, repr(e)[:300]))
                print(f"[roofline] {a} x {s}: FAIL {e!r}", file=sys.stderr)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f,
                      indent=1)
    print(f"[roofline] {len(results)} OK, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
