"""Ensemble parallelism: HOLMES' bagging ensemble (Eq. 5) as a first-class
distributed feature on the multi-pod mesh.

The composer picks an ensemble b*; homogeneous members (same architecture,
different weights — e.g. the per-lead / per-seed ECG ResNeXts, or LM zoo
replicas fine-tuned per modality) are STACKED along a leading member axis
and shard_map-ped over the "pod" axis: each pod serves its member(s) on
its own (data, model) submesh and the final prediction is ONE cross-pod
psum of the [batch, n_classes] score — Eq. 5 as a collective.

Heterogeneous members fall back to per-pod programs placed by
serving/placement.py (plan_pod_ensemble).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def stack_members(member_params: list):
    """[params_0, params_1, ...] -> stacked pytree with leading member
    axis (members must be structurally identical)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *member_params)


def ensemble_serve(member_apply: Callable, mesh, n_members: int
                   ) -> Callable:
    """Build the ensemble-parallel serving step.

    member_apply(params_one_member, batch) -> scores [B, C]
    Returns step(stacked_params, batch) -> bagged scores [B, C]
    with members sharded over "pod" (each pod computes its members
    locally, then one psum over "pod" completes Eq. 5).
    """
    n_pods = mesh.shape.get("pod", 1)
    assert n_members % max(n_pods, 1) == 0, (n_members, n_pods)

    def local(params_local, batch):
        # params_local: leading axis = members on THIS pod
        scores = jax.vmap(lambda p: member_apply(p, batch))(params_local)
        total = jnp.sum(scores, axis=0)                 # [B, C]
        if n_pods > 1:
            total = jax.lax.psum(total, "pod")
        return total / n_members                        # Eq. 5 mean

    param_spec = jax.tree.map(lambda _: P("pod"), {"_": 0})["_"] \
        if n_pods > 1 else P()

    def specs_for(tree):
        return jax.tree.map(lambda _: param_spec, tree)

    def step(stacked_params, batch):
        in_specs = (specs_for(stacked_params),
                    jax.tree.map(lambda _: P(), batch))
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=P())
        return fn(stacked_params, batch)

    return step


def dryrun_ensemble(n_members: int = 4, multi_pod: bool = True,
                    d: int = 512, verbose: bool = True) -> dict:
    """Compile the ensemble-parallel step on the production mesh with
    abstract member weights (a small MLP member as the stand-in)."""
    from repro.launch.mesh import make_production_mesh
    from repro.launch.dryrun import collective_bytes

    mesh = make_production_mesh(multi_pod=multi_pod)

    def member_apply(p, batch):
        h = jnp.tanh(batch["x"] @ p["w1"])
        return jax.nn.softmax(h @ p["w2"], axis=-1)

    member = {"w1": jax.ShapeDtypeStruct((d, d), jnp.bfloat16),
              "w2": jax.ShapeDtypeStruct((d, 2), jnp.bfloat16)}
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_members,) + s.shape, s.dtype),
        member)
    batch = {"x": jax.ShapeDtypeStruct((64, d), jnp.bfloat16)}

    step = ensemble_serve(member_apply, mesh, n_members)
    with mesh:
        compiled = jax.jit(step).lower(stacked, batch).compile()
    coll = collective_bytes(compiled.as_text())
    ca = compiled.cost_analysis()            # list-of-dicts on older jax
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    rec = {"mesh": "2x16x16" if multi_pod else "16x16",
           "n_members": n_members,
           "collective_bytes": coll,
           "flops": float(ca.get("flops", 0))}
    if verbose:
        print(f"[ensemble-parallel] {rec['mesh']} x {n_members} members: "
              f"OK, collectives {coll}")
    return rec


if __name__ == "__main__":
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    dryrun_ensemble(multi_pod=True)
