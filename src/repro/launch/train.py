"""Training launcher.

CPU-runnable end-to-end:   PYTHONPATH=src python -m repro.launch.train \
    --arch smollm-360m-reduced --steps 50 --batch 8 --seq 128
Production lowering check: add --dry-run (delegates to launch/dryrun.py,
which forces the 512-device host platform in its own process).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time

import jax


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-reduced")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile on the production mesh instead")
    args = ap.parse_args(argv)

    if args.dry_run:
        return subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", args.arch.replace("-reduced", ""),
             "--shape", "train_4k", "--both-meshes"])

    from repro.configs.registry import get_config
    from repro.models.runtime import RuntimeOptions
    from repro.training import checkpoint
    from repro.training.data import lm_batches, audio_frames
    from repro.training.train_loop import train_lm

    cfg = get_config(args.arch)
    rt = RuntimeOptions()
    base = lm_batches(cfg.vocab_size, args.batch, args.seq,
                      seed=args.seed)

    def batches():
        for b in base:
            if cfg.n_prefix_tokens and cfg.frontend_dim:
                b = dict(b)
                b["prefix_embeds"] = audio_frames(
                    args.batch, cfg.n_prefix_tokens, cfg.frontend_dim,
                    seed=args.seed)
                if cfg.family == "vlm":
                    import numpy as np
                    b["labels"] = np.concatenate(
                        [np.full((args.batch, cfg.n_prefix_tokens), -1,
                                 np.int32), b["labels"]], axis=1)
            yield b

    t0 = time.time()
    params, losses = train_lm(
        cfg, rt, batches(), steps=args.steps, lr=args.lr, seed=args.seed,
        callback=lambda i, l: print(f"step {i:5d} loss {l:.4f}",
                                    flush=True))
    dt = time.time() - t0
    print(json.dumps({"arch": args.arch, "steps": args.steps,
                      "first_loss": losses[0], "last_loss": losses[-1],
                      "wall_s": round(dt, 1),
                      "steps_per_s": round(args.steps / dt, 3)}))
    if args.checkpoint:
        checkpoint.save(args.checkpoint, params,
                        {"arch": args.arch, "steps": args.steps})
        print(f"checkpoint -> {args.checkpoint}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
