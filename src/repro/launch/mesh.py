"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:   (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests (same axis names, trivial extents)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axes that shard the global batch."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out
