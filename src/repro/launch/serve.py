"""Serving launcher: batched prefill + decode loop for any --arch
(reduced variants run end-to-end on CPU).

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-4b-reduced --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b-reduced")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config
    from repro.models.api import get_model
    from repro.models.runtime import RuntimeOptions

    cfg = get_config(args.arch)
    rt = RuntimeOptions()
    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key, cfg, rt)

    toks = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    pe = None
    if cfg.n_prefix_tokens and cfg.frontend_dim:
        pe = jax.random.normal(
            key, (args.batch, cfg.n_prefix_tokens, cfg.frontend_dim))

    prefill = jax.jit(lambda p, t, e: model.prefill(
        p, t, cfg, rt, prefix_embeds=e,
        max_len=args.prompt_len + args.new_tokens + 1
        + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)))
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, cfg, rt))

    t0 = time.time()
    logits, cache = prefill(params, toks, pe)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.stack(out, axis=1)
    print(f"generated tokens[0,:16]: {gen[0,:16].tolist()}")
    print(json.dumps({
        "arch": args.arch, "batch": args.batch,
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(args.batch * args.new_tokens
                                  / t_decode, 1),
        "decode_ms_per_token": round(1000 * t_decode / args.new_tokens,
                                     2)}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
