"""Abstract input construction for the dry-run: ShapeDtypeStruct stand-ins
for params, optimizer state, batches and KV caches — weak-type-correct,
shardable, zero allocation.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.models.api import get_model
from repro.models.runtime import RuntimeOptions
from repro.training.optimizer import AdamW, constant_schedule


def runtime_for(cfg: ArchConfig, shape: InputShape, model_axis: int,
                dtype=jnp.bfloat16, absorbed_mla: bool = False
                ) -> RuntimeOptions:
    """Pick lowering-time options for an (arch, shape, mesh) combo."""
    kv_mult = 1
    if cfg.n_kv_heads and cfg.n_kv_heads < model_axis \
            and model_axis % cfg.n_kv_heads == 0:
        kv_mult = model_axis // cfg.n_kv_heads
    window = 0
    if shape.name == "long_500k" and cfg.n_heads:
        # attention archs need sub-quadratic handling at 524k: sliding
        # window (dense/moe/vlm/encdec and the hybrid's shared attention).
        window = cfg.long_context_window
    return RuntimeOptions(kv_mult=kv_mult, impl="xla",
                          remat=(shape.kind == "train"), window=window,
                          absorbed_mla=absorbed_mla, dtype=dtype)


def param_shapes(cfg: ArchConfig, rt: RuntimeOptions):
    model = get_model(cfg)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: model.init(k, cfg, rt), key)


def opt_shapes(params, opt: AdamW):
    return jax.eval_shape(opt.init, params)


def default_optimizer() -> AdamW:
    return AdamW(lr=constant_schedule(3e-4))


def _text_len(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.family == "vlm":
        return max(1, seq_len - cfg.n_prefix_tokens)
    return seq_len


def batch_specs(cfg: ArchConfig, shape: InputShape) -> Dict:
    """Abstract training/prefill batch for one global step."""
    B, S = shape.global_batch, shape.seq_len
    St = _text_len(cfg, S)
    out = {"tokens": jax.ShapeDtypeStruct((B, St), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.n_prefix_tokens and cfg.frontend_dim:
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.bfloat16)
    return out


def cache_shapes(cfg: ArchConfig, rt: RuntimeOptions, shape: InputShape):
    model = get_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(cfg, rt, shape.global_batch,
                                 shape.seq_len))


def decode_token_spec(shape: InputShape):
    return jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)


def input_specs(cfg: ArchConfig, shape: InputShape, rt: RuntimeOptions,
                opt: Optional[AdamW] = None) -> Tuple:
    """All abstract step inputs for (arch x shape): returns a tuple of
    pytrees matching the lowered step's signature."""
    params = param_shapes(cfg, rt)
    if shape.kind == "train":
        opt = opt or default_optimizer()
        return (params, opt_shapes(params, opt), batch_specs(cfg, shape))
    if shape.kind == "prefill":
        return (params, batch_specs(cfg, shape))
    return (params, cache_shapes(cfg, rt, shape), decode_token_spec(shape))
