"""Latency estimation (§3.4): T-hat = T_q + T_s.

T_s — serving latency of the ensemble — comes from throughput capacity
(closed-loop measurement on the real zoo, or the analytic roofline model
for datacenter-scale members).

T_q — queueing delay — via NETWORK CALCULUS (Fig. 5): the maximum
horizontal distance between the empirical arrival curve (max #queries in
any window of length dt, from the observed trace) and the analytic service
curve (rate-latency function beta(t) = mu * (t - T0)+) is a tight upper
bound on queueing delay.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.profiles import ModelZoo, SystemConfig
from repro.serving.placement import Placement, lpt_placement


# ------------------------------------------------------- network calculus
def arrival_curve(arrivals: np.ndarray, dts: np.ndarray) -> np.ndarray:
    """Empirical arrival curve: alpha(dt) = max #arrivals in any
    half-open window [t, t+dt).  The max is attained with a window
    anchored at some arrival, where the count is
    ``searchsorted(a, a[i] + dt, 'left') - i``, so each dt costs one
    vectorized searchsorted over the sorted trace.  An empty trace
    yields the zero curve."""
    arrivals = np.sort(np.asarray(arrivals, np.float64))
    dts = np.atleast_1d(np.asarray(dts, np.float64))
    n = len(arrivals)
    if n == 0:
        return np.zeros(len(dts))
    ends = np.searchsorted(arrivals,
                           arrivals[None, :] + dts[:, None], side="left")
    return (ends - np.arange(n)[None, :]).max(axis=1).astype(np.float64)


def service_curve(mu: float, T0: float, dts: np.ndarray) -> np.ndarray:
    """Rate-latency curve beta(dt) = mu * max(dt - T0, 0)."""
    return mu * np.maximum(np.asarray(dts, np.float64) - T0, 0.0)


def max_horizontal_distance(dts: np.ndarray, alpha: np.ndarray,
                            mu: float, T0: float) -> float:
    """sup_t h(t) where h(t) = inf{d >= 0 : alpha(t) <= beta(t + d)}.
    For the rate-latency beta this is closed-form:
        h(t) = T0 + alpha(t)/mu - t.
    """
    if mu <= 0:
        return float("inf")
    h = T0 + alpha / mu - dts
    return float(max(np.max(h), 0.0))


def queueing_bound(arrivals: np.ndarray, mu: float, T0: float) -> float:
    """T_q: tight upper bound on queueing delay from the observed trace.

    Exact evaluation of sup_t [T0 + alpha(t)/mu - t]: alpha is a step
    function, so the sup is attained where a count c first becomes
    reachable — at the MINIMAL window containing c arrivals:
        bound = T0 + max_c ( c/mu - min_i (a[i+c-1] - a[i]) ).
    (A sampled arrival curve under-states alpha between grid points and
    can violate the bound; this closed form cannot.)
    """
    a = np.sort(np.asarray(arrivals, np.float64))
    n = len(a)
    if n == 0 or mu <= 0:
        return 0.0 if n == 0 else float("inf")
    best = 1.0 / mu                       # c = 1, zero-length window
    for c in range(2, n + 1):
        min_win = np.min(a[c - 1:] - a[:n - c + 1])
        best = max(best, c / mu - min_win)
    return float(T0 + max(best, 0.0))


# ------------------------------------------------------- latency profiler
@dataclasses.dataclass
class LatencyProfiler:
    """f_l(V, c, b) (§3.4).  Two Ts sources share one Tq methodology:

    * cost_fn given  — measured mode: per-model service seconds/query
      (e.g. timed jitted CPU inference, or compiled-FLOPs/peak on TPU).
    * cost_fn None   — analytic mode from profile MACs and c.device_flops.
    """
    zoo: ModelZoo
    config: SystemConfig
    cost_fn: Optional[Callable[[int], float]] = None   # model idx -> sec/q
    flops_efficiency: float = 0.35
    fixed_overhead: float = 0.004        # queue/RPC/dispatch seconds
    trace_seconds: float = 120.0
    p95: bool = True
    seed: int = 0
    # infeasible configurations (OOM / unstable queue) get a large FINITE
    # latency so surrogate models can still fit the profiled set
    infeasible_latency: float = 100.0
    # per-device relative speeds (heterogeneous pool): costs are
    # reference-device seconds, so device j serves cost c in
    # c / device_speeds[j] seconds.  None == homogeneous (unit) pool;
    # length must equal config.n_devices when given.
    device_speeds: Optional[Sequence[float]] = None

    def _speeds(self) -> Optional[Sequence[float]]:
        sp = self.device_speeds
        if sp is not None and len(sp) != self.config.n_devices:
            raise ValueError(f"{len(sp)} device_speeds != "
                             f"{self.config.n_devices} devices")
        return sp

    def model_cost(self, i: int) -> float:
        if self.cost_fn is not None:
            return float(self.cost_fn(i))
        macs = self.zoo.profiles[i].macs
        return 2.0 * macs / (self.config.device_flops
                             * self.flops_efficiency)

    def ensemble_memory(self, b: np.ndarray) -> float:
        return float(sum(p.memory_bytes for p, bi
                         in zip(self.zoo.profiles, b) if bi))

    def serving_latency(self, b: np.ndarray,
                        placement: Optional[Placement] = None) -> float:
        """T_s: PER-DEVICE MAKESPAN of the selected models under their
        device placement — the ensemble members run concurrently (§3.4
        stateless actors), so T_s is the slowest device's total work,
        not the sum over members.  ``placement=None`` plans with the
        same ``lpt_placement`` the live sharded service actuates, so
        the offline model and the serving path share one planner; pass
        the ACTIVE plan to score what is actually deployed."""
        costs = [self.model_cost(i) for i in range(len(b)) if b[i]]
        if not costs:
            return self.fixed_overhead
        if placement is None:
            placement = lpt_placement(costs, self.config.n_devices,
                                      speeds=self._speeds())
        return placement.makespan + self.fixed_overhead

    def throughput(self, b: np.ndarray) -> float:
        """mu (queries/s): total reference-device work per ensemble
        query is sum(costs); the pool retires sum(speeds) work units
        per second under perfect pipelining, so
        mu = sum(speeds) / sum(costs) (n_devices/total when unit)."""
        total = sum(self.model_cost(i) for i in range(len(b)) if b[i])
        if total <= 0:
            return float("inf")
        sp = self._speeds()
        capacity = (float(np.sum(sp)) if sp is not None
                    else float(self.config.n_devices))
        return capacity / total

    def query_arrivals(self) -> np.ndarray:
        """Ensemble queries: each patient fires once per observation
        window, with phase jitter (patients are not synchronized)."""
        rng = np.random.default_rng(self.seed)
        c = self.config
        windows = int(self.trace_seconds / c.window_seconds)
        phases = rng.uniform(0, c.window_seconds, c.n_patients)
        t = (np.arange(windows)[None, :] * c.window_seconds
             + phases[:, None])
        return np.sort(t.ravel())

    def __call__(self, b: np.ndarray,
                 placement: Optional[Placement] = None) -> float:
        """A caller holding the ACTIVE placement (e.g. a post-failover,
        deliberately unbalanced interim plan) must pass it: a fresh LPT
        plan here would understate T_s exactly when the controller's
        risk prediction matters most."""
        b = np.asarray(b).astype(bool)
        if self.ensemble_memory(b) > (self.config.device_mem_bytes
                                      * self.config.n_devices):
            return self.infeasible_latency
        Ts = self.serving_latency(b, placement=placement)
        mu = self.throughput(b)
        lam = self.config.n_patients / self.config.window_seconds
        if lam >= mu:
            return self.infeasible_latency       # unstable queue
        Tq = queueing_bound(self.query_arrivals(), mu, Ts)
        return min(Ts + Tq, self.infeasible_latency)
