"""Stateful data aggregators (§3.4, Fig. 4).

Multi-rate, multi-modal sensory streams are buffered per patient so the
ensemble always sees a synchronized observation window Delta-T across all
sensors.  Two implementations share semantics:

* ``PatientAggregator`` — plain-python actor, kept as the semantics
  ORACLE: the serving equivalence suite checks the device path against
  it, and the discrete-event simulator still drives it directly.
* ``AggState`` ring buffers — pure-functional jnp state (one
  ``[n_patients, channels, capacity]`` buffer per modality) updated by
  compiled steps, the JAX-native analogue of the paper's Ray stateful
  actors.  ``DeviceIngest`` wraps them into the serving pipeline's
  device-resident ingest stage: 250 Hz chunks land via ``ingest_chunk``
  (a pow2 chunk-size ladder keeps the compiled-variant count bounded
  under mixed-rate feeds) and a closed observation window is handed to
  the ensemble as a ``DeviceWindowRef`` — three host integers per
  modality, NO host-side sample marshaling.  The flush side
  (``EnsembleService.predict_batch``) gathers the referenced windows
  straight out of the ring with ``gather_windows`` (the
  ``kernels.ref.window_gather`` program), so samples ingested on the
  device are never copied back to the host on the serving hot path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref


# ------------------------------------------------- actor implementation
@dataclasses.dataclass
class ModalitySpec:
    name: str
    rate_hz: float                 # nominal sampling rate
    channels: int


class PatientAggregator:
    """Buffers per-modality samples; emits aligned windows of Delta-T."""

    def __init__(self, modalities: List[ModalitySpec],
                 window_seconds: float):
        self.modalities = {m.name: m for m in modalities}
        self.window = window_seconds
        self.buffers: Dict[str, List[Tuple[float, np.ndarray]]] = {
            m.name: [] for m in modalities}
        self.window_start: Optional[float] = None

    def ingest(self, t: float, modality: str, samples: np.ndarray) -> None:
        if self.window_start is None:
            self.window_start = t
        self.buffers[modality].append((t, np.asarray(samples)))

    def window_ready(self, now: float) -> bool:
        return (self.window_start is not None
                and now - self.window_start >= self.window)

    def pop_window(self, now: float) -> Dict[str, np.ndarray]:
        """Returns {modality: [channels, n_samples]} for the last window,
        dropping data older than the window (noisy-environment tolerant:
        missing samples are zero-filled to the nominal count)."""
        out = {}
        t0 = now - self.window
        for name, spec in self.modalities.items():
            want = max(1, int(round(spec.rate_hz * self.window)))
            rows = [s for (t, s) in self.buffers[name] if t >= t0]
            if rows:
                arr = np.concatenate([np.atleast_2d(r) for r in rows],
                                     axis=-1)[:, -want:]
            else:
                arr = np.zeros((spec.channels, 0), np.float32)
            if arr.shape[-1] < want:             # sensor fell off: pad
                pad = np.zeros((spec.channels, want - arr.shape[-1]),
                               np.float32)
                arr = np.concatenate([pad, arr], axis=-1)
            out[name] = arr.astype(np.float32)
            self.buffers[name] = [(t, s) for (t, s) in self.buffers[name]
                                  if t >= t0]
        self.window_start = now
        return out


# --------------------------------------------- jit-compatible ring buffer
class AggState(NamedTuple):
    """One modality's device-resident ring buffer for all patients."""
    buf: jax.Array            # [n_patients, channels, capacity]
    write_idx: jax.Array      # [n_patients] int32
    total: jax.Array          # [n_patients] int32  samples ever written


def agg_init(n_patients: int, channels: int, capacity: int) -> AggState:
    return AggState(
        buf=jnp.zeros((n_patients, channels, capacity), jnp.float32),
        write_idx=jnp.zeros((n_patients,), jnp.int32),
        total=jnp.zeros((n_patients,), jnp.int32))


def ring_wrap(cap: int) -> int:
    """Wrap modulus for ``write_idx``: the largest multiple of ``cap``
    not exceeding 2**30.  Ring positions are ``write_idx % cap``, so the
    wrap point MUST be a multiple of ``cap`` — wrapping at a plain
    2**30 silently sheared the ring for any capacity that doesn't
    divide 2**30 (the pre-fix behavior; regression-tested)."""
    return max(1, (1 << 30) // cap) * cap


@jax.jit
def ingest_step(state: AggState, patient: jax.Array,
                samples: jax.Array) -> AggState:
    """Append samples [channels, k] for one patient (ring semantics).
    Retraces per distinct ``k`` — prefer ``ingest_chunk`` on the
    serving path, which pads to a static size ladder."""
    cap = state.buf.shape[-1]
    k = samples.shape[-1]
    idx = (state.write_idx[patient] + jnp.arange(k)) % cap
    buf = state.buf.at[patient, :, idx].set(samples.T)
    return AggState(
        buf=buf,
        write_idx=state.write_idx.at[patient].add(k) % ring_wrap(cap),
        total=state.total.at[patient].add(k))


def pow2_rung(n: int) -> int:
    """Next power of two >= ``n`` (min 1): the ONE static-shape ladder
    shared by ingest chunk padding, flush batch padding and ring
    capacities, so every padded shape in the data plane lands on the
    same log2-bounded set of compiled programs."""
    return 1 << (max(1, int(n)) - 1).bit_length()


def chunk_rung(k: int) -> int:
    """Static chunk-size ladder: incoming chunks are right-zero-padded
    to a ``pow2_rung`` so ``ingest_chunk`` compiles at most
    ``log2(max_chunk)`` variants under mixed-rate feeds instead of one
    per distinct chunk length."""
    return pow2_rung(k)


@jax.jit
def _ingest_padded(state: AggState, patient: jax.Array,
                   samples: jax.Array, n_valid: jax.Array) -> AggState:
    """Ladder-shaped ingest step: ``samples`` is [channels, rung] with
    only the first ``n_valid`` columns real; pad lanes scatter to an
    out-of-bounds ring position and are dropped, so the ring never sees
    the padding."""
    cap = state.buf.shape[-1]
    lane = jnp.arange(samples.shape[-1])
    pos = (state.write_idx[patient] + lane) % cap
    pos = jnp.where(lane < n_valid, pos, cap)          # OOB -> dropped
    buf = state.buf.at[patient, :, pos].set(samples.T, mode="drop")
    return AggState(
        buf=buf,
        write_idx=state.write_idx.at[patient].add(n_valid)
        % ring_wrap(cap),
        total=state.total.at[patient].add(n_valid))


def ingest_chunk(state: AggState, patient: int,
                 samples: np.ndarray) -> AggState:
    """Append a variable-length chunk through the pow2 size ladder:
    one compiled variant per rung, not per chunk length."""
    samples = np.atleast_2d(np.asarray(samples, np.float32))
    k = samples.shape[-1]
    cap = state.buf.shape[-1]
    if k > cap:
        raise ValueError(f"chunk of {k} samples exceeds ring capacity "
                         f"{cap}")
    rung = chunk_rung(k)
    if rung != k:
        samples = np.pad(samples, ((0, 0), (0, rung - k)))
    return _ingest_padded(state, jnp.asarray(patient, jnp.int32),
                          jnp.asarray(samples),
                          jnp.asarray(k, jnp.int32))


@functools.partial(jax.jit, static_argnums=(2,))
def read_window(state: AggState, patient: jax.Array,
                want: int) -> jax.Array:
    """Last ``want`` samples, oldest first: [channels, want]."""
    cap = state.buf.shape[-1]
    end = state.write_idx[patient]
    idx = (end - want + jnp.arange(want)) % cap
    return state.buf[patient, :, idx].T


def read_window_static(state: AggState, patient: int, want: int
                       ) -> jax.Array:
    return read_window(state, jnp.asarray(patient), want)


@functools.partial(jax.jit, static_argnums=(4,))
def gather_windows(buf: jax.Array, patients: jax.Array,
                   ends: jax.Array, valid: jax.Array,
                   want: int) -> jax.Array:
    """One-dispatch flush gather: the last ``want`` samples for each
    flushed patient, ``[P, channels, want]`` oldest-first, with
    left-zero-fill fused in (``valid[i] < want`` rows) and pow2 batch
    padding (``valid == 0`` rows all-zero).  ``ends`` are sample
    counts at window close (any integers — reduced mod capacity), so a
    ref stays readable even while newer samples keep streaming into the
    ring, as long as fewer than ``cap - want`` arrive before the flush.
    Pure data movement: bitwise-identical to the host-marshaled pack.
    """
    return kref.window_gather(buf, patients, ends, valid, want)


# ----------------------------------------- device-resident ingest stage
class DeviceWindowRef(NamedTuple):
    """A closed observation window that LIVES in a ``DeviceIngest``
    ring: per modality just ``(end, valid)`` sample counts — the flush
    gathers the samples on device, so handing a window to the server
    costs a few host integers instead of a [channels, want] copy.
    ``extra`` carries host-side side-channel inputs (labs vector)."""
    ingest: "DeviceIngest"
    patient: int
    ends: Dict[str, int]
    valid: Dict[str, int]
    extra: Dict[str, np.ndarray]

    def host_window(self, modality: str) -> np.ndarray:
        """Read this window back as the oracle's [channels, want] array
        (CPU-side models / debugging; NOT the serving hot path).
        Staleness-guarded like the fused flush: a ref whose ring slot
        has been overwritten by later ingest raises instead of silently
        returning the newer window's samples."""
        di = self.ingest
        st = di.states[modality]
        cap = st.buf.shape[-1]
        want = di.want[modality]
        oldest = self.ends[modality] - min(self.valid[modality], want)
        if int(di.fed[modality][self.patient]) - oldest > cap:
            raise ValueError(
                f"stale DeviceWindowRef for patient {self.patient}: "
                f"the {modality} ring (capacity {cap}) has overwritten"
                f" its window; flush sooner or raise capacity_windows")
        win = gather_windows(
            st.buf, jnp.asarray([self.patient], jnp.int32),
            jnp.asarray([self.ends[modality] % cap], jnp.int32),
            jnp.asarray([self.valid[modality]], jnp.int32),
            want)
        return np.asarray(win[0])


class DeviceIngest:
    """Device-resident multi-patient ingest: one ``AggState`` ring per
    modality, fed by the compiled pow2-ladder ``ingest_chunk``.

    Window accounting stays on the host as plain integers (samples fed
    per patient, high-water mark at the last window close); the samples
    themselves never leave the device.  ``close_window`` emits a
    ``DeviceWindowRef`` whose ``valid`` is the number of samples that
    arrived inside the window (clamped to the nominal count), which is
    exactly the ``PatientAggregator`` zero-fill contract: fewer samples
    -> left-zero-fill, more -> keep the last nominal-count many.

    ``capacity_windows`` rings hold that many windows of slack, so a
    ref enqueued behind a busy server stays readable while the next
    window's samples stream in underneath it.

    Concurrency contract: every ingest step is a FUNCTIONAL update —
    ``self.states`` is replaced, never mutated — so a flush thread's
    snapshot of ``states[m]`` stays valid (and immutable) while ingest
    keeps advancing, with no locks.  The cost is that the jitted
    scatter cannot donate its input buffer (a donated ring would
    invalidate exactly those in-flight flush snapshots), so on the CPU
    backend each chunk pays an O(n_patients * channels * cap) ring
    copy.  The flush side — this PR's target — never sees that cost;
    amortizing the feed side (per-patient ring stripes so a chunk
    rewrites only its own [channels, cap] slice, or a batched
    multi-patient step) is the ROADMAP's batched-ingest follow-up.
    """

    def __init__(self, modalities: List[ModalitySpec],
                 n_patients: int, window_seconds: float,
                 capacity_windows: float = 2.0):
        self.modalities = {m.name: m for m in modalities}
        self.window = window_seconds
        self.n_patients = n_patients
        self.states: Dict[str, AggState] = {}
        self.want: Dict[str, int] = {}
        self.fed: Dict[str, np.ndarray] = {}
        self.mark: Dict[str, np.ndarray] = {}
        for m in modalities:
            want = max(1, int(round(m.rate_hz * window_seconds)))
            cap = chunk_rung(max(2, int(np.ceil(
                capacity_windows * want))))          # pow2: wrap-exact
            self.states[m.name] = agg_init(n_patients, m.channels, cap)
            self.want[m.name] = want
            self.fed[m.name] = np.zeros(n_patients, np.int64)
            self.mark[m.name] = np.zeros(n_patients, np.int64)
        self.window_start: List[Optional[float]] = [None] * n_patients

    def grow(self, n_patients: int) -> None:
        """Grow the census to ``n_patients`` ring rows (no-op when
        already large enough).  Each modality's ring is replaced by a
        zero-padded copy along the patient axis — a FUNCTIONAL update,
        so an in-flight flush's snapshot of the old (smaller) state
        stays valid, exactly like ``ingest``'s replacement contract.
        Existing rows keep their samples and window accounting bitwise;
        new rows start empty.  Like ``ingest``, growth assumes a single
        feeding thread per modality (the ``SlotEngine`` serializes its
        growth against live ticks separately)."""
        if n_patients <= self.n_patients:
            return
        add = n_patients - self.n_patients
        for name, st in self.states.items():
            self.states[name] = AggState(
                buf=jnp.pad(st.buf, ((0, add), (0, 0), (0, 0))),
                write_idx=jnp.pad(st.write_idx, (0, add)),
                total=jnp.pad(st.total, (0, add)))
            self.fed[name] = np.pad(self.fed[name], (0, add))
            self.mark[name] = np.pad(self.mark[name], (0, add))
        self.window_start.extend([None] * add)
        self.n_patients = n_patients

    def ingest(self, t: float, patient: int, modality: str,
               samples: np.ndarray) -> None:
        samples = np.atleast_2d(np.asarray(samples, np.float32))
        self.states[modality] = ingest_chunk(
            self.states[modality], patient, samples)
        self.fed[modality][patient] += samples.shape[-1]
        if self.window_start[patient] is None:
            self.window_start[patient] = t

    def window_ready(self, patient: int, now: float) -> bool:
        ws = self.window_start[patient]
        return ws is not None and now - ws >= self.window

    def close_window(self, patient: int, now: float,
                     extra: Optional[Dict[str, np.ndarray]] = None
                     ) -> DeviceWindowRef:
        """Close the patient's window: snapshot (end, valid) counts per
        modality, advance the high-water mark, and return the ref.  The
        samples stay put — the flush gathers them on device."""
        ends, valid = {}, {}
        for name in self.modalities:
            end = int(self.fed[name][patient])
            ends[name] = end
            valid[name] = min(end - int(self.mark[name][patient]),
                              self.want[name])
            self.mark[name][patient] = end
        self.window_start[patient] = now
        return DeviceWindowRef(ingest=self, patient=patient, ends=ends,
                               valid=valid, extra=dict(extra or {}))

    def headroom(self, patient: int,
                 modality: Optional[str] = None) -> float:
        """Slack left before a ref closed at the CURRENT mark would be
        overwritten in a ring (conservatively assuming the ref needs a
        full ``want``-sample window).  The ingest side's backpressure
        signal.

        With a ``modality`` name: that ring's headroom in SAMPLES (an
        int), the per-ring view.  With ``modality=None`` (the driver
        default): the MINIMUM across all modalities, normalized to
        WINDOW units (samples of slack / window length) so the
        differently-clocked rings are comparable — a 250 Hz ECG ring
        and a 1 Hz vitals ring overrun on different clocks, and the
        pre-fix ECG-only signal let a vitals-stale ref pass admission
        and NaN downstream.  At ``< 1.0`` (less than one full window of
        slack in SOME ring) further feeding will push outstanding
        windows past a staleness guard, so the driver should reject
        (and count) new queries rather than let them go
        stale-then-NaN."""
        if modality is not None:
            st = self.states[modality]
            cap = int(st.buf.shape[-1])
            mark = int(self.mark[modality][patient])
            fed = int(self.fed[modality][patient])
            oldest = max(0, mark - self.want[modality])
            return cap - (fed - oldest)
        return min(self.headroom(patient, m) / self.want[m]
                   for m in self.modalities)

    def headroom_by_modality(self, patient: int) -> Dict[str, float]:
        """Per-ring headroom breakdown in samples (the per-modality
        view behind the min-aggregated backpressure signal)."""
        return {m: self.headroom(patient, m) for m in self.modalities}

    def warm_gather(self, lens: Tuple[int, ...],
                    batch_sizes: Tuple[int, ...] = (1, 2, 4, 8),
                    modality: str = "ecg") -> None:
        """Pre-compile the flush gather at every (window length, pow2
        flush size) the service will hit, off the latency path."""
        st = self.states[modality]
        z = jnp.zeros((max(batch_sizes),), jnp.int32)
        for L in lens:
            for p in batch_sizes:
                jax.block_until_ready(gather_windows(
                    st.buf, z[:p], z[:p], z[:p], L))
