"""Stateful data aggregators (§3.4, Fig. 4).

Multi-rate, multi-modal sensory streams are buffered per patient so the
ensemble always sees a synchronized observation window Delta-T across all
sensors.  Two implementations share semantics:

* ``PatientAggregator`` — plain-python actor used by the serving pipeline
  and the discrete-event simulator (arbitrary arrival patterns).
* ``ingest_step`` / ``AggState`` — pure-functional jnp ring buffers
  (jit-compatible) for the device-resident streaming path: state lives in
  device arrays and is updated by a compiled step, the JAX-native analogue
  of the paper's Ray stateful actors.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------- actor implementation
@dataclasses.dataclass
class ModalitySpec:
    name: str
    rate_hz: float                 # nominal sampling rate
    channels: int


class PatientAggregator:
    """Buffers per-modality samples; emits aligned windows of Delta-T."""

    def __init__(self, modalities: List[ModalitySpec],
                 window_seconds: float):
        self.modalities = {m.name: m for m in modalities}
        self.window = window_seconds
        self.buffers: Dict[str, List[Tuple[float, np.ndarray]]] = {
            m.name: [] for m in modalities}
        self.window_start: Optional[float] = None

    def ingest(self, t: float, modality: str, samples: np.ndarray) -> None:
        if self.window_start is None:
            self.window_start = t
        self.buffers[modality].append((t, np.asarray(samples)))

    def window_ready(self, now: float) -> bool:
        return (self.window_start is not None
                and now - self.window_start >= self.window)

    def pop_window(self, now: float) -> Dict[str, np.ndarray]:
        """Returns {modality: [channels, n_samples]} for the last window,
        dropping data older than the window (noisy-environment tolerant:
        missing samples are zero-filled to the nominal count)."""
        out = {}
        t0 = now - self.window
        for name, spec in self.modalities.items():
            want = max(1, int(round(spec.rate_hz * self.window)))
            rows = [s for (t, s) in self.buffers[name] if t >= t0]
            if rows:
                arr = np.concatenate([np.atleast_2d(r) for r in rows],
                                     axis=-1)[:, -want:]
            else:
                arr = np.zeros((spec.channels, 0), np.float32)
            if arr.shape[-1] < want:             # sensor fell off: pad
                pad = np.zeros((spec.channels, want - arr.shape[-1]),
                               np.float32)
                arr = np.concatenate([pad, arr], axis=-1)
            out[name] = arr.astype(np.float32)
            self.buffers[name] = [(t, s) for (t, s) in self.buffers[name]
                                  if t >= t0]
        self.window_start = now
        return out


# --------------------------------------------- jit-compatible ring buffer
class AggState(NamedTuple):
    """One modality's device-resident ring buffer for all patients."""
    buf: jax.Array            # [n_patients, channels, capacity]
    write_idx: jax.Array      # [n_patients] int32
    total: jax.Array          # [n_patients] int32  samples ever written


def agg_init(n_patients: int, channels: int, capacity: int) -> AggState:
    return AggState(
        buf=jnp.zeros((n_patients, channels, capacity), jnp.float32),
        write_idx=jnp.zeros((n_patients,), jnp.int32),
        total=jnp.zeros((n_patients,), jnp.int32))


@jax.jit
def ingest_step(state: AggState, patient: jax.Array,
                samples: jax.Array) -> AggState:
    """Append samples [channels, k] for one patient (ring semantics)."""
    cap = state.buf.shape[-1]
    k = samples.shape[-1]
    idx = (state.write_idx[patient] + jnp.arange(k)) % cap
    buf = state.buf.at[patient, :, idx].set(samples.T)
    return AggState(
        buf=buf,
        write_idx=state.write_idx.at[patient].add(k) % (2 ** 30),
        total=state.total.at[patient].add(k))


import functools


@functools.partial(jax.jit, static_argnums=(2,))
def read_window(state: AggState, patient: jax.Array,
                want: int) -> jax.Array:
    """Last ``want`` samples, oldest first: [channels, want]."""
    cap = state.buf.shape[-1]
    end = state.write_idx[patient]
    idx = (end - want + jnp.arange(want)) % cap
    return state.buf[patient, :, idx].T


def read_window_static(state: AggState, patient: int, want: int
                       ) -> jax.Array:
    return read_window(state, jnp.asarray(patient), want)
