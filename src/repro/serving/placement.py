"""Ensemble placement.

LPT (longest-processing-time-first) greedy placement of ensemble members
onto devices — used by the latency profiler's T_s model and by the
pipeline's device assignment.  For the datacenter-scale zoo, the same
logic plans which POD (mesh axis 0 slice) hosts which ensemble member —
HOLMES' ensemble-parallelism mapped onto the multi-pod mesh (DESIGN.md §5).

A ``Placement`` is controller-actuated serving state (alongside the
selector): ``serving.pipeline.EnsembleService`` shards its stacked
bucket params across ``jax.devices()`` per the assignment,
``control.swap.HotSwapper`` pre-stages ``(selector, placement)`` pairs,
and the adaptive controller re-derives the plan from freshly measured
costs when it recomposes or when load imbalance warrants a RE-PLACE.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Placement:
    assignment: List[List[int]]       # device/pod -> member indices
    loads: List[float]                # per device/pod total cost

    @property
    def n_slots(self) -> int:
        return len(self.assignment)

    @property
    def makespan(self) -> float:
        return max(self.loads) if self.loads else 0.0

    @property
    def imbalance(self) -> float:
        """max load / mean NONZERO-slot load, >= 1 whenever any work is
        placed (1.0 == perfectly balanced over the used slots)."""
        used = [l for l in self.loads if l > 0]
        if not used:
            return 0.0
        return max(used) / (sum(used) / len(used))

    @property
    def n_members(self) -> int:
        return sum(len(a) for a in self.assignment)

    def signature(self) -> bytes:
        """Stable identity for staging caches: two placements with the
        same device->members map are the same actuated state."""
        return repr([sorted(a) for a in self.assignment]).encode()


def placement_signature(placement: Optional[Placement]) -> bytes:
    """Cache-key fragment; None (unsharded single-device service) gets a
    distinct tag so it never collides with a real plan."""
    return b"<single>" if placement is None else placement.signature()


def lpt_placement(costs: Sequence[float], n_slots: int) -> Placement:
    order = np.argsort(-np.asarray(costs, np.float64), kind="stable")
    assignment: List[List[int]] = [[] for _ in range(max(1, n_slots))]
    loads = [0.0] * max(1, n_slots)
    for i in order:
        j = int(np.argmin(loads))
        assignment[j].append(int(i))
        loads[j] += float(costs[i])
    return Placement(assignment=assignment, loads=loads)


def grouped_lpt_placement(groups: Sequence[Sequence[int]],
                          group_costs: Sequence[float],
                          n_slots: int) -> Placement:
    """LPT over atomic GROUPS of members (architecture buckets): each
    group lands on one slot whole, so a stacked bucket dispatch is never
    split across devices.  ``assignment`` is expanded back to member
    indices; ``loads`` carry the group costs."""
    if len(groups) != len(group_costs):
        raise ValueError(f"{len(groups)} groups != "
                         f"{len(group_costs)} costs")
    pl = lpt_placement(group_costs, n_slots)
    assignment = [[m for g in slot for m in groups[g]]
                  for slot in pl.assignment]
    return Placement(assignment=assignment, loads=pl.loads)


def plan_pod_ensemble(member_costs: Dict[str, float], n_pods: int
                      ) -> Dict[str, int]:
    """Map ensemble member names -> pod index (bagging combine then needs
    one cross-pod all-reduce of the [batch, n_classes] score — Eq. 5 as a
    collective)."""
    names = list(member_costs)
    pl = lpt_placement([member_costs[n] for n in names], n_pods)
    out = {}
    for pod, idxs in enumerate(pl.assignment):
        for i in idxs:
            out[names[i]] = pod
    return out
