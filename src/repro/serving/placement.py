"""Ensemble placement.

LPT (longest-processing-time-first) greedy placement of ensemble members
onto devices — used by the latency profiler's T_s model and by the
pipeline's device assignment.  For the datacenter-scale zoo, the same
logic plans which POD (mesh axis 0 slice) hosts which ensemble member —
HOLMES' ensemble-parallelism mapped onto the multi-pod mesh (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Placement:
    assignment: List[List[int]]       # device/pod -> member indices
    loads: List[float]                # per device/pod total cost

    @property
    def makespan(self) -> float:
        return max(self.loads) if self.loads else 0.0

    @property
    def imbalance(self) -> float:
        if not self.loads or max(self.loads) == 0:
            return 0.0
        return max(self.loads) / (sum(self.loads) / len(self.loads))


def lpt_placement(costs: Sequence[float], n_slots: int) -> Placement:
    order = np.argsort(-np.asarray(costs, np.float64), kind="stable")
    assignment: List[List[int]] = [[] for _ in range(max(1, n_slots))]
    loads = [0.0] * max(1, n_slots)
    for i in order:
        j = int(np.argmin(loads))
        assignment[j].append(int(i))
        loads[j] += float(costs[i])
    return Placement(assignment=assignment, loads=loads)


def plan_pod_ensemble(member_costs: Dict[str, float], n_pods: int
                      ) -> Dict[str, int]:
    """Map ensemble member names -> pod index (bagging combine then needs
    one cross-pod all-reduce of the [batch, n_classes] score — Eq. 5 as a
    collective)."""
    names = list(member_costs)
    pl = lpt_placement([member_costs[n] for n in names], n_pods)
    out = {}
    for pod, idxs in enumerate(pl.assignment):
        for i in idxs:
            out[names[i]] = pod
    return out
