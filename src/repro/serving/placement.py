"""Ensemble placement.

LPT (longest-processing-time-first) greedy placement of ensemble members
onto devices — used by the latency profiler's T_s model and by the
pipeline's device assignment.  For the datacenter-scale zoo, the same
logic plans which POD (mesh axis 0 slice) hosts which ensemble member —
HOLMES' ensemble-parallelism mapped onto the multi-pod mesh (DESIGN.md §5).

A ``Placement`` is controller-actuated serving state (alongside the
selector): ``serving.pipeline.EnsembleService`` shards its stacked
bucket params across ``jax.devices()`` per the assignment,
``control.swap.HotSwapper`` pre-stages ``(selector, placement)`` pairs,
and the adaptive controller re-derives the plan from freshly measured
costs when it recomposes or when load imbalance warrants a RE-PLACE.

Heterogeneous pools: real hospital deployments mix CPU and accelerator
nodes, so the planner takes a per-device ``speeds`` vector (work units
per second relative to the reference device the costs were measured
on).  LPT then greedily minimizes NORMALIZED FINISH TIMES — item ``c``
goes to the slot minimizing ``(load_j + c) / speed_j`` — and
``makespan`` / ``imbalance`` are finish-time quantities.  ``speeds``
move work onto fast devices; they never change the math a member
computes, so sharded scores stay bitwise-equal to the unsharded oracle
for every speed vector.  ``signature()`` deliberately hashes the
assignment only: a re-speeded but identically-assigned plan is the
same actuated state, so staging-cache keys don't churn.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def finish_imbalance(finish_times: Sequence[float]) -> float:
    """max finish / mean finish over ALL slots (1.0 == perfectly
    balanced, 0.0 == no work anywhere).  Averaging over every slot —
    idle ones included — is deliberate: a plan that strands a device
    (``finish=[x, 0]``) reports 2.0, not 1.0, so the controller's
    ``imbalance > imbalance_high`` RE-PLACE trigger can fire on it."""
    ft = [max(0.0, float(f)) for f in finish_times]
    if not ft or max(ft) <= 0.0:
        return 0.0
    return max(ft) / (sum(ft) / len(ft))


@dataclasses.dataclass
class Placement:
    assignment: List[List[int]]       # device/pod -> member indices
    loads: List[float]                # per device/pod total cost (work)
    # per-slot relative speed (None == homogeneous pool, unit speeds).
    # loads stay in device-independent work units; wall-clock per slot
    # is loads[j] / speeds[j].
    speeds: Optional[List[float]] = None

    def __post_init__(self) -> None:
        if self.speeds is not None:
            if len(self.speeds) != len(self.assignment):
                raise ValueError(
                    f"{len(self.speeds)} speeds != "
                    f"{len(self.assignment)} slots")
            if any(s <= 0 for s in self.speeds):
                raise ValueError(f"speeds must be > 0: {self.speeds}")

    @property
    def n_slots(self) -> int:
        return len(self.assignment)

    @property
    def finish_times(self) -> List[float]:
        """Per-slot normalized finish time (seconds on that device)."""
        if self.speeds is None:
            return [float(l) for l in self.loads]
        return [float(l) / s for l, s in zip(self.loads, self.speeds)]

    @property
    def makespan(self) -> float:
        ft = self.finish_times
        return max(ft) if ft else 0.0

    @property
    def imbalance(self) -> float:
        """max finish time / mean finish time over ALL slots (idle
        slots count: stranding a device is imbalance, not balance)."""
        return finish_imbalance(self.finish_times)

    @property
    def n_members(self) -> int:
        return sum(len(a) for a in self.assignment)

    def signature(self) -> bytes:
        """Stable identity for staging caches: two placements with the
        same device->members map are the same actuated state (speeds
        are advisory planner input, not actuated state)."""
        return repr([sorted(a) for a in self.assignment]).encode()


def placement_signature(placement: Optional[Placement]) -> bytes:
    """Cache-key fragment; None (unsharded single-device service) gets a
    distinct tag so it never collides with a real plan."""
    return b"<single>" if placement is None else placement.signature()


def _checked_speeds(speeds: Optional[Sequence[float]],
                    n_slots: int) -> Optional[List[float]]:
    if speeds is None:
        return None
    sp = [float(s) for s in speeds]
    if len(sp) != n_slots:
        raise ValueError(f"{len(sp)} speeds != {n_slots} slots")
    if any(s <= 0 for s in sp):
        raise ValueError(f"speeds must be > 0: {sp}")
    return sp


def lpt_placement(costs: Sequence[float], n_slots: int,
                  speeds: Optional[Sequence[float]] = None) -> Placement:
    """Greedy LPT on uniform ("related") machines: items in decreasing
    cost order, each to the slot minimizing its completion time
    ``(load_j + c) / speed_j`` (first minimum wins).  When all speeds
    are equal the criterion reduces — bitwise, tie-breaks included —
    to today's homogeneous ``argmin(loads)``, so unit-speed plans are
    identical to the speed-blind planner's."""
    k = max(1, n_slots)
    sp = _checked_speeds(speeds, k)
    order = np.argsort(-np.asarray(costs, np.float64), kind="stable")
    assignment: List[List[int]] = [[] for _ in range(k)]
    loads = [0.0] * k
    uniform = sp is None or len(set(sp)) == 1
    sp_arr = None if uniform else np.asarray(sp, np.float64)
    for i in order:
        c = float(costs[i])
        if uniform:
            j = int(np.argmin(loads))
        else:
            j = int(np.argmin((np.asarray(loads) + c) / sp_arr))
        assignment[j].append(int(i))
        loads[j] += c
    return Placement(assignment=assignment, loads=loads, speeds=sp)


def grouped_lpt_placement(groups: Sequence[Sequence[int]],
                          group_costs: Sequence[float],
                          n_slots: int,
                          speeds: Optional[Sequence[float]] = None
                          ) -> Placement:
    """LPT over atomic GROUPS of members (architecture buckets): each
    group lands on one slot whole, so a stacked bucket dispatch is never
    split across devices.  ``assignment`` is expanded back to member
    indices; ``loads`` carry the group costs."""
    if len(groups) != len(group_costs):
        raise ValueError(f"{len(groups)} groups != "
                         f"{len(group_costs)} costs")
    pl = lpt_placement(group_costs, n_slots, speeds=speeds)
    assignment = [[m for g in slot for m in groups[g]]
                  for slot in pl.assignment]
    return Placement(assignment=assignment, loads=pl.loads,
                     speeds=pl.speeds)


def plan_pod_ensemble(member_costs: Dict[str, float], n_pods: int
                      ) -> Dict[str, int]:
    """Map ensemble member names -> pod index (bagging combine then needs
    one cross-pod all-reduce of the [batch, n_classes] score — Eq. 5 as a
    collective)."""
    names = list(member_costs)
    pl = lpt_placement([member_costs[n] for n in names], n_pods)
    out = {}
    for pod, idxs in enumerate(pl.assignment):
        for i in idxs:
            out[names[i]] = pod
    return out
