"""Slot-based continuous serving engine (the JetStream/MaxText slot
idiom applied to ICU ensemble serving).

The flush path (``pipeline.EnsembleService.predict_batch``) is
query-oriented: every micro-batch re-marshals refs, pads, dispatches
and gathers, so dispatches/query bottoms out at ``n_buckets /
max_batch`` (~0.25 on the reduced zoo).  Continuous monitoring inverts
that: every bed streams *all the time*, so the score should be an
always-fresh per-patient STATE that queries merely read.

``SlotEngine`` keeps exactly that state:

* each bed owns a **slot** — its window state already lives in the
  ``DeviceIngest`` ring buffers (``[n_patients, channels, capacity]``
  per modality, updated in place by device ingest); the engine adds
  the host-side slot bookkeeping (occupancy, last-closed-window ints,
  close/score versions) plus a persistent on-device member-score
  matrix ``[M, n_slots]`` per device group;
* ``tick()`` scores **all occupied slots at once**: one fused ring
  gather per distinct window length (``gather_windows`` — the same
  program the flush uses), the *same cached stacked bucket dispatches*
  as the flush path (``pipeline._make_bucket_fn`` jit objects, so the
  tick shares the flush's compile cache), and ONE **donated** jitted
  update step per device group that applies the occupancy mask to the
  member-score state in place and writes the ``[n_slots]`` combined
  score vector that stays on device (``device_scores``);
* a query becomes "read slot k's latest score" — host int indexing
  into the engine's mirror, **zero H2D and zero dispatches per
  query**.  The tick's ``n_buckets + 1`` dispatches amortize over
  every occupied slot, so dispatches/query ~ ``n_buckets / n_slots``
  (~0.06 at 64 beds, → 0 at the ROADMAP's thousands).

Bitwise oracle contract
-----------------------
Because the tick reuses the flush's OWN bucket jit objects and the
masked update merely *selects* freshly computed columns, a slot's
score is bitwise-identical to ``predict_batch`` over the same refs
(the flush path stays the oracle, exactly like ``marshal="legacy"``
is the oracle for the packed marshal).  The host ``read()`` surface
replicates ``EnsembleService._combine``'s float64 mean + CPU-side
vitals/labs models verbatim from a per-tick readback of the member
score matrix, so even the combined score matches the oracle bit for
bit.  (The on-device ``device_scores`` vector is the float32 ECG-zoo
mean — the mesh-facing artifact — and is NOT the oracle surface.)
One caveat inherited from XLA: a flush of exactly ONE window compiles
a different (batch-1-specialized) program, so the oracle comparison
holds for flushes of two or more windows.

Staleness is a tick-age guard: a slot whose ring data was overwritten
before the tick could gather it (the same two-host-int check the
flush uses) is skipped — its mirror keeps the last good score and its
score version stops advancing, so version-gated readers
(``wait_scored``) time out to NaN instead of serving wrong-window
data.

``SlotTicker`` drives ``tick()`` from a daemon thread at a writable
interval, and ``TickLadder`` exposes that interval as a degradation
ladder with the same ``shed``/``climb``/``swap_to`` protocol as
``control.swap.SelectorLadder`` — tick RATE joins ensemble
composition and placement as a controller-actuated knob.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import threading
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.aggregator import (DeviceIngest, DeviceWindowRef,
                                      gather_windows, pow2_rung)

log = logging.getLogger(__name__)

# the CPU backend cannot donate buffers (jax copies instead, which is
# semantically identical); the once-per-compile warning would otherwise
# fire on every engine's first tick
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

_DEVICE_LOST_CLS = None


def _device_lost(e: BaseException) -> bool:
    """True when ``e`` is the fault plane's ``DeviceLostError``.  Lazily
    imported so the serving layer never depends on ``repro.control`` at
    import time (the control plane already imports serving)."""
    global _DEVICE_LOST_CLS
    if _DEVICE_LOST_CLS is None:
        try:
            from repro.control.faults import DeviceLostError
            _DEVICE_LOST_CLS = DeviceLostError
        except Exception:               # control plane absent: nothing
            return False                # can raise its error type
    return isinstance(e, _DEVICE_LOST_CLS)


@functools.partial(jax.jit, donate_argnums=(0,))
def _masked_update(prev: jax.Array, cands: Tuple[jax.Array, ...],
                   occ: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """The donated slot-state step: merge this tick's freshly scored
    member columns (``cands``, one ``[m_i, S]`` block per bucket in
    group order) into the persistent ``[M_g, S]`` member-score state
    behind the occupancy mask, in place (``prev`` is donated), and
    emit the group's ``[S]`` combined score vector.  ``where`` only
    SELECTS columns, so a scored slot's state is bitwise the bucket
    dispatch's output."""
    new = jnp.where(occ[None, :], jnp.concatenate(cands, axis=0), prev)
    return new, jnp.mean(new, axis=0)


@jax.jit
def _fleet_mean(mats: Tuple[jax.Array, ...]) -> jax.Array:
    """Cross-group combine for sharded plans: the [S] member mean over
    every device group's score matrix (brought to one device first)."""
    return jnp.mean(jnp.concatenate(mats, axis=0), axis=0)


@dataclasses.dataclass
class _Group:
    """Per-device slice of the tick: the bucket shards pinned to one
    device plus that device's persistent member-score state."""
    device: object                  # jax.Device or None (default)
    buckets: List                   # pipeline._Bucket shards, plan order
    rows: np.ndarray                # global member index per state row
    state: jax.Array                # [M_g, Spad] float32, donated per tick


@dataclasses.dataclass
class TickReport:
    """What one ``tick()`` did (the bench/telemetry surface).

    ``stamped``/``versions``/``scores`` name the slots whose mirror
    actually ADVANCED this tick (the ABA/version guards can drop a
    computed score), aligned index-for-index — together with ``spad``
    (the pad rung the tick dispatched at) they are exactly what an
    offline oracle needs to re-score the tick bitwise."""
    tick: int                       # tick ordinal after this tick
    n_scored: int                   # occupied slots scored this tick
    n_stale: int                    # occupied slots skipped (ring overrun)
    seconds: float                  # wall clock of the whole tick
    scored: np.ndarray              # slot ids scored this tick
    stamped: Optional[np.ndarray] = None   # slot ids whose mirror advanced
    versions: Optional[np.ndarray] = None  # close version per stamped slot
    scores: Optional[np.ndarray] = None    # combined score per stamped slot
    spad: int = 0                   # pad rung (oracle batch size)
    skipped: bool = False           # tick-lock timeout: nothing ran


class SlotEngine:
    """Persistent patient-slot scoring over a ``DeviceIngest`` census.

    ``service`` must be a fused, packed-marshal ``EnsembleService``
    (optionally placement-sharded); ``ingest`` the census's
    ``DeviceIngest`` (slot k == patient k — a bed owns its ring row).

    Host API (all thread-safe):

    * ``admit(slot)`` / ``discharge(slot)`` — slot insert / free;
    * ``update(ref)`` — record a closed window for its slot (admits on
      first window), returns the slot's new close VERSION;
    * ``tick()`` — score all occupied slots once (see module doc);
    * ``read(slot)`` — the slot's latest combined score, host int
      indexing only (NaN before the first scoring or past the tick-age
      guard); ``wait_scored(slot, version)`` blocks until the tick
      covering that close version lands.
    """

    def __init__(self, service, ingest: DeviceIngest):
        if not getattr(service, "fused", False):
            raise ValueError("SlotEngine needs a fused EnsembleService")
        if getattr(service, "marshal", "packed") != "packed":
            raise ValueError("SlotEngine needs the packed marshal (the "
                             "tick gathers windows on device)")
        if not service.members:
            raise ValueError("SlotEngine needs at least one zoo member")
        if "ecg" not in ingest.states:
            raise ValueError("SlotEngine needs an 'ecg' ingest ring")
        self.service = service
        self.ingest = ingest
        self.n_slots = ingest.n_patients
        self._Spad = pow2_rung(self.n_slots)
        self._lens = tuple(sorted({b.spec.input_len
                                   for b in service._buckets}))
        self.groups: List[_Group] = self._build_groups(service)
        # [Spad] f32 combined (zoo-mean) score vector, stays on device
        self.device_scores: Optional[jax.Array] = None
        self._pj = jnp.asarray(
            np.pad(np.arange(self.n_slots, dtype=np.int32),
                   (0, self._Spad - self.n_slots)))

        # ---- tick serialization + fault recovery ----
        # one tick (or growth, or rebind) at a time; REENTRANT so the
        # device-loss hook may rebind from inside a failing tick.  A
        # respawned ticker generation that finds the lock held (a
        # zombie tick still in flight) SKIPS rather than piling up.
        self._tick_lock = threading.RLock()
        self.tick_lock_timeout = 2.0
        self.max_tick_retries = 3
        # on_device_lost(err) -> bool: installed by the fault plane
        # (``FaultPlane.protect_engine``); True means "recovered, re-run
        # the tick", False/None means abort (the error propagates and
        # the NEXT tick retries naturally — right for transient losses)
        self.on_device_lost = None
        self.on_tick = None             # on_tick(TickReport), post-tick
        self._pre_stamp_hook = None     # test seam: runs between the
        #                                 readback and the stamp lock
        self._pending_rebind = None     # service queued by request_rebind

        # ---- host slot state (all guarded by _lock) ----
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.occupied = np.zeros(self.n_slots, bool)
        self.has_window = np.zeros(self.n_slots, bool)
        self._ends = {m: np.zeros(self.n_slots, np.int64)
                      for m in ingest.states}
        self._valid = {m: np.zeros(self.n_slots, np.int64)
                       for m in ingest.states}
        self._extra: List[Dict] = [{} for _ in range(self.n_slots)]
        self._close_version = np.zeros(self.n_slots, np.int64)
        self.scored_version = np.full(self.n_slots, -1, np.int64)
        self.last_scored_tick = np.full(self.n_slots, -1, np.int64)
        self._admit_epoch = np.zeros(self.n_slots, np.int64)
        self.mirror = np.full(self.n_slots, np.nan)   # float64 oracle
        self.tick_count = 0
        # counters (bench surface)
        self.dispatch_count = 0      # stacked bucket dispatches by ticks
        self.n_admits = 0
        self.n_discharges = 0
        self.n_stale_total = 0
        self.tick_seconds = 0.0
        self.n_tick_faults = 0       # DeviceLostError raised inside a tick
        self.n_tick_aborts = 0       # ticks abandoned (fault, no recovery)
        self.n_tick_skips = 0        # ticks skipped on the tick lock
        self.n_rebinds = 0           # post-failover service rebinds
        self.n_grows = 0             # census regrowths (ensure_slots)

    def _build_groups(self, service) -> List[_Group]:
        """Device groups in bucket-plan order (one per shard device),
        each with a ZERO member-score state at the current pad rung."""
        groups: Dict[object, _Group] = {}
        for b in service._buckets:
            g = groups.get(b.device)
            if g is None:
                g = _Group(device=b.device, buckets=[],
                           rows=np.zeros(0, np.int64), state=None)
                groups[b.device] = g
            g.buckets.append(b)
        for g in groups.values():
            g.rows = np.asarray([i for b in g.buckets for i in b.idx])
            state = jnp.zeros((len(g.rows), self._Spad), jnp.float32)
            g.state = (jax.device_put(state, g.device)
                       if g.device is not None else state)
        return list(groups.values())

    def rebind(self, service) -> None:
        """Point the engine at a new ``EnsembleService`` — the
        post-failover step: ``HotSwapper.quarantine_device`` re-stages
        onto the survivor pool and swaps its facade, but the engine
        holds a DIRECT service ref, so the fault plane (or a
        quarantine hook) must rebind it.  Idempotent; the member
        composition must be unchanged (failover moves shards, it never
        drops members).  Group states restart at zero on the new
        placement — every occupied slot is fully re-scored by the next
        tick anyway, and the host mirror keeps its last good scores in
        the gap (stale, never wrong)."""
        if service is self.service:
            return
        if not getattr(service, "fused", False) or \
                getattr(service, "marshal", "packed") != "packed":
            raise ValueError("rebind needs a fused, packed "
                             "EnsembleService")
        old = [getattr(m, "name", None) for m in self.service.members]
        new = [getattr(m, "name", None) for m in service.members]
        if old != new:
            raise ValueError(f"rebind must keep the member composition "
                             f"({old} -> {new})")
        with self._tick_lock:
            self.service = service
            self._lens = tuple(sorted({b.spec.input_len
                                       for b in service._buckets}))
            self.groups = self._build_groups(service)
            self.device_scores = None
            with self._lock:
                self.n_rebinds += 1

    def request_rebind(self, service) -> None:
        """Queue a rebind to be applied at the next tick.  The async
        form exists for ``HotSwapper.quarantine_hooks``: a hook can
        fire on the failover thread WHILE a tick (waiting on that very
        failover) holds the tick lock — a synchronous ``rebind`` there
        would deadlock."""
        with self._lock:
            self._pending_rebind = service

    # ------------------------------------------------------ slot admin
    def admit(self, slot: int) -> None:
        """Insert a bed into its slot (idempotent), growing the census
        when ``slot`` is past the current capacity.  The slot serves
        NaN until its first window is closed and ticked."""
        if slot >= self.n_slots:
            self.ensure_slots(slot + 1)
        with self._lock:
            if self.occupied[slot]:
                return
            self._admit_locked(slot)

    def acquire_slot(self) -> int:
        """Admit into the lowest FREE slot and return its id, growing
        the census when every slot is occupied — the free-list admit
        path for callers that track beds, not slot ids (a hospital
        census scales past the initial ``n_slots`` this way)."""
        while True:
            with self._lock:
                free = np.flatnonzero(~self.occupied)
                if len(free):
                    s = int(free[0])
                    self._admit_locked(s)
                    return s
                want = self.n_slots + 1
            self.ensure_slots(want)   # racers just re-loop

    def ensure_slots(self, n: int) -> int:
        """Grow the census to hold at least ``n`` slots, under live
        ticks, and return the new capacity.  Growth goes in pow2 steps
        (``pow2_rung``) so slot count and pad rung stay aligned and
        regrowths amortize.  Serialized against ``tick()`` on the tick
        lock: a tick in flight finishes on the OLD shapes (its
        snapshot is consistent), the next one sees the grown census.
        Existing slots keep their scores, versions and ring rows
        bitwise; device group states are zero-padded along the slot
        axis, which preserves every live column exactly."""
        if n <= self.n_slots:
            return self.n_slots
        with self._tick_lock:
            if n <= self.n_slots:     # lost the growth race: done
                return self.n_slots
            new_n = int(pow2_rung(n))
            old_spad = self._Spad
            new_spad = int(pow2_rung(new_n))
            self.ingest.grow(new_n)
            add = new_n - self.n_slots
            with self._lock:
                self.occupied = np.pad(self.occupied, (0, add))
                self.has_window = np.pad(self.has_window, (0, add))
                for m in list(self._ends):
                    self._ends[m] = np.pad(self._ends[m], (0, add))
                    self._valid[m] = np.pad(self._valid[m], (0, add))
                self._extra.extend({} for _ in range(add))
                self._close_version = np.pad(self._close_version,
                                             (0, add))
                self.scored_version = np.pad(
                    self.scored_version, (0, add), constant_values=-1)
                self.last_scored_tick = np.pad(
                    self.last_scored_tick, (0, add), constant_values=-1)
                self._admit_epoch = np.pad(self._admit_epoch, (0, add))
                self.mirror = np.pad(self.mirror, (0, add),
                                     constant_values=np.nan)
                self.n_slots = new_n
                self._Spad = new_spad
                self.n_grows += 1
            self._pj = jnp.asarray(
                np.pad(np.arange(self.n_slots, dtype=np.int32),
                       (0, self._Spad - self.n_slots)))
            if new_spad != old_spad:
                for g in self.groups:
                    grown = jnp.pad(
                        g.state, ((0, 0), (0, new_spad - old_spad)))
                    g.state = (jax.device_put(grown, g.device)
                               if g.device is not None else grown)
                self.device_scores = None
            return self.n_slots

    def _admit_locked(self, slot: int) -> None:
        self.occupied[slot] = True
        self.has_window[slot] = False
        self.mirror[slot] = np.nan
        self.scored_version[slot] = -1
        self.last_scored_tick[slot] = -1
        self._admit_epoch[slot] += 1
        self._extra[slot] = {}
        self.n_admits += 1

    def discharge(self, slot: int) -> None:
        """Free the bed's slot.  Its mirror score is cleared and any
        reader still waiting on it wakes to NaN; the device-side state
        column is simply masked out of future ticks until re-admission
        closes a fresh window."""
        with self._lock:
            if not self.occupied[slot]:
                raise KeyError(f"slot {slot} is not occupied")
            self.occupied[slot] = False
            self.has_window[slot] = False
            self.mirror[slot] = np.nan
            self.scored_version[slot] = -1
            self._extra[slot] = {}
            self.n_discharges += 1
            self._cv.notify_all()

    def update(self, ref: DeviceWindowRef) -> int:
        """Record a closed observation window for its slot (admitting
        the bed on its first window) and return the slot's new close
        version — ``wait_scored(slot, version)`` then blocks until the
        tick that covers this window has landed.  Only the ref's host
        integers are touched; the samples stay in the rings."""
        if ref.ingest is not self.ingest:
            raise ValueError("ref belongs to a different DeviceIngest")
        s = ref.patient
        if s >= self.n_slots:      # ingest grown out-of-band: catch up
            self.ensure_slots(s + 1)
        with self._lock:
            if not self.occupied[s]:
                self._admit_locked(s)
            for m in ref.ends:
                self._ends[m][s] = ref.ends[m]
                self._valid[m][s] = ref.valid[m]
            self._extra[s] = dict(ref.extra)
            self.has_window[s] = True
            self._close_version[s] += 1
            return int(self._close_version[s])

    # ------------------------------------------------------------ tick
    def _stale_mask(self, occ: np.ndarray, ends: Dict[str, np.ndarray],
                    valid: Dict[str, np.ndarray]) -> np.ndarray:
        """Slots whose last-closed window has been overwritten in a
        ring the tick will read — the flush path's staleness guard,
        vectorized over slots.  Checked for the ECG ring always and
        the vitals ring iff the tick's side-model readback uses it."""
        need = {"ecg": max(self._lens)}
        if self.service.vitals_model is not None \
                and "vitals" in self.ingest.states:
            need["vitals"] = self.ingest.want["vitals"]
        stale = np.zeros(self.n_slots, bool)
        for m, l_need in need.items():
            cap = int(self.ingest.states[m].buf.shape[-1])
            fed = self.ingest.fed[m][:self.n_slots]
            oldest = ends[m] - np.minimum(valid[m], l_need)
            stale |= occ & ((fed - oldest) > cap)
        return stale

    def _occ_device(self, mask: np.ndarray) -> Dict[object, jax.Array]:
        occ = jnp.asarray(np.pad(mask, (0, self._Spad - self.n_slots)))
        out = {}
        for g in self.groups:
            out[g.device] = (jax.device_put(occ, g.device)
                             if g.device is not None else occ)
        return out

    def tick(self) -> TickReport:
        """Score every occupied, non-stale slot once: fused ring
        gathers + the flush path's cached stacked bucket dispatches +
        one donated masked-update step per device group, then refresh
        the host mirror with the oracle-exact combined scores.

        Fault contract: every gather and bucket dispatch runs behind
        the fault plane's ``dispatch_guard``, and ALL guards fire
        before the first donated ``_masked_update`` fold — a
        ``DeviceLostError`` aborts the tick with every group's
        persistent score state untouched (a partially-failed tick can
        never be folded in).  When ``on_device_lost`` is installed and
        recovers (quarantine + rebind), the tick re-runs on the
        survivor placement; otherwise the error propagates and the
        next tick retries — either way post-recovery scores are
        bitwise the unsharded oracle's.  Concurrent ticks serialize on
        the tick lock; a caller that cannot acquire it within
        ``tick_lock_timeout`` returns a ``skipped`` report instead of
        piling up behind a stalled zombie tick."""
        if not self._tick_lock.acquire(timeout=self.tick_lock_timeout):
            with self._lock:
                self.n_tick_skips += 1
                return TickReport(self.tick_count, 0, 0, 0.0,
                                  np.zeros(0, np.int64),
                                  spad=self._Spad, skipped=True)
        try:
            with self._lock:
                pending = self._pending_rebind
                self._pending_rebind = None
            if pending is not None:
                try:
                    self.rebind(pending)    # reentrant on the tick lock
                except Exception:
                    log.exception("queued rebind failed")
            attempts = 0
            while True:
                try:
                    report = self._tick_attempt()
                    break
                except Exception as e:
                    if not _device_lost(e):
                        raise
                    with self._lock:
                        self.n_tick_faults += 1
                    hook = self.on_device_lost
                    attempts += 1
                    if hook is not None \
                            and attempts <= self.max_tick_retries \
                            and hook(e):
                        continue        # recovered: re-run the tick
                    with self._lock:
                        self.n_tick_aborts += 1
                        self._cv.notify_all()
                    raise
        finally:
            self._tick_lock.release()
        cb = self.on_tick
        if cb is not None:
            try:
                cb(report)
            except Exception:
                log.exception("on_tick callback failed")
        return report

    def _tick_attempt(self) -> TickReport:
        t0 = time.perf_counter()
        svc = self.service
        with self._lock:
            spad = self._Spad
            pj = self._pj
            occ = self.occupied & self.has_window
            ends = {m: a.copy() for m, a in self._ends.items()}
            valid = {m: a.copy() for m, a in self._valid.items()}
            versions = self._close_version.copy()
            epochs = self._admit_epoch.copy()
            extras = list(self._extra)
        stale = self._stale_mask(occ, ends, valid)
        mask = occ & ~stale
        scored = np.flatnonzero(mask)
        empty = np.zeros(0, np.int64)
        if not len(scored):
            with self._lock:
                self.tick_count += 1
                self.n_stale_total += int(stale.sum())
                self.tick_seconds += time.perf_counter() - t0
                self._cv.notify_all()
                return TickReport(self.tick_count, 0, int(stale.sum()),
                                  time.perf_counter() - t0, scored,
                                  stamped=empty, versions=empty,
                                  scores=np.zeros(0), spad=spad)

        # ---- phase 1: gather + dispatch.  No persistent state is
        # touched and every guard fires HERE, so a DeviceLostError
        # anywhere in this phase aborts with all group states intact.
        guard = svc.dispatch_guard
        if guard is not None:
            guard(None)      # the ingest rings live on the default device

        # one fused gather per distinct window length, over ALL slots
        # (masked-out columns carry garbage and are dropped on device)
        st = self.ingest.states["ecg"]
        cap = st.buf.shape[-1]
        pad = spad - self.n_slots
        ej = jnp.asarray(np.pad((ends["ecg"] % cap).astype(np.int32),
                                (0, pad)))
        vj = jnp.asarray(np.pad(
            np.where(mask, valid["ecg"], 0).astype(np.int32), (0, pad)))
        packs = {L: gather_windows(st.buf, pj, ej, vj, L)
                 for L in self._lens}
        dev_wins, _ = svc._ship_packs(packs)    # D2D for remote shards

        vit_rows = None
        if svc.vitals_model is not None \
                and "vitals" in self.ingest.states:
            vst = self.ingest.states["vitals"]
            vcap = vst.buf.shape[-1]
            vej = jnp.asarray(np.pad(
                (ends["vitals"] % vcap).astype(np.int32), (0, pad)))
            vvj = jnp.asarray(np.pad(
                np.where(mask, valid["vitals"], 0).astype(np.int32),
                (0, pad)))
            vit_rows = np.asarray(gather_windows(
                vst.buf, pj, vej, vvj, self.ingest.want["vitals"]))

        occ_dev = self._occ_device(mask)
        group_cands: List[Tuple[jax.Array, ...]] = []
        n_disp = 0
        for g in self.groups:
            cands = []
            for b in g.buckets:
                if guard is not None:
                    guard(b.device)
                cands.append(b.fn(
                    b.stacked, dev_wins[(b.spec.input_len, b.device)]))
            n_disp += len(g.buckets)
            group_cands.append(tuple(cands))

        # ---- phase 2: fold.  Every guard has passed; the donated
        # updates commit each group's state for this tick.
        combined = None
        for g, cands in zip(self.groups, group_cands):
            g.state, combined = _masked_update(
                g.state, cands, occ_dev[g.device])
        if len(self.groups) == 1:
            self.device_scores = combined
        else:
            anchor = self.groups[0].device
            self.device_scores = _fleet_mean(tuple(
                jax.device_put(g.state, anchor) for g in self.groups))

        # host mirror: exact _combine numerics (float64 mean over the
        # member column + CPU-side vitals/labs models) from one small
        # per-tick readback — this sync point plays the flush's gather
        score_mat = np.zeros((len(svc.members), spad))
        for g in self.groups:
            score_mat[g.rows] = np.asarray(jax.block_until_ready(g.state))
        fresh: Dict[int, float] = {}
        for s in scored:
            fresh[int(s)] = self._host_combine(
                score_mat[:, s], extras[s],
                vit_rows[s] if vit_rows is not None else None)

        hook = self._pre_stamp_hook
        if hook is not None:
            hook()

        wall = time.perf_counter() - t0
        stamped: List[int] = []
        with self._lock:
            self.tick_count += 1
            for s, sc in fresh.items():
                # a slot discharged (or churned to a new occupant, or
                # closed a NEWER window — whose samples the gather may
                # already have seen) while the tick was in flight must
                # not be stamped with this tick's score
                if not self.occupied[s] \
                        or self._admit_epoch[s] != epochs[s] \
                        or self._close_version[s] != versions[s]:
                    continue
                self.mirror[s] = sc
                self.scored_version[s] = versions[s]
                self.last_scored_tick[s] = self.tick_count
                stamped.append(s)
            self.dispatch_count += n_disp
            self.n_stale_total += int(stale.sum())
            self.tick_seconds += wall
            self._cv.notify_all()
            st_ids = np.asarray(stamped, np.int64)
            return TickReport(
                self.tick_count, len(scored), int(stale.sum()), wall,
                scored, stamped=st_ids,
                versions=versions[st_ids].copy(),
                scores=np.asarray([fresh[int(s)] for s in st_ids]),
                spad=spad)

    def _host_combine(self, score_col: np.ndarray, extra: Dict,
                      vit_row: Optional[np.ndarray]) -> float:
        """``EnsembleService._combine`` for one slot, verbatim: python
        list of float64 member scores, CPU-side models appended in the
        same order, ``np.mean`` over the list."""
        svc = self.service
        scores = list(score_col) if len(svc.members) else []
        if svc.vitals_model is not None:
            vit = vit_row if vit_row is not None else extra.get("vitals")
            if vit is not None:
                scores.append(float(
                    svc.vitals_model.predict_proba(vit[None])[0]))
        if svc.labs_model is not None:
            labs = extra.get("labs")
            if labs is not None:
                scores.append(float(
                    svc.labs_model.predict_proba(labs[None])[0]))
        return float(np.mean(scores)) if scores else 0.5

    # ------------------------------------------------------------ reads
    def read(self, slot: int,
             max_age_ticks: Optional[int] = None) -> float:
        """The slot's latest combined score — host int indexing, no
        device work at all.  NaN before the slot's first scoring, and
        NaN past the tick-age guard: ``max_age_ticks`` bounds how many
        ticks ago the score may have landed (a stale ring or a stopped
        ticker stops a slot's score version from advancing, and this
        guard keeps such a slot from serving an old score forever)."""
        with self._lock:
            if not self.occupied[slot]:
                raise KeyError(f"slot {slot} is not occupied")
            if self.scored_version[slot] < 0:
                return float("nan")
            if max_age_ticks is not None and (
                    self.tick_count - self.last_scored_tick[slot]
                    > max_age_ticks):
                return float("nan")
            return float(self.mirror[slot])

    def wait_scored(self, slot: int, version: int,
                    timeout: float = 1.0) -> bool:
        """Block until the tick covering close ``version`` of ``slot``
        has landed (True), or the slot was discharged / the timeout
        expired (False — the caller should serve NaN)."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while True:
                if not self.occupied[slot]:
                    return False
                if self.scored_version[slot] >= version:
                    return True
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(min(remaining, 0.05))

    def scores(self) -> np.ndarray:
        """Snapshot of the host mirror: ``[n_slots]`` float64, NaN for
        unoccupied / not-yet-scored slots."""
        with self._lock:
            return np.where(self.occupied, self.mirror, np.nan)

    # ----------------------------------------------------------- warmup
    def warm(self) -> None:
        """Pre-compile everything a tick touches (ring gathers and
        bucket dispatches at the slot batch size) so the first tick
        never pays XLA compile on the serving path."""
        self.ingest.warm_gather(self._lens, batch_sizes=(self._Spad,))
        if self.service.vitals_model is not None \
                and "vitals" in self.ingest.states:
            self.ingest.warm_gather(
                (self.ingest.want["vitals"],),
                batch_sizes=(self._Spad,), modality="vitals")
        self.service.warmup(batch_sizes=(self._Spad,))


class SlotTicker:
    """Daemon-thread tick driver: calls ``engine.tick()`` every
    ``interval`` seconds.  ``interval`` is a plain writable float read
    fresh each cycle — ``TickLadder`` actuates it live, no restart.

    The thread is GENERATIONAL (PR 8's worker epoch-token idiom):
    ``respawn()`` bumps the epoch and starts a fresh thread; the
    abandoned generation exits at its next epoch check, and even one
    wedged inside a tick is harmless — the engine's tick lock makes
    the new generation SKIP while the zombie finishes, and the
    zombie's eventual stamp is a normally-guarded, correct (if late)
    tick.  Every generation ever spawned stays in ``_threads`` so
    ``stop()`` joins them ALL — a watchdog-respawned ticker can never
    orphan a thread past the leak checker.

    ``beat`` is the watchdog heartbeat: ``(epoch, count, stamp)``
    advanced after each tick by the CURRENT generation only (a stale
    generation can never beat).  ``before_tick`` is the fault plane's
    stall hook: it returns a stall duration in seconds (0 for none)
    and the ticker sleeps it out WITHOUT beating — an injected
    ``ticker_stall`` looks exactly like a wedged tick to the watchdog.
    """

    def __init__(self, engine: SlotEngine, interval: float = 0.05,
                 name: str = "repro-ticker"):
        self.engine = engine
        self.interval = float(interval)
        self._base_name = name
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._epoch = 0
        self.n_respawns = 0
        self.before_tick = None    # () -> float stall seconds, or None
        self._beat = (0, 0, time.monotonic())
        self._threads: List[threading.Thread] = [
            threading.Thread(target=self._run, args=(0,), daemon=True,
                             name=name)]

    def start(self) -> "SlotTicker":
        self._threads[-1].start()
        return self

    def _is_current(self, epoch: int) -> bool:
        with self._lock:
            return epoch == self._epoch

    def _beat_now(self, epoch: int) -> None:
        with self._lock:
            if epoch == self._epoch:
                self._beat = (epoch, self._beat[1] + 1,
                              time.monotonic())

    @property
    def beat(self) -> Tuple[int, int, float]:
        """(epoch, tick-loop count, monotonic stamp) — the stamp also
        resets on ``respawn()`` so a fresh generation gets a full
        deadline of grace before the watchdog may judge it."""
        with self._lock:
            return self._beat

    def _run(self, epoch: int) -> None:
        while not self._stop.wait(self.interval):
            if not self._is_current(epoch):
                return
            hook = self.before_tick
            if hook is not None:
                try:
                    dur = float(hook() or 0.0)
                except Exception:
                    log.exception("before_tick hook failed")
                    dur = 0.0
                if dur > 0:
                    time.sleep(dur)     # injected stall: no beat
            if not self._is_current(epoch):
                return
            try:
                self.engine.tick()
            except Exception:
                log.exception("slot tick failed; ticker continues")
            self._beat_now(epoch)

    def respawn(self) -> bool:
        """Abandon the current generation and start a fresh one.
        No-op (False) once stopped."""
        with self._lock:
            if self._stop.is_set():
                return False
            self._epoch += 1
            epoch = self._epoch
            t = threading.Thread(
                target=self._run, args=(epoch,), daemon=True,
                name=f"{self._base_name}-r{epoch}")
            self._threads.append(t)
            self.n_respawns += 1
            self._beat = (epoch, self._beat[1], time.monotonic())
        t.start()
        return True

    def stop(self, join_timeout: float = 2.0) -> bool:
        """Stop and join EVERY generation ever spawned (watchdog
        respawns included); True only when all of them exited."""
        self._stop.set()
        deadline = time.monotonic() + join_timeout
        ok = True
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))
            ok &= not t.is_alive()
        return ok

    def alive_threads(self) -> List[str]:
        """Names of every still-running generation (the server's leak
        accounting surface)."""
        with self._lock:
            threads = list(self._threads)
        return [t.name for t in threads if t.is_alive()]

    @property
    def alive(self) -> bool:
        """True when the CURRENT generation's thread is running (an
        abandoned zombie doesn't count — it never ticks again)."""
        with self._lock:
            return self._threads[-1].is_alive()

    @property
    def name(self) -> str:
        with self._lock:
            return self._threads[-1].name


class TickerWatchdog:
    """Heartbeat watchdog over a ``SlotTicker``: a daemon poll loop
    that respawns the ticker when its current generation dies or its
    beat stamp goes quiet past the deadline (a wedged tick, an
    injected ticker stall).  Readers are already safe during the gap
    — ``read()``'s tick-age guard and ``wait_scored()``'s timeout
    surface NaN-or-stale, never a wrong score — so the watchdog's
    only job is to get ticks flowing again.

    The quiet threshold is ``deadline_seconds + ticker.interval``
    (read live, so a ``TickLadder`` shed to a slow rung doesn't read
    as a stall), and the beat stamp resets on every respawn, giving
    each new generation a full deadline of grace — no respawn storms.
    """

    def __init__(self, ticker: SlotTicker,
                 deadline_seconds: float = 1.0, poll: float = 0.05,
                 name: str = "repro-tickwatch"):
        if deadline_seconds <= 0:
            raise ValueError("deadline must be positive")
        self.ticker = ticker
        self.deadline = float(deadline_seconds)
        self.poll = float(poll)
        self.n_respawns = 0
        self.events: List[Dict] = []
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)

    def start(self) -> "TickerWatchdog":
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.poll):
            epoch, _count, stamp = self.ticker.beat
            quiet = time.monotonic() - stamp
            dead = not self.ticker.alive
            if not dead and quiet <= self.deadline + self.ticker.interval:
                continue
            if self.ticker.respawn():
                self.n_respawns += 1
                self.events.append({
                    "cause": "dead" if dead else "stall",
                    "epoch": epoch, "quiet_s": round(quiet, 4)})
            else:
                return      # ticker stopped for good: nothing to guard

    def stop(self, join_timeout: float = 2.0) -> bool:
        self._stop.set()
        self._thread.join(timeout=join_timeout)
        return not self._thread.is_alive()

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    @property
    def name(self) -> str:
        return self._thread.name


class TickLadder:
    """Tick RATE as a degradation-ladder knob, duck-typing
    ``control.swap.SelectorLadder``'s shed/climb protocol so the
    adaptive controller can actuate it exactly like it sheds ensemble
    members: rung 0 is the cheapest (slowest tick — least device work
    per second), the last rung the richest (fastest tick — freshest
    scores).  ``shed()`` slows the tick, ``climb()`` speeds it up;
    both write ``ticker.interval`` atomically under the ladder lock.
    """

    def __init__(self, ticker: SlotTicker,
                 intervals: Sequence[float],
                 start: Optional[int] = None):
        rungs = sorted({float(i) for i in intervals}, reverse=True)
        if not rungs:
            raise ValueError("TickLadder needs at least one interval")
        if any(r <= 0 for r in rungs):
            raise ValueError("tick intervals must be positive")
        self.ticker = ticker
        self._ladder = rungs
        self._lock = threading.RLock()
        pos = len(rungs) - 1 if start is None else int(start)
        if not 0 <= pos < len(rungs):
            raise ValueError(f"start rung {pos} outside ladder of "
                             f"{len(rungs)}")
        self._pos = pos
        self._activate(rungs[pos])

    @property
    def ladder(self) -> List[float]:
        return list(self._ladder)

    @property
    def ladder_pos(self) -> int:
        return self._pos

    @property
    def active_interval(self) -> float:
        return self._ladder[self._pos]

    def can_shed(self) -> bool:
        return self._pos > 0

    def can_climb(self) -> bool:
        return self._pos < len(self._ladder) - 1

    def shed(self) -> bool:
        with self._lock:
            if not self.can_shed():
                return False
            self._pos -= 1
            self._activate(self._ladder[self._pos])
            return True

    def climb(self) -> bool:
        with self._lock:
            if not self.can_climb():
                return False
            self._pos += 1
            self._activate(self._ladder[self._pos])
            return True

    def swap_to(self, pos: int) -> None:
        with self._lock:
            if not 0 <= pos < len(self._ladder):
                raise ValueError(f"rung {pos} outside ladder of "
                                 f"{len(self._ladder)}")
            self._pos = pos
            self._activate(self._ladder[pos])

    def _activate(self, interval: float) -> None:
        self.ticker.interval = float(interval)
