"""Wall-clock serving server: the HTTP-ingest stand-in of Fig. 4 as a
threaded request loop — bounded ingest queue, N device-worker threads
draining per-model tasks, SLO accounting.

Workers are batch-aware: given a ``batch_handler`` (e.g.
``EnsembleService.predict_batch``) they coalesce queries from many
patients through a shared ``MicroBatcher`` (bounded by ``max_batch`` /
``max_wait_ms``) and retire each flush with ONE fused ensemble call.
With only a scalar ``handler`` they process queries one at a time as
before.

The ``windows`` payload is OPAQUE to the server: a host window dict,
or — under device-resident ingest — a
``serving.aggregator.DeviceWindowRef`` (three host integers per
modality; the flush gathers the samples on device).  Queue bounds,
shedding, telemetry taps and tier routing are identical either way,
so switching the ingest side to the device rings changes nothing
above ``submit``.

Tiered serving: with ``tier_of`` (patient id -> acuity tier, e.g.
``control.tiers.TierRegistry.tier_of``) the batcher becomes tier-KEYED
— cross-patient coalescing still happens, but only WITHIN a tier — and
every flush is handed to ``batch_handler(windows, tier)`` (e.g.
``control.tiers.TieredEnsemble.predict_batch``), so each query is
served by exactly its tier's (selector, placement) service.  The
telemetry tap always carries the patient id, so per-tier SLO slices
(``control.telemetry.TieredTelemetry``) come for free.

The DES simulator (simulator.py) is the deterministic twin used by the
latency profiler and benchmarks; this server is the "really runs" path
the examples exercise (real jitted inference, real clocks).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.queues import NO_LANE, KeyedMicroBatcher, MicroBatcher


class ServerStats:
    """Thread-safe serving counters.  Worker threads ``record()``
    retired queries concurrently with readers: every mutation holds the
    internal lock, and ``p()``/``snapshot()`` copy the latency list
    under it, so percentile reads are snapshot-consistent instead of
    racing ongoing appends."""

    def __init__(self):
        self._lock = threading.Lock()
        self.served = 0
        self.slo_violations = 0
        self.shed = 0
        self.latencies: List[float] = []

    def record(self, latency: float, violated: bool) -> None:
        with self._lock:
            self.served += 1
            self.latencies.append(latency)
            if violated:
                self.slo_violations += 1

    def record_shed(self) -> None:
        with self._lock:
            self.shed += 1

    @property
    def violation_rate(self) -> float:
        with self._lock:
            return self.slo_violations / self.served if self.served else 0.0

    def snapshot(self) -> List[float]:
        with self._lock:
            return list(self.latencies)

    def p(self, pct: float) -> float:
        lat = self.snapshot()
        return float(np.percentile(lat, pct)) if lat else 0.0


class EnsembleServer:
    """Serves ensemble queries with a pool of worker threads (the
    stateless-actor pool; one thread ~ one device in the CPU demo).

    handler(query) -> score runs the jitted ensemble per query;
    batch_handler(queries) -> scores runs one fused flush for a
    micro-batch (takes precedence when given).  Queries are
    (patient_id, windows dict) tuples submitted by the ingest side.
    """

    def __init__(self, handler: Optional[Callable[[Dict], float]] = None,
                 n_workers: int = 2, slo_seconds: float = 1.0,
                 max_queue: int = 1024,
                 batch_handler: Optional[
                     Callable[[Sequence[Dict]], List[float]]] = None,
                 max_batch: int = 8, max_wait_ms: float = 2.0,
                 telemetry=None,
                 tier_of: Optional[Callable[[int], object]] = None):
        assert handler is not None or batch_handler is not None
        self.handler = handler
        self.batch_handler = batch_handler
        self.slo = slo_seconds
        self.q: "queue.Queue" = queue.Queue(maxsize=max_queue)
        # tiered mode: per-tier coalescing lanes; batch_handler then
        # takes (windows, tier) so a flush is served by ITS tier only
        if tier_of is not None and batch_handler is None:
            raise ValueError("tier_of requires a batch_handler (the "
                             "scalar handler path has no tier routing)")
        self.tier_of = tier_of
        self.batcher = (
            KeyedMicroBatcher(max_batch=max_batch, max_wait_ms=max_wait_ms)
            if self.tier_of is not None
            else MicroBatcher(max_batch=max_batch, max_wait_ms=max_wait_ms))
        self.stats = ServerStats()
        # control-plane tap (duck-typed control.telemetry.SloTelemetry):
        # every ingest is an arrival, every retired query a latency sample
        self.telemetry = telemetry
        self._stop = threading.Event()
        self._results: "queue.Queue" = queue.Queue()
        self._workers = [threading.Thread(target=self._run, daemon=True)
                         for _ in range(n_workers)]

    def start(self) -> "EnsembleServer":
        for w in self._workers:
            w.start()
        return self

    def submit(self, patient: int, windows: Dict,
               t_window: Optional[float] = None) -> bool:
        """Non-blocking ingest; returns False if the queue is full
        (overload shedding rather than unbounded latency)."""
        t_window = t_window if t_window is not None else time.monotonic()
        try:
            self.q.put_nowait((patient, windows, t_window))
            if self.telemetry is not None:
                self.telemetry.record_arrival(t_window, patient=patient)
            return True
        except queue.Full:
            self.stats.record_shed()
            if self.telemetry is not None:
                self.telemetry.record_shed(t_window, patient=patient)
            return False

    # ------------------------------------------------------------ workers
    def _retire(self, tasks: Sequence, scores: Sequence[float]) -> None:
        now = time.monotonic()
        for (patient, _w, t_window), score in zip(tasks, scores):
            lat = now - t_window
            self.stats.record(lat, lat > self.slo)
            if self.telemetry is not None:
                self.telemetry.record_served(lat, now, patient=patient)
            self._results.put((patient, score, lat))
        for _ in tasks:
            self.q.task_done()

    def _call_batch(self, windows: List[Dict], tier=None) -> List[float]:
        if self.tier_of is None:
            return list(self.batch_handler(windows))
        return list(self.batch_handler(windows, tier))

    def _safe_batch_scores(self, windows: List[Dict],
                           tier=None) -> List[float]:
        """A failing flush must not kill the worker or drop its healthy
        co-batched queries: retry singly, scoring only the bad ones NaN."""
        try:
            return self._call_batch(windows, tier)
        except Exception:
            out = []
            for w in windows:
                try:
                    out.extend(self._call_batch([w], tier))
                except Exception:
                    out.append(float("nan"))
            return out

    def _run_batched(self) -> None:
        # short poll only while a batch is coalescing (to honor
        # max_wait); block at the long timeout when idle
        coalesce_poll = min(0.05, self.batcher.max_wait / 2 or 0.05)
        tiered = self.tier_of is not None
        while not self._stop.is_set():
            timeout = 0.05 if not len(self.batcher) else coalesce_poll
            try:
                task = self.q.get(timeout=timeout)
                if tiered:
                    # the tier is sampled at ROUTING time: a mid-queue
                    # escalation moves the patient's NEXT queries.  A
                    # failing tier_of must not kill the worker or
                    # strand the popped query — route to the default
                    # lane (None: TierRouter/TieredEnsemble fall back)
                    try:
                        key = self.tier_of(task[0])
                    except Exception:
                        key = None
                    self.batcher.push(key, task)
                else:
                    self.batcher.push(task)
            except queue.Empty:
                pass
            if tiered:
                tier = self.batcher.ready()
                if tier is NO_LANE:
                    continue
                tasks = self.batcher.pop_batch(tier)
            else:
                tier = None
                if not self.batcher.ready():
                    continue
                tasks = self.batcher.pop_batch()
            if not tasks:
                continue
            scores = self._safe_batch_scores([w for _, w, _ in tasks],
                                             tier)
            self._retire(tasks, scores)

    def _run(self) -> None:
        if self.batch_handler is not None:
            return self._run_batched()
        while not self._stop.is_set():
            try:
                task = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                score = self.handler(task[1])
            except Exception:
                score = float("nan")
            self._retire([task], [score])

    def results(self, max_items: int = 0) -> List:
        out = []
        while not self._results.empty() and (
                not max_items or len(out) < max_items):
            out.append(self._results.get_nowait())
        return out

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every submitted query has been FULLY processed
        (queue.join semantics, with a timeout).  Checking ``q.empty()``
        is not enough: a worker may have popped the last task and still
        be mid-handler (or the task may be coalescing in the batcher),
        which used to undercount ``stop()`` stats."""
        deadline = time.monotonic() + timeout
        with self.q.all_tasks_done:
            while self.q.unfinished_tasks:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self.q.all_tasks_done.wait(min(0.05, remaining))

    def stop(self) -> ServerStats:
        self.drain()
        self._stop.set()
        for w in self._workers:
            w.join(timeout=2.0)
        return self.stats
