"""Wall-clock serving server: the HTTP-ingest stand-in of Fig. 4 as a
threaded request loop — bounded ingest queue, N device-worker threads
draining per-model tasks, SLO accounting.

The DES simulator (simulator.py) is the deterministic twin used by the
latency profiler and benchmarks; this server is the "really runs" path
the examples exercise (real jitted inference, real clocks).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class ServerStats:
    served: int = 0
    slo_violations: int = 0
    latencies: List[float] = dataclasses.field(default_factory=list)

    def p(self, pct: float) -> float:
        return float(np.percentile(self.latencies, pct)) \
            if self.latencies else 0.0


class EnsembleServer:
    """Serves ensemble queries with a pool of worker threads (the
    stateless-actor pool; one thread ~ one device in the CPU demo).

    handler(query) -> score runs the jitted ensemble; queries are
    (patient_id, windows dict) tuples submitted by the ingest side.
    """

    def __init__(self, handler: Callable[[Dict], float],
                 n_workers: int = 2, slo_seconds: float = 1.0,
                 max_queue: int = 1024):
        self.handler = handler
        self.slo = slo_seconds
        self.q: "queue.Queue" = queue.Queue(maxsize=max_queue)
        self.stats = ServerStats()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._results: "queue.Queue" = queue.Queue()
        self._workers = [threading.Thread(target=self._run, daemon=True)
                         for _ in range(n_workers)]

    def start(self) -> "EnsembleServer":
        for w in self._workers:
            w.start()
        return self

    def submit(self, patient: int, windows: Dict,
               t_window: Optional[float] = None) -> bool:
        """Non-blocking ingest; returns False if the queue is full
        (overload shedding rather than unbounded latency)."""
        t_window = t_window if t_window is not None else time.monotonic()
        try:
            self.q.put_nowait((patient, windows, t_window))
            return True
        except queue.Full:
            return False

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                patient, windows, t_window = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            score = self.handler(windows)
            lat = time.monotonic() - t_window
            with self._lock:
                self.stats.served += 1
                self.stats.latencies.append(lat)
                if lat > self.slo:
                    self.stats.slo_violations += 1
            self._results.put((patient, score, lat))
            self.q.task_done()

    def results(self, max_items: int = 0) -> List:
        out = []
        while not self._results.empty() and (
                not max_items or len(out) < max_items):
            out.append(self._results.get_nowait())
        return out

    def drain(self, timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        while not self.q.empty() and time.monotonic() < deadline:
            time.sleep(0.01)

    def stop(self) -> ServerStats:
        self.drain()
        self._stop.set()
        for w in self._workers:
            w.join(timeout=2.0)
        return self.stats
