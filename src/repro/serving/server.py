"""Wall-clock serving server: the HTTP-ingest stand-in of Fig. 4 as a
threaded request loop — bounded ingest queue, N device-worker threads
draining per-model tasks, SLO accounting.

Workers are batch-aware: given a ``batch_handler`` (e.g.
``EnsembleService.predict_batch``) they coalesce queries from many
patients through a shared ``MicroBatcher`` (bounded by ``max_batch`` /
``max_wait_ms``) and retire each flush with ONE fused ensemble call.
With only a scalar ``handler`` they process queries one at a time as
before.

The ``windows`` payload is OPAQUE to the server: a host window dict,
or — under device-resident ingest — a
``serving.aggregator.DeviceWindowRef`` (three host integers per
modality; the flush gathers the samples on device).  Queue bounds,
shedding, telemetry taps and tier routing are identical either way,
so switching the ingest side to the device rings changes nothing
above ``submit``.

Tiered serving: with ``tier_of`` (patient id -> acuity tier, e.g.
``control.tiers.TierRegistry.tier_of``) the batcher becomes tier-KEYED
— cross-patient coalescing still happens, but only WITHIN a tier — and
every flush is handed to ``batch_handler(windows, tier)`` (e.g.
``control.tiers.TieredEnsemble.predict_batch``), so each query is
served by exactly its tier's (selector, placement) service.  The
telemetry tap always carries the patient id, so per-tier SLO slices
(``control.telemetry.TieredTelemetry``) come for free.

Continuous slot serving: ``engine="slots"`` (with a
``serving.slots.SlotEngine``) subsumes the micro-batcher on the hot
path entirely — ``submit`` folds each closed window into the bed's
persistent slot, a dedicated ticker thread scores ALL occupied slots
every tick with one fused step, and workers retire each query with a
version-gated host int read (zero dispatches, zero H2D per query).
Queue bounds, shedding, stats, telemetry taps and span tracing are
identical to the flush engine; staleness becomes a tick-age guard
(``slot_wait_timeout``) instead of the flush deadline.

Fault tolerance:

* the ingest queue is a ``ShedQueue`` bounding UNFINISHED work (queued
  + coalescing + in-flight) at ``max_queue`` — the micro-batcher lanes
  can no longer grow without limit under backpressure;
* with ``tier_priority`` (tier -> numeric priority), overrun admission
  is priority-aware: a higher-priority query evicts the oldest
  lowest-priority queued one (stable tier sheds first), and a critical
  query is never bumped by a lesser one.  Every rejection — incoming or
  evicted — is counted in ``ServerStats`` (``shed`` plus the per-tier
  ``rejected`` map) and tapped to telemetry; nothing is silently lost;
* with ``deadline_seconds`` a watchdog thread bounds how long any
  co-batch may be in-flight: a stalled worker's batch is retired NaN
  (the existing failure score — downstream treats it exactly like a
  poisoned query), the worker is marked abandoned and a replacement is
  spawned.  When the stalled handler eventually returns, the abandoned
  worker discards its late scores and exits, so every query is retired
  exactly once and ``drain()`` conservation holds through stalls.

The DES simulator (simulator.py) is the deterministic twin used by the
latency profiler and benchmarks; this server is the "really runs" path
the examples exercise (real jitted inference, real clocks).
"""
from __future__ import annotations

import logging
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs import sketch as _sketch
from repro.obs import spans as _spans
from repro.serving.queues import (NO_LANE, KeyedMicroBatcher, MicroBatcher,
                                  ShedQueue)

log = logging.getLogger(__name__)


class Task:
    """One submitted query in flight through the server.  Replaces the
    old ``(patient, windows, t_window)`` tuple so the span stamps the
    tracer needs ride the object itself instead of a side table.  All
    fields except the first three are stamped lazily on the trace
    path; ``__slots__`` keeps the per-query footprint tuple-sized.
    ``version`` is the slot engine's close version under
    ``engine="slots"`` (which tick must land before the read)."""

    __slots__ = ("patient", "windows", "t_window", "tier",
                 "t_dequeue", "t_flush", "batch_n", "stages", "version")

    def __init__(self, patient: int, windows: Dict, t_window: float,
                 tier: object = None):
        self.patient = patient
        self.windows = windows
        self.t_window = t_window
        self.tier = tier
        self.t_dequeue = t_window
        self.t_flush = t_window
        self.batch_n = 1
        self.stages: Optional[Dict[str, float]] = None
        self.version = 0


class ServerStats:
    """Thread-safe serving counters.  Worker threads ``record()``
    retired queries concurrently with readers: every mutation holds the
    internal lock, and ``p()``/``snapshot()`` read the latency
    histogram under it, so percentile reads are snapshot-consistent
    instead of racing ongoing updates.

    Latencies live in the obs plane's log-spaced histogram
    (``obs.sketch``: fixed ``N_BINS`` bins, growth 1.12), NOT a list:
    an hours-long soak retires millions of queries, and the pre-fix
    unbounded ``latencies`` list grew O(n) memory while ``p()`` paid an
    O(n log n) copy-and-sort per read.  Now memory is O(1), ``record``
    is O(log bins) and ``p()`` is O(bins), with quantiles within the
    sketch's ~5.8% relative-error bound (``sketch.REL_ERR_BOUND``).
    The ``served``/``failed``/``shed``/``stalls`` counters and the
    latency SUM stay exact — only quantiles are approximate.

    ``served`` counts every retired query including failures; ``failed``
    is the NaN-scored subset (poisoned / stale / stall-killed), so
    ``served - failed`` is the number of REAL scores delivered.
    ``shed`` counts every rejected query, with the per-tier breakdown in
    ``rejected`` (key None for untiered submits); ``stalls`` counts
    watchdog-killed co-batches."""

    def __init__(self):
        self._lock = threading.Lock()
        self.served = 0
        self.slo_violations = 0
        self.shed = 0
        self.failed = 0
        self.stalls = 0
        self.rejected: Dict[object, int] = {}
        self._lat_counts = np.zeros(_sketch.N_BINS, np.int64)
        self._lat_sum = 0.0
        self._lat_max = 0.0

    def record(self, latency: float, violated: bool,
               failed: bool = False) -> None:
        with self._lock:
            self.served += 1
            self._lat_counts[_sketch.bin_index(latency)] += 1
            self._lat_sum += latency
            if latency > self._lat_max:
                self._lat_max = latency
            if violated:
                self.slo_violations += 1
            if failed:
                self.failed += 1

    def record_shed(self, tier: object = None) -> None:
        with self._lock:
            self.shed += 1
            self.rejected[tier] = self.rejected.get(tier, 0) + 1

    def record_stall(self) -> None:
        with self._lock:
            self.stalls += 1

    @property
    def violation_rate(self) -> float:
        with self._lock:
            return self.slo_violations / self.served if self.served else 0.0

    @property
    def n_latencies(self) -> int:
        """Exact number of recorded latency samples (== ``served``)."""
        with self._lock:
            return int(self._lat_counts.sum())

    @property
    def mean_latency(self) -> float:
        """Exact mean served latency (the sum is kept exactly; only
        quantiles go through the histogram)."""
        with self._lock:
            n = int(self._lat_counts.sum())
            return self._lat_sum / n if n else 0.0

    @property
    def max_latency(self) -> float:
        with self._lock:
            return self._lat_max

    def snapshot(self) -> np.ndarray:
        """Consistent copy of the latency histogram bin counts
        (``obs.sketch`` bin layout — mergeable across servers by
        elementwise sum)."""
        with self._lock:
            return self._lat_counts.copy()

    def p(self, pct: float) -> float:
        counts = self.snapshot()
        return _sketch.quantile_from_counts(counts, pct)


class EnsembleServer:
    """Serves ensemble queries with a pool of worker threads (the
    stateless-actor pool; one thread ~ one device in the CPU demo).

    handler(query) -> score runs the jitted ensemble per query;
    batch_handler(queries) -> scores runs one fused flush for a
    micro-batch (takes precedence when given).  Queries are
    (patient_id, windows dict) tuples submitted by the ingest side.
    """

    def __init__(self, handler: Optional[Callable[[Dict], float]] = None,
                 n_workers: int = 2, slo_seconds: float = 1.0,
                 max_queue: int = 1024,
                 batch_handler: Optional[
                     Callable[[Sequence[Dict]], List[float]]] = None,
                 max_batch: int = 8, max_wait_ms: float = 2.0,
                 telemetry=None,
                 tier_of: Optional[Callable[[int], object]] = None,
                 tier_priority: Optional[Dict[object, float]] = None,
                 deadline_seconds: Optional[float] = None,
                 watchdog_interval: float = 0.02,
                 tracer: Optional["_spans.SpanRecorder"] = None,
                 engine: str = "flush",
                 slot_engine=None,
                 tick_interval: float = 0.02,
                 slot_wait_timeout: Optional[float] = None,
                 ticker_deadline_seconds: Optional[float] = None):
        if engine not in ("flush", "slots"):
            raise ValueError(f"unknown engine {engine!r}")
        if engine == "slots":
            # continuous slot serving: no per-query handler at all — a
            # dedicated ticker scores every occupied slot each tick and
            # workers just version-gate a host read per query, so the
            # micro-batcher is subsumed entirely on the hot path
            if slot_engine is None:
                raise ValueError('engine="slots" needs a slot_engine '
                                 "(serving.slots.SlotEngine)")
            if handler is not None or batch_handler is not None:
                raise ValueError('engine="slots" replaces the handlers; '
                                 "pass neither")
            if tier_of is not None or tier_priority is not None:
                raise ValueError('engine="slots" is untiered (one slot '
                                 "plane per census); drop tier_of")
        else:
            assert handler is not None or batch_handler is not None
            if slot_engine is not None:
                raise ValueError('slot_engine needs engine="slots"')
        self.engine = engine
        self.slot_engine = slot_engine
        self._slot_wait = (slot_wait_timeout
                           if slot_wait_timeout is not None
                           else max(1.0, 10.0 * tick_interval))
        self.ticker = None
        self.ticker_watchdog = None
        if engine == "slots":
            from repro.serving.slots import SlotTicker, TickerWatchdog
            self.ticker = SlotTicker(slot_engine, interval=tick_interval)
            if ticker_deadline_seconds is not None:
                # heartbeat watchdog: a dead or stalled ticker is
                # respawned; readers ride the gap on the tick-age
                # guard / wait timeout (NaN-or-stale, never wrong)
                self.ticker_watchdog = TickerWatchdog(
                    self.ticker, deadline_seconds=ticker_deadline_seconds)
        elif ticker_deadline_seconds is not None:
            raise ValueError('ticker_deadline_seconds needs '
                             'engine="slots"')
        self.handler = handler
        self.batch_handler = batch_handler
        self.slo = slo_seconds
        self.q = ShedQueue(maxsize=max_queue)
        # tiered mode: per-tier coalescing lanes; batch_handler then
        # takes (windows, tier) so a flush is served by ITS tier only
        if tier_of is not None and batch_handler is None:
            raise ValueError("tier_of requires a batch_handler (the "
                             "scalar handler path has no tier routing)")
        if tier_priority is not None and tier_of is None:
            raise ValueError("tier_priority requires tier_of (priorities "
                             "are keyed by acuity tier)")
        self.tier_of = tier_of
        self.tier_priority = tier_priority
        self.batcher = (
            KeyedMicroBatcher(max_batch=max_batch, max_wait_ms=max_wait_ms)
            if self.tier_of is not None
            else MicroBatcher(max_batch=max_batch, max_wait_ms=max_wait_ms))
        self.stats = ServerStats()
        # control-plane tap (duck-typed control.telemetry.SloTelemetry):
        # every ingest is an arrival, every retired query a latency sample
        self.telemetry = telemetry
        # span tracer (obs.spans.SpanRecorder): when set, every retired
        # query emits a lifecycle SpanRecord with stage attribution
        self.tracer = tracer
        self.deadline = deadline_seconds
        self._wd_interval = watchdog_interval
        self._wd_lock = threading.Lock()
        # watchdog bookkeeping is keyed by a per-worker EPOCH TOKEN
        # (the monotonic spawn counter, stamped into a thread-local at
        # worker start), NOT ``threading.get_ident()``: the OS reuses
        # idents after a thread exits, so a replacement worker could
        # inherit its stalled predecessor's ``_abandoned`` entry and
        # silently discard a healthy co-batch's scores — breaking the
        # "every query retires exactly once" contract.  Epoch tokens
        # are never reused within a server's lifetime.
        self._inflight: Dict[int, tuple] = {}    # token -> (t0, tasks)
        self._abandoned: set = set()             # tokens killed by watchdog
        self._worker_token = threading.local()
        self._stop = threading.Event()
        self._results: "queue.Queue" = queue.Queue()
        self._spawned = 0
        self._workers = [self._make_worker() for _ in range(n_workers)]
        self._watchdog = (
            threading.Thread(target=self._watch, daemon=True,
                             name="repro-watchdog")
            if self.deadline is not None else None)
        self.leaked: List[str] = []

    def _make_worker(self) -> threading.Thread:
        self._spawned += 1
        return threading.Thread(target=self._run, args=(self._spawned,),
                                daemon=True,
                                name=f"repro-worker-{self._spawned}")

    def _token(self) -> int:
        """The calling worker's epoch token (its spawn ordinal).  A
        non-worker caller (tests poking ``heartbeat`` from the main
        thread) gets a sentinel that is never in the watchdog maps."""
        return getattr(self._worker_token, "token", -1)

    def start(self) -> "EnsembleServer":
        for w in self._workers:
            w.start()
        if self._watchdog is not None:
            self._watchdog.start()
        if self.ticker is not None:
            self.ticker.start()
        if self.ticker_watchdog is not None:
            self.ticker_watchdog.start()
        return self

    def _tier_and_priority(self, patient: int):
        tier = None
        if self.tier_of is not None:
            try:
                tier = self.tier_of(patient)
            except Exception:
                tier = None
        prio = 0.0
        if self.tier_priority is not None:
            prio = float(self.tier_priority.get(tier, 0.0))
        return tier, prio

    def submit(self, patient: int, windows: Dict,
               t_window: Optional[float] = None) -> bool:
        """Non-blocking ingest; returns False if the queue is full
        (overload shedding rather than unbounded latency).  With
        ``tier_priority`` set, admission under overrun is priority-aware:
        the newcomer may evict a strictly lower-priority queued query
        (which is then counted shed) instead of being rejected itself."""
        t_window = t_window if t_window is not None else time.monotonic()
        tier, prio = self._tier_and_priority(patient)
        task = Task(patient, windows, t_window, tier)
        if self.engine == "slots":
            # fold the closed window into the bed's slot BEFORE
            # admission control: even if the read request is shed, the
            # slot state must stay fresh (monitoring never regresses)
            task.version = self.slot_engine.update(windows)
        try:
            if self.tier_priority is not None:
                ok, victim = self.q.put_evicting(task, priority=prio,
                                                 tag=tier)
                if not ok:
                    raise queue.Full
                if victim is not None:
                    vtask, vtier = victim
                    self.stats.record_shed(vtier)
                    if self.telemetry is not None:
                        self.telemetry.record_shed(t_window,
                                                   patient=vtask.patient)
            else:
                self.q.put_nowait(task, priority=prio, tag=tier)
            if self.telemetry is not None:
                self.telemetry.record_arrival(t_window, patient=patient)
            return True
        except queue.Full:
            self.stats.record_shed(tier)
            if self.telemetry is not None:
                self.telemetry.record_shed(t_window, patient=patient)
            return False

    # ------------------------------------------------------------ workers
    def _retire(self, tasks: Sequence, scores: Sequence[float],
                cause: Optional[str] = None) -> None:
        now = time.monotonic()
        for task, score in zip(tasks, scores):
            lat = now - task.t_window
            failed = score != score           # NaN-safe for float/np
            self.stats.record(lat, lat > self.slo, failed=failed)
            if self.telemetry is not None:
                self.telemetry.record_served(lat, now,
                                             patient=task.patient)
                if failed:
                    tap = getattr(self.telemetry, "record_failure", None)
                    if tap is not None:
                        tap(now, patient=task.patient)
            if self.tracer is not None:
                st = task.stages or {}
                self.tracer.record(_spans.SpanRecord(
                    patient=task.patient, tier=task.tier,
                    status=cause or ("failed" if failed else "ok"),
                    t_submit=task.t_window, t_dequeue=task.t_dequeue,
                    t_flush=task.t_flush, t_retire=now,
                    batch_n=task.batch_n,
                    marshal_s=st.get("marshal", 0.0),
                    dispatch_s=st.get("dispatch", 0.0),
                    gather_s=st.get("gather", 0.0)))
            self._results.put((task.patient, score, lat, task.windows))
        for _ in tasks:
            self.q.task_done()

    # ----------------------------------------------------------- watchdog
    def _begin_inflight(self, tasks: Sequence) -> None:
        if self.deadline is None:
            return
        with self._wd_lock:
            self._inflight[self._token()] = (time.monotonic(),
                                             list(tasks))

    def heartbeat(self) -> bool:
        """Refresh the calling worker's in-flight deadline.  For
        handlers legitimately WAITING — a device-loss retry loop riding
        out a failover restage — so the watchdog keeps catching silent
        hangs without NaN-failing a co-batch that is alive and making
        progress.  A genuinely stalled worker never calls this, which
        is exactly the distinction the watchdog needs.  Returns False
        when the watchdog already abandoned the co-batch (the caller's
        scores will be discarded; it may stop retrying)."""
        if self.deadline is None:
            return True
        me = self._token()
        with self._wd_lock:
            if me in self._inflight:
                _, tasks = self._inflight[me]
                self._inflight[me] = (time.monotonic(), tasks)
                return True
            return me not in self._abandoned

    def _end_inflight(self) -> bool:
        """Clear this worker's in-flight record.  Returns False when the
        watchdog already gave up on the co-batch (retired it NaN and
        respawned a replacement): the late scores must be DISCARDED and
        the worker must exit, so each query retires exactly once."""
        if self.deadline is None:
            return True
        me = self._token()
        with self._wd_lock:
            self._inflight.pop(me, None)
            if me in self._abandoned:
                self._abandoned.discard(me)
                return False
        return True

    def _watch(self) -> None:
        """Deadline enforcement: a co-batch in-flight longer than
        ``deadline_seconds`` is failed safely (NaN scores — the same
        path a poisoned flush takes) and its worker replaced.  Never
        blocks on the stalled handler itself."""
        while not self._stop.wait(self._wd_interval):
            now = time.monotonic()
            overdue = []
            with self._wd_lock:
                for token, (t0, tasks) in list(self._inflight.items()):
                    if now - t0 > self.deadline:
                        del self._inflight[token]
                        self._abandoned.add(token)
                        overdue.append(tasks)
            for tasks in overdue:
                self.stats.record_stall()
                log.warning("watchdog: co-batch of %d overran deadline "
                            "%.3fs; failing NaN and respawning worker",
                            len(tasks), self.deadline)
                self._retire(tasks, [float("nan")] * len(tasks),
                             cause="watchdog")
                w = self._make_worker()
                self._workers.append(w)
                w.start()

    def _call_batch(self, windows: List[Dict], tier=None) -> List[float]:
        if self.tier_of is None:
            return list(self.batch_handler(windows))
        return list(self.batch_handler(windows, tier))

    def _safe_batch_scores(self, windows: List[Dict],
                           tier=None) -> List[float]:
        """A failing flush must not kill the worker or drop its healthy
        co-batched queries: retry singly, scoring only the bad ones NaN."""
        try:
            return self._call_batch(windows, tier)
        except Exception:
            out = []
            for w in windows:
                try:
                    out.extend(self._call_batch([w], tier))
                except Exception:
                    out.append(float("nan"))
            return out

    def _run_batched(self) -> None:
        # short poll only while a batch is coalescing (to honor
        # max_wait); block at the long timeout when idle
        coalesce_poll = min(0.05, self.batcher.max_wait / 2 or 0.05)
        tiered = self.tier_of is not None
        tracing = self.tracer is not None
        while not self._stop.is_set():
            timeout = 0.05 if not len(self.batcher) else coalesce_poll
            try:
                task = self.q.get(timeout=timeout)
                if tracing:
                    task.t_dequeue = time.monotonic()
                if tiered:
                    # the tier is sampled at ROUTING time: a mid-queue
                    # escalation moves the patient's NEXT queries.  A
                    # failing tier_of must not kill the worker or
                    # strand the popped query — route to the default
                    # lane (None: TierRouter/TieredEnsemble fall back)
                    try:
                        key = self.tier_of(task.patient)
                    except Exception:
                        key = None
                    task.tier = key
                    self.batcher.push(key, task)
                else:
                    self.batcher.push(task)
            except queue.Empty:
                pass
            if tiered:
                tier = self.batcher.ready()
                if tier is NO_LANE:
                    continue
                tasks = self.batcher.pop_batch(tier)
            else:
                tier = None
                if not self.batcher.ready():
                    continue
                tasks = self.batcher.pop_batch()
            if not tasks:
                continue
            windows = [t.windows for t in tasks]
            if tracing:
                # the stamps/sink are per co-batch: every rider shares
                # the flush time and the handler's stage attribution
                t_flush = time.monotonic()
                for t in tasks:
                    t.t_flush = t_flush
                    t.batch_n = len(tasks)
                self._begin_inflight(tasks)
                with _spans.collect() as acc:
                    scores = self._safe_batch_scores(windows, tier)
                for t in tasks:
                    t.stages = acc
            else:
                self._begin_inflight(tasks)
                scores = self._safe_batch_scores(windows, tier)
            if not self._end_inflight():
                return                  # watchdog replaced this worker
            self._retire(tasks, scores)

    def _run_slots(self) -> None:
        """Slot-engine worker: no handler, no batcher, no dispatch —
        wait for the tick covering the task's close version, then one
        host int read.  The wait is bounded by ``slot_wait_timeout``
        (default 10 tick intervals): a stopped ticker or a slot gone
        stale retires the query NaN instead of blocking forever — the
        tick-age guard in server form."""
        eng = self.slot_engine
        while not self._stop.is_set():
            try:
                task = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            task.t_dequeue = time.monotonic()
            ok = eng.wait_scored(task.patient, task.version,
                                 timeout=self._slot_wait)
            task.t_flush = time.monotonic()
            if ok:
                try:
                    score = eng.read(task.patient)
                except KeyError:          # discharged after scoring
                    score = float("nan")
            else:
                score = float("nan")
            self._retire([task], [score],
                         cause=None if ok else "stale")

    def _run(self, token: int = -1) -> None:
        # stamp this worker's epoch token before any watchdog-visible
        # work; everything downstream (_begin/_end_inflight, heartbeat)
        # reads it from the thread-local
        self._worker_token.token = token
        if self.engine == "slots":
            return self._run_slots()
        if self.batch_handler is not None:
            return self._run_batched()
        tracing = self.tracer is not None
        while not self._stop.is_set():
            try:
                task = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            if tracing:
                # scalar path has no coalesce stage: dequeue == flush
                task.t_dequeue = task.t_flush = time.monotonic()
                self._begin_inflight([task])
                try:
                    with _spans.collect() as acc:
                        score = self.handler(task.windows)
                except Exception:
                    score = float("nan")
                task.stages = acc
            else:
                self._begin_inflight([task])
                try:
                    score = self.handler(task.windows)
                except Exception:
                    score = float("nan")
            if not self._end_inflight():
                return                  # watchdog replaced this worker
            self._retire([task], [score])

    def results(self, max_items: int = 0) -> List:
        """Retired queries as ``(patient, score, latency, windows)``
        tuples; ``windows`` is the submitted payload (its ``extra`` side
        channel lets harnesses correlate results back to query ids)."""
        out = []
        while not self._results.empty() and (
                not max_items or len(out) < max_items):
            out.append(self._results.get_nowait())
        return out

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every submitted query has been FULLY processed
        (queue.join semantics, with a timeout).  Checking ``q.empty()``
        is not enough: a worker may have popped the last task and still
        be mid-handler (or the task may be coalescing in the batcher),
        which used to undercount ``stop()`` stats."""
        deadline = time.monotonic() + timeout
        with self.q.all_tasks_done:
            while self.q.unfinished_tasks:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self.q.all_tasks_done.wait(min(0.05, remaining))

    def stop(self, join_timeout: float = 2.0) -> ServerStats:
        """Drain, stop workers and watchdog, and report.  Threads that
        failed to exit (e.g. a handler still stalled past the join
        timeout) are listed by name in ``self.leaked`` and logged —
        never silently ignored."""
        self.drain()
        self._stop.set()
        threads = list(self._workers)
        if self._watchdog is not None:
            threads.append(self._watchdog)
        for t in threads:
            t.join(timeout=join_timeout)
        self.leaked = [t.name for t in threads if t.is_alive()]
        if self.ticker_watchdog is not None:
            # the watchdog stops FIRST so it cannot respawn a ticker
            # generation behind the ticker join below
            if not self.ticker_watchdog.stop(join_timeout):
                self.leaked.append(self.ticker_watchdog.name)
        if self.ticker is not None and not self.ticker.stop(join_timeout):
            # every generation a respawn ever left behind is accounted
            self.leaked.extend(self.ticker.alive_threads())
        if self.leaked:
            log.warning("server stop(): threads still alive: %s",
                        self.leaked)
        return self.stats
