"""Timestamped FIFO queues (the per-modality ensemble queues of Fig. 4)
with waiting-time statistics for the latency profiler, plus the
cross-patient ``MicroBatcher`` that coalesces ready windows into fused
ensemble flushes (serving.pipeline.EnsembleService.predict_batch).

``KeyedMicroBatcher`` is the tiered-serving variant: one coalescing
lane per key (acuity tier), so a flush never mixes tiers — every
micro-batch is served whole by ONE tier's (selector, placement)
service while cross-patient amortisation still happens within a tier.
"""
from __future__ import annotations

import collections
import dataclasses
import queue as _queue
import threading
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class QueueStats:
    n_pushed: int = 0
    n_popped: int = 0
    total_wait: float = 0.0
    max_wait: float = 0.0
    max_depth: int = 0

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.n_popped if self.n_popped else 0.0


class TimestampedQueue:
    def __init__(self, name: str = "q"):
        self.name = name
        self._q: Deque[Tuple[float, Any]] = collections.deque()
        self.stats = QueueStats()

    def push(self, t: float, item: Any) -> None:
        self._q.append((t, item))
        self.stats.n_pushed += 1
        self.stats.max_depth = max(self.stats.max_depth, len(self._q))

    def pop(self, now: float) -> Optional[Any]:
        if not self._q:
            return None
        t_in, item = self._q.popleft()
        wait = max(0.0, now - t_in)
        self.stats.n_popped += 1
        self.stats.total_wait += wait
        self.stats.max_wait = max(self.stats.max_wait, wait)
        return item

    def __len__(self) -> int:
        return len(self._q)

    def retain(self, pred: Callable[[Any], bool]) -> List[Any]:
        """Keep only items matching ``pred`` (in order); returns the
        removed items.  Wait stats are untouched — the DES uses this at
        an epoch cutoff, where the removed tasks carry over rather than
        retire."""
        kept, removed = [], []
        for t, item in self._q:
            (kept if pred(item) else removed).append((t, item))
        self._q = collections.deque(kept)
        return [item for _, item in removed]

    def waits(self) -> QueueStats:
        return self.stats


@dataclasses.dataclass
class MicroBatchStats:
    n_items: int = 0
    n_flushes: int = 0
    max_batch_seen: int = 0
    total_hold: float = 0.0       # sum of per-item time spent coalescing

    @property
    def mean_batch(self) -> float:
        return self.n_items / self.n_flushes if self.n_flushes else 0.0

    @property
    def mean_hold(self) -> float:
        return self.total_hold / self.n_items if self.n_items else 0.0


class MicroBatcher:
    """Coalesces ready per-patient windows into one fused ensemble flush.

    The two knobs trade tail latency for dispatch amortisation:

    * ``max_batch``   — flush as soon as this many items are pending
                        (bounds per-flush device work and memory);
    * ``max_wait_ms`` — flush once the OLDEST pending item has waited
                        this long (bounds the latency a lone patient's
                        query pays for batching).

    Thread-safe: server workers push/pop concurrently.  ``pop_batch``
    returns up to ``max_batch`` items (empty list when nothing pending);
    ``ready`` says whether a flush is due.  ``clock`` is injectable so
    the DES/unit tests can drive virtual time.
    """

    def __init__(self, max_batch: int = 8, max_wait_ms: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self.clock = clock
        self.stats = MicroBatchStats()
        self._lock = threading.Lock()
        self._q: Deque[Tuple[float, Any]] = collections.deque()

    def push(self, item: Any, t: Optional[float] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            self._q.append((t, item))

    def __len__(self) -> int:
        return len(self._q)

    def ready(self, now: Optional[float] = None) -> bool:
        now = self.clock() if now is None else now
        with self._lock:
            if not self._q:
                return False
            return (len(self._q) >= self.max_batch
                    or now - self._q[0][0] >= self.max_wait)

    def pop_batch(self, now: Optional[float] = None) -> List[Any]:
        """Pops up to ``max_batch`` items (FIFO) and records stats."""
        now = self.clock() if now is None else now
        with self._lock:
            n = min(len(self._q), self.max_batch)
            if not n:
                return []
            taken = [self._q.popleft() for _ in range(n)]
            self.stats.n_items += n
            self.stats.n_flushes += 1
            self.stats.max_batch_seen = max(self.stats.max_batch_seen, n)
            self.stats.total_hold += sum(max(0.0, now - t)
                                         for t, _ in taken)
            return [item for _, item in taken]

    def oldest(self) -> Optional[float]:
        """Timestamp of the oldest pending item (None when empty)."""
        with self._lock:
            return self._q[0][0] if self._q else None

    def stats_snapshot(self) -> MicroBatchStats:
        """Consistent copy of the flush stats, taken under the batcher
        lock.  ``pop_batch`` mutates several stats fields in sequence;
        reading the live ``self.stats`` object field-by-field from
        another thread can interleave with that sequence and return a
        torn aggregate (``n_items`` from after a flush, ``n_flushes``
        from before it).  Readers that combine fields — the keyed
        aggregate below, the Prometheus exporter — must go through this
        snapshot."""
        with self._lock:
            return dataclasses.replace(self.stats)


# KeyedMicroBatcher.ready()'s "no lane is due" result: a sentinel, NOT
# None — None is a legitimate lane key (the server's fallback when a
# tier_of callback fails) and must remain poppable
NO_LANE = object()


class KeyedMicroBatcher:
    """Per-key ``MicroBatcher`` lanes (one per acuity tier): coalescing
    NEVER crosses keys, so every flush is served whole by one tier's
    service.  Lanes are created on demand and share the clock and
    flush knobs; ``ready()`` returns the due key whose oldest pending
    item has waited longest (deterministic fairness: the tier closest
    to its wait bound flushes first), or ``NO_LANE``.
    """

    def __init__(self, max_batch: int = 8, max_wait_ms: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self.clock = clock
        self._lock = threading.Lock()
        self._lanes: "collections.OrderedDict[Any, MicroBatcher]" = \
            collections.OrderedDict()

    def lane(self, key: Any) -> MicroBatcher:
        with self._lock:
            lane = self._lanes.get(key)
            if lane is None:
                lane = MicroBatcher(max_batch=self.max_batch,
                                    max_wait_ms=self.max_wait * 1000.0,
                                    clock=self.clock)
                self._lanes[key] = lane
            return lane

    def push(self, key: Any, item: Any,
             t: Optional[float] = None) -> None:
        self.lane(key).push(item, t)

    def __len__(self) -> int:
        with self._lock:
            lanes = list(self._lanes.values())
        return sum(len(l) for l in lanes)

    def ready(self, now: Optional[float] = None) -> Optional[Any]:
        now = self.clock() if now is None else now
        with self._lock:
            lanes = list(self._lanes.items())
        due = []
        for k, l in lanes:
            oldest = l.oldest()       # read before ready(): a racing
            if oldest is None:        # pop may empty the lane between
                continue              # the two checks
            if l.ready(now):
                due.append((k, oldest))
        if not due:
            return NO_LANE
        return min(due, key=lambda kv: (kv[1], str(kv[0])))[0]

    def pop_batch(self, key: Any,
                  now: Optional[float] = None) -> List[Any]:
        return self.lane(key).pop_batch(now)

    @property
    def stats(self) -> MicroBatchStats:
        """Aggregate over lanes (the server's reporting surface).  Each
        lane contributes an atomic ``stats_snapshot()`` — summing the
        live per-lane objects field-by-field raced concurrent
        ``pop_batch`` updates and could publish a torn aggregate (e.g.
        ``n_flushes`` from after a flush whose ``n_items`` was read
        before it)."""
        with self._lock:
            lanes = list(self._lanes.values())
        agg = MicroBatchStats()
        for l in lanes:
            s = l.stats_snapshot()
            agg.n_items += s.n_items
            agg.n_flushes += s.n_flushes
            agg.max_batch_seen = max(agg.max_batch_seen,
                                     s.max_batch_seen)
            agg.total_hold += s.total_hold
        return agg

    def lane_stats(self) -> "Dict[Any, MicroBatchStats]":
        """Per-lane stats SNAPSHOTS (each internally consistent), not
        the live mutable objects."""
        with self._lock:
            lanes = list(self._lanes.items())
        return {k: l.stats_snapshot() for k, l in lanes}


class ShedQueue:
    """Bounded ingest queue whose bound covers UNFINISHED work, not just
    queued items.

    ``queue.Queue(maxsize=N)`` only bounds what sits in the queue proper;
    the server's workers immediately drain it into micro-batcher lanes,
    so under sustained backpressure the lanes grow without limit while
    the queue reads empty.  ``ShedQueue`` bounds ``unfinished_tasks``
    (queued + coalescing + in-flight) instead: admission is refused the
    moment total outstanding work hits ``maxsize``, which is the number
    that actually limits memory and staleness.

    API-compatible with the ``queue.Queue`` subset ``EnsembleServer``
    uses (``put_nowait``/``queue.Full``, ``get(timeout)``/
    ``queue.Empty``, ``task_done``, ``all_tasks_done``,
    ``unfinished_tasks``, ``empty``, ``qsize``), plus priority-aware
    admission: ``put_evicting(item, priority, tag)`` evicts the
    lowest-priority (then oldest) QUEUED item whose priority is strictly
    below the newcomer's — so under overrun the stable tier sheds first
    and a critical query is never bumped by a lesser one.  Eviction only
    reaches items still in the queue; work already coalescing or
    in-flight is past the admission boundary.
    """

    def __init__(self, maxsize: int = 0):
        self.maxsize = maxsize
        self._lock = threading.Lock()
        self.not_empty = threading.Condition(self._lock)
        self.all_tasks_done = threading.Condition(self._lock)
        self._q: Deque[Tuple[float, Any, Any]] = collections.deque()
        self.unfinished_tasks = 0
        # admission counters (export surface; guarded by _lock)
        self.n_admitted = 0
        self.n_evicted = 0
        self.n_rejected = 0

    def qsize(self) -> int:
        with self._lock:
            return len(self._q)

    def empty(self) -> bool:
        return self.qsize() == 0

    def put_nowait(self, item: Any, priority: float = 0.0,
                   tag: Any = None) -> None:
        with self.not_empty:
            if self.maxsize > 0 and self.unfinished_tasks >= self.maxsize:
                self.n_rejected += 1
                raise _queue.Full
            self._q.append((priority, tag, item))
            self.unfinished_tasks += 1
            self.n_admitted += 1
            self.not_empty.notify()

    def put_evicting(self, item: Any, priority: float = 0.0,
                     tag: Any = None) -> Tuple[bool, Optional[Tuple[Any, Any]]]:
        """Admit ``item``, evicting a strictly lower-priority queued item
        if full.  Returns ``(admitted, victim)`` where victim is the
        ``(evicted_item, evicted_tag)`` pair or None.  The victim's
        unfinished slot transfers to the newcomer, so conservation
        accounting (one ``task_done`` per admitted-and-served item)
        stays exact."""
        with self.not_empty:
            if self.maxsize <= 0 or self.unfinished_tasks < self.maxsize:
                self._q.append((priority, tag, item))
                self.unfinished_tasks += 1
                self.n_admitted += 1
                self.not_empty.notify()
                return True, None
            best = None                 # (index, priority): lowest, oldest
            for i, (pr, _tg, _it) in enumerate(self._q):
                if pr < priority and (best is None or pr < best[1]):
                    best = (i, pr)
            if best is None:
                self.n_rejected += 1
                return False, None
            _pr, vtag, victim = self._q[best[0]]
            del self._q[best[0]]
            self._q.append((priority, tag, item))
            self.n_admitted += 1
            self.n_evicted += 1
            # queue length and unfinished count are unchanged: the
            # victim never gets a task_done — its slot is the newcomer's
            self.not_empty.notify()
            return True, (victim, vtag)

    def get(self, timeout: Optional[float] = None) -> Any:
        with self.not_empty:
            if timeout is None:
                while not self._q:
                    self.not_empty.wait()
            else:
                deadline = time.monotonic() + timeout
                while not self._q:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise _queue.Empty
                    self.not_empty.wait(remaining)
            _pr, _tg, item = self._q.popleft()
            return item

    def task_done(self) -> None:
        with self.all_tasks_done:
            unfinished = self.unfinished_tasks - 1
            if unfinished < 0:
                raise ValueError("task_done() called too many times")
            self.unfinished_tasks = unfinished
            if unfinished == 0:
                self.all_tasks_done.notify_all()
