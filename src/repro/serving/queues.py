"""Timestamped FIFO queues (the per-modality ensemble queues of Fig. 4)
with waiting-time statistics for the latency profiler.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, List, Optional, Tuple


@dataclasses.dataclass
class QueueStats:
    n_pushed: int = 0
    n_popped: int = 0
    total_wait: float = 0.0
    max_wait: float = 0.0
    max_depth: int = 0

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.n_popped if self.n_popped else 0.0


class TimestampedQueue:
    def __init__(self, name: str = "q"):
        self.name = name
        self._q: Deque[Tuple[float, Any]] = collections.deque()
        self.stats = QueueStats()

    def push(self, t: float, item: Any) -> None:
        self._q.append((t, item))
        self.stats.n_pushed += 1
        self.stats.max_depth = max(self.stats.max_depth, len(self._q))

    def pop(self, now: float) -> Optional[Any]:
        if not self._q:
            return None
        t_in, item = self._q.popleft()
        wait = max(0.0, now - t_in)
        self.stats.n_popped += 1
        self.stats.total_wait += wait
        self.stats.max_wait = max(self.stats.max_wait, wait)
        return item

    def __len__(self) -> int:
        return len(self._q)

    def waits(self) -> QueueStats:
        return self.stats
