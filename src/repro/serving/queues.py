"""Timestamped FIFO queues (the per-modality ensemble queues of Fig. 4)
with waiting-time statistics for the latency profiler, plus the
cross-patient ``MicroBatcher`` that coalesces ready windows into fused
ensemble flushes (serving.pipeline.EnsembleService.predict_batch).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Deque, List, Optional, Tuple


@dataclasses.dataclass
class QueueStats:
    n_pushed: int = 0
    n_popped: int = 0
    total_wait: float = 0.0
    max_wait: float = 0.0
    max_depth: int = 0

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.n_popped if self.n_popped else 0.0


class TimestampedQueue:
    def __init__(self, name: str = "q"):
        self.name = name
        self._q: Deque[Tuple[float, Any]] = collections.deque()
        self.stats = QueueStats()

    def push(self, t: float, item: Any) -> None:
        self._q.append((t, item))
        self.stats.n_pushed += 1
        self.stats.max_depth = max(self.stats.max_depth, len(self._q))

    def pop(self, now: float) -> Optional[Any]:
        if not self._q:
            return None
        t_in, item = self._q.popleft()
        wait = max(0.0, now - t_in)
        self.stats.n_popped += 1
        self.stats.total_wait += wait
        self.stats.max_wait = max(self.stats.max_wait, wait)
        return item

    def __len__(self) -> int:
        return len(self._q)

    def retain(self, pred: Callable[[Any], bool]) -> List[Any]:
        """Keep only items matching ``pred`` (in order); returns the
        removed items.  Wait stats are untouched — the DES uses this at
        an epoch cutoff, where the removed tasks carry over rather than
        retire."""
        kept, removed = [], []
        for t, item in self._q:
            (kept if pred(item) else removed).append((t, item))
        self._q = collections.deque(kept)
        return [item for _, item in removed]

    def waits(self) -> QueueStats:
        return self.stats


@dataclasses.dataclass
class MicroBatchStats:
    n_items: int = 0
    n_flushes: int = 0
    max_batch_seen: int = 0
    total_hold: float = 0.0       # sum of per-item time spent coalescing

    @property
    def mean_batch(self) -> float:
        return self.n_items / self.n_flushes if self.n_flushes else 0.0

    @property
    def mean_hold(self) -> float:
        return self.total_hold / self.n_items if self.n_items else 0.0


class MicroBatcher:
    """Coalesces ready per-patient windows into one fused ensemble flush.

    The two knobs trade tail latency for dispatch amortisation:

    * ``max_batch``   — flush as soon as this many items are pending
                        (bounds per-flush device work and memory);
    * ``max_wait_ms`` — flush once the OLDEST pending item has waited
                        this long (bounds the latency a lone patient's
                        query pays for batching).

    Thread-safe: server workers push/pop concurrently.  ``pop_batch``
    returns up to ``max_batch`` items (empty list when nothing pending);
    ``ready`` says whether a flush is due.  ``clock`` is injectable so
    the DES/unit tests can drive virtual time.
    """

    def __init__(self, max_batch: int = 8, max_wait_ms: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self.clock = clock
        self.stats = MicroBatchStats()
        self._lock = threading.Lock()
        self._q: Deque[Tuple[float, Any]] = collections.deque()

    def push(self, item: Any, t: Optional[float] = None) -> None:
        t = self.clock() if t is None else t
        with self._lock:
            self._q.append((t, item))

    def __len__(self) -> int:
        return len(self._q)

    def ready(self, now: Optional[float] = None) -> bool:
        now = self.clock() if now is None else now
        with self._lock:
            if not self._q:
                return False
            return (len(self._q) >= self.max_batch
                    or now - self._q[0][0] >= self.max_wait)

    def pop_batch(self, now: Optional[float] = None) -> List[Any]:
        """Pops up to ``max_batch`` items (FIFO) and records stats."""
        now = self.clock() if now is None else now
        with self._lock:
            n = min(len(self._q), self.max_batch)
            if not n:
                return []
            taken = [self._q.popleft() for _ in range(n)]
            self.stats.n_items += n
            self.stats.n_flushes += 1
            self.stats.max_batch_seen = max(self.stats.max_batch_seen, n)
            self.stats.total_hold += sum(max(0.0, now - t)
                                         for t, _ in taken)
            return [item for _, item in taken]
