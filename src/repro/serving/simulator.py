"""Discrete-event simulation of the HOLMES serving pipeline (§4.1.2).

Replaces the paper's client-node/HTTP/RPC testbed with a deterministic,
seedable event simulation of the SAME pipeline: per-patient multi-modal
streams -> stateful aggregators -> observation-window queries -> model
queue -> device pool running the ensemble -> bagging combine.

Used for (a) Fig. 9 online-vs-offline, (b) Fig. 10 scalability sweeps,
(c) the measured-mode latency profiler, and (d) validating the network-
calculus T_q bound against empirical queueing delays.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.queues import TimestampedQueue

SAMPLE, WINDOW, DEVICE_FREE, FLUSH, CENSUS = range(5)


@dataclasses.dataclass
class SimConfig:
    n_patients: int = 64
    n_devices: int = 2
    window_seconds: float = 30.0
    duration_seconds: float = 120.0
    ingest_hz: float = 250.0          # per-patient waveform rate
    chunk_seconds: float = 0.2        # HTTP flush granularity
    batch_period: float = 0.0         # >0 => offline batch mode (Fig. 9)
    dispatch_overhead: float = 0.0005
    seed: int = 0
    # churn mode: piecewise-constant TARGET census [(t, n_active), ...].
    # Overrides n_patients; admissions/discharges happen at each step
    # (deterministic under seed: phases drawn in event order, discharges
    # LIFO).  None => the original static-cohort behaviour, untouched.
    census: Optional[Sequence[Tuple[float, int]]] = None
    # scales admission phase jitter: 1.0 = phases uniform over a window
    # (desynchronized beds), 0.0 = a step admission fires all its new
    # patients' windows at the same instant (thundering-herd burst)
    churn_phase_jitter: float = 1.0
    # epoch mode: cut the run at duration_seconds instead of draining —
    # queries that never started by the cutoff are returned as
    # ``SimResult.backlog`` (ages) for the NEXT epoch's ``simulate``
    # call to ingest at t=0, so sustained overload accumulates across
    # epoch boundaries instead of resetting.  False keeps the original
    # drain-to-empty behaviour, untouched.
    carry_backlog: bool = False
    # acuity tiers: admission-fraction per tier, keys ordered lowest ->
    # highest acuity (e.g. {"stable": .6, "elevated": .25,
    # "critical": .15}).  When set, ``model_costs`` must be a mapping
    # tier -> per-member cost list: each query is stamped with its
    # patient's CURRENT tier at window close and served with THAT
    # tier's ensemble (the DES twin of per-tier selector routing).
    # None => the original untiered behaviour, bit-identical.
    tiers: Optional[Dict[str, float]] = None
    # per-window hazard that a sub-top-tier patient escalates ONE tier
    # at a window close (mid-stay acuity escalation, e.g. a stable bed
    # deteriorating); drawn in event order, deterministic under seed
    escalate_hazard: float = 0.0


@dataclasses.dataclass
class QueryRecord:
    patient: int
    t_window: float                   # when the window closed (query born)
    t_start: float = 0.0              # first model began executing
    t_done: float = 0.0              # last model finished
    n_models: int = 0
    tier: str = ""                    # acuity tier at birth (tiered mode)

    @property
    def latency(self) -> float:
        return self.t_done - self.t_window

    @property
    def queue_delay(self) -> float:
        return self.t_start - self.t_window


@dataclasses.dataclass
class SimResult:
    queries: List[QueryRecord]
    arrivals: np.ndarray              # query birth times
    ingest_events: int
    device_busy: float
    duration: float
    queue_stats: Dict[str, object]
    # churn mode only: patient -> (t_admit, t_discharge, phase); the
    # discharge time is +inf for patients active at the end of the run
    patients: Dict[int, Tuple[float, float, float]] = \
        dataclasses.field(default_factory=dict)
    churn_log: List[Tuple[float, str, int]] = \
        dataclasses.field(default_factory=list)
    # carry_backlog mode: ages (seconds since birth, measured at the
    # cutoff) of queries that never started service this epoch — feed
    # them to the next epoch's ``simulate(..., backlog=)``
    backlog: np.ndarray = dataclasses.field(
        default_factory=lambda: np.asarray([]))
    # tiered mode: the carried queries' tiers, aligned with ``backlog``
    # (a carried query keeps the tier it was born with), and the acuity
    # trail — (t, patient, old_tier, new_tier), old == "" at admission
    backlog_tiers: List[str] = dataclasses.field(default_factory=list)
    tier_log: List[Tuple[float, int, str, str]] = \
        dataclasses.field(default_factory=list)

    def latencies(self) -> np.ndarray:
        return np.asarray([q.latency for q in self.queries])

    def queue_delays(self) -> np.ndarray:
        return np.asarray([q.queue_delay for q in self.queries])

    def p(self, pct: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, pct)) if len(lat) else 0.0

    @property
    def utilization(self) -> float:
        return self.device_busy / max(self.duration, 1e-9)


def simulate(model_costs, cfg: SimConfig,
             backlog: Sequence[float] = (),
             backlog_tiers: Sequence[str] = ()) -> SimResult:
    """model_costs: seconds/query for each SELECTED ensemble member —
    or, with ``cfg.tiers``, a mapping tier -> cost list (each query is
    served with its birth-tier's ensemble).
    ``backlog``: ages of queries carried in from a previous epoch
    (``SimResult.backlog``); they enter the model queue at t=0 with
    negative birth times, so their end-to-end latency keeps
    accumulating across the epoch edge and is never double-counted —
    the carrying epoch returns them unserved, the serving epoch
    retires them exactly once.  ``backlog_tiers`` aligns tiers with
    those ages in tiered mode."""
    if cfg.carry_backlog and cfg.batch_period > 0:
        # batch mode schedules its final FLUSH past duration_seconds,
        # so held queries would be served beyond the epoch edge instead
        # of carried — the combination has no coherent epoch semantics
        raise ValueError("carry_backlog is incompatible with "
                         "batch_period > 0")
    rng = np.random.default_rng(cfg.seed)
    tiered = cfg.tiers is not None
    if tiered:
        tier_names = list(cfg.tiers)
        fracs = np.asarray([cfg.tiers[t] for t in tier_names],
                           np.float64)
        if fracs.sum() <= 0:
            raise ValueError("tier fractions must sum to > 0")
        fracs = fracs / fracs.sum()
        costs_by_tier = {t: list(model_costs[t]) for t in tier_names}
        if len(backlog) and len(backlog_tiers) != len(backlog):
            raise ValueError("tiered backlog needs one tier per age")
        costs = None
    else:
        if cfg.escalate_hazard:
            raise ValueError("escalate_hazard requires cfg.tiers")
        costs = list(model_costs)
    tier_now: Dict[int, str] = {}
    tier_log: List[Tuple[float, int, str, str]] = []

    def assign_tier(now: float, p: int) -> None:
        t = tier_names[int(rng.choice(len(tier_names), p=fracs))]
        tier_now[p] = t
        tier_log.append((now, p, "", t))

    def maybe_escalate(now: float, p: int) -> None:
        """Mid-stay acuity escalation, drawn at window close BEFORE the
        query is stamped (the deteriorating bed's next prediction is
        already served at the higher tier)."""
        if not cfg.escalate_hazard:
            return
        cur = tier_now[p]
        i = tier_names.index(cur)
        if i + 1 >= len(tier_names):
            return
        if rng.uniform() < cfg.escalate_hazard:
            tier_now[p] = tier_names[i + 1]
            tier_log.append((now, p, cur, tier_names[i + 1]))

    events: List[Tuple[float, int, int, tuple]] = []
    counter = itertools.count()

    def push(t: float, kind: int, payload: tuple = ()):
        heapq.heappush(events, (t, next(counter), kind, payload))

    # -------------------------------------------------- patient cohort
    churn = cfg.census is not None
    active: set = set()
    admit_t: Dict[int, float] = {}
    discharge_t: Dict[int, float] = {}
    phase_of: Dict[int, float] = {}
    churn_log: List[Tuple[float, str, int]] = []
    pid_counter = itertools.count()

    def admit(now: float, k: int):
        for _ in range(k):
            p = next(pid_counter)
            ph = float(rng.uniform(0, cfg.window_seconds)) \
                * cfg.churn_phase_jitter
            phase_of[p], admit_t[p] = ph, now
            active.add(p)
            churn_log.append((now, "admit", p))
            if tiered:
                assign_tier(now, p)
            t1 = now + ph + cfg.window_seconds
            if t1 <= cfg.duration_seconds:
                push(t1, WINDOW, (p,))

    def discharge(now: float, k: int):
        # LIFO (most recent admissions leave first): deterministic
        for p in sorted(active, reverse=True)[:k]:
            active.discard(p)
            discharge_t[p] = now
            churn_log.append((now, "discharge", p))

    if churn:
        # census steps drive admissions/discharges; windows are
        # scheduled incrementally per active patient
        for t_c, n_target in cfg.census:
            push(t_c, CENSUS, (int(n_target),))
    else:
        # static cohort: schedule all window closures up front
        phases = rng.uniform(0, cfg.window_seconds, cfg.n_patients)
        if tiered:                     # draws AFTER phases: the untiered
            for p in range(cfg.n_patients):   # stream stays bit-identical
                assign_tier(0.0, p)
        for p in range(cfg.n_patients):
            t = phases[p] + cfg.window_seconds
            while t <= cfg.duration_seconds:
                push(t, WINDOW, (p,))
                t += cfg.window_seconds
    # batch mode: queries are held and flushed every batch_period
    if cfg.batch_period > 0:
        t = cfg.batch_period
        while t <= cfg.duration_seconds + cfg.batch_period:
            push(t, FLUSH, ())
            t += cfg.batch_period

    ingest_events = int(cfg.duration_seconds / cfg.chunk_seconds
                        * cfg.n_patients)

    model_q = TimestampedQueue("models")
    held: List[QueryRecord] = []
    queries: List[QueryRecord] = []
    free_devices = cfg.n_devices
    device_busy = 0.0

    def enqueue_query(rec: QueryRecord, now: float):
        # tiered: the query is served by its BIRTH tier's ensemble — the
        # conservation invariant "never answered by the wrong tier's
        # selector" is structural here
        c_list = costs_by_tier[rec.tier] if tiered else costs
        rec.n_models = len(c_list)
        rec._remaining = len(c_list)          # type: ignore[attr-defined]
        rec.t_start = -1.0
        queries.append(rec)
        for c in c_list:
            model_q.push(now, (rec, c))

    def try_dispatch(now: float):
        nonlocal free_devices, device_busy
        while free_devices > 0 and len(model_q):
            task = model_q.pop(now)
            rec, c = task
            if rec.t_start < 0:
                rec.t_start = now
            free_devices -= 1
            device_busy += c
            push(now + c + cfg.dispatch_overhead, DEVICE_FREE, (rec,))

    # backlog carried in from the previous epoch: already-born queries
    # join the model queue at t=0, ahead of this epoch's first window
    # (a carried query keeps its birth tier)
    for k, age in enumerate(backlog):
        enqueue_query(QueryRecord(
            patient=-(k + 1), t_window=-float(age),
            tier=backlog_tiers[k] if tiered else ""), 0.0)
    if len(backlog):
        try_dispatch(0.0)

    closed = False                     # carry_backlog epoch cutoff hit

    while events:
        now, _, kind, payload = heapq.heappop(events)
        if cfg.carry_backlog and not closed \
                and now > cfg.duration_seconds:
            # epoch edge: queries already in service run to completion
            # (their queued tasks stay), never-started queries carry
            # over whole — no partial work is redone or double-served
            closed = True
            model_q.retain(lambda task: task[0].t_start >= 0)
        if kind == CENSUS:
            target = payload[0]
            if target > len(active):
                admit(now, target - len(active))
            elif target < len(active):
                discharge(now, len(active) - target)
        elif kind == WINDOW:
            p = payload[0]
            if churn:
                if p not in active:
                    continue              # discharged: window dropped
                if now + cfg.window_seconds <= cfg.duration_seconds:
                    push(now + cfg.window_seconds, WINDOW, (p,))
            if tiered:
                maybe_escalate(now, p)    # before stamping the query
            rec = QueryRecord(patient=p, t_window=now,
                              tier=tier_now.get(p, "") if tiered else "")
            if cfg.batch_period > 0:
                held.append(rec)
            else:
                enqueue_query(rec, now)
                try_dispatch(now)
        elif kind == FLUSH:
            for rec in held:
                enqueue_query(rec, now)
            held.clear()
            try_dispatch(now)
        elif kind == DEVICE_FREE:
            rec = payload[0]
            rec._remaining -= 1               # type: ignore[attr-defined]
            if rec._remaining == 0:
                rec.t_done = now
            free_devices += 1
            try_dispatch(now)

    if churn:
        ingest_events = int(sum(
            (min(discharge_t.get(p, cfg.duration_seconds),
                 cfg.duration_seconds) - t_a) / cfg.chunk_seconds
            for p, t_a in admit_t.items()))
    done = [q for q in queries if q.t_done > 0]
    # oldest first, so the next epoch's FIFO serves in birth order
    carried = sorted(((cfg.duration_seconds - q.t_window, q.tier)
                      for q in queries if q.t_done <= 0),
                     key=lambda at: -at[0]) \
        if cfg.carry_backlog else []
    return SimResult(
        queries=done,
        arrivals=np.asarray(sorted(q.t_window for q in queries)),
        ingest_events=ingest_events,
        device_busy=device_busy,
        duration=cfg.duration_seconds,
        queue_stats={"models": model_q.waits()},
        patients={p: (t_a, discharge_t.get(p, float("inf")), phase_of[p])
                  for p, t_a in admit_t.items()},
        churn_log=churn_log,
        backlog=np.asarray([a for a, _ in carried]),
        backlog_tiers=[t for _, t in carried],
        tier_log=tier_log)
