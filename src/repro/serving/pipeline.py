"""The served ensemble pipeline (Fig. 4): HTTP-ingest stand-in ->
stateful aggregators -> ensemble query -> bagging combine.

``EnsembleService`` does real jitted inference with the selected ECG zoo
members plus the CPU-side vitals/labs models; ``StreamingPipeline`` drives
it from per-patient multi-modal streams and records end-to-end wall-clock
latencies (the measured counterpart of the DES simulator).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ecg_zoo import (CLIP_SECONDS, ECG_HZ, EcgModelSpec,
                                   VITALS_HZ)
from repro.models.ecg_resnext import ecg_apply
from repro.serving.aggregator import ModalitySpec, PatientAggregator
from repro.serving.placement import lpt_placement


@dataclasses.dataclass
class ZooMember:
    spec: EcgModelSpec
    params: Dict


class EnsembleService:
    """Stateless ensemble actors: jitted per-member predict functions."""

    def __init__(self, members: Sequence[ZooMember],
                 vitals_model=None, labs_model=None,
                 n_devices: int = 1):
        self.members = list(members)
        self.vitals_model = vitals_model
        self.labs_model = labs_model
        self._fns: List[Callable] = []
        for m in self.members:
            fn = jax.jit(lambda x, p=m.params, s=m.spec: jax.nn.softmax(
                ecg_apply(p, x, s), axis=-1)[:, 1])
            self._fns.append(fn)
        self.n_devices = n_devices

    def warmup(self) -> None:
        for m, fn in zip(self.members, self._fns):
            fn(jnp.zeros((1, m.spec.input_len, 1)))

    def measured_costs(self, reps: int = 3) -> List[float]:
        """Closed-loop per-member seconds/query (the mu measurement)."""
        self.warmup()
        out = []
        for m, fn in zip(self.members, self._fns):
            x = jnp.zeros((1, m.spec.input_len, 1))
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(x).block_until_ready()
            out.append((time.perf_counter() - t0) / reps)
        return out

    def predict(self, windows: Dict[str, np.ndarray]) -> float:
        """windows: {"ecg": [3, L], "vitals": [7, W], "labs": [8]}.
        Returns the bagged P(stable) (Eq. 5)."""
        scores = []
        ecg = windows.get("ecg")
        for m, fn in zip(self.members, self._fns):
            clip = ecg[m.spec.lead, -m.spec.input_len:]
            scores.append(float(fn(jnp.asarray(clip)[None, :, None])[0]))
        if self.vitals_model is not None and "vitals" in windows:
            scores.append(float(self.vitals_model.predict_proba(
                windows["vitals"][None])[0]))
        if self.labs_model is not None and "labs" in windows:
            scores.append(float(self.labs_model.predict_proba(
                windows["labs"][None])[0]))
        return float(np.mean(scores)) if scores else 0.5


@dataclasses.dataclass
class ServedQuery:
    patient: int
    t_window: float
    t_done: float
    score: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_window


class StreamingPipeline:
    """Stateful aggregators + the ensemble service, driven by a stream."""

    def __init__(self, service: EnsembleService, n_patients: int,
                 window_seconds: float = float(CLIP_SECONDS)):
        mods = [ModalitySpec("ecg", ECG_HZ, 3),
                ModalitySpec("vitals", VITALS_HZ, 7)]
        self.service = service
        self.aggs = [PatientAggregator(mods, window_seconds)
                     for _ in range(n_patients)]
        self.labs_cache: Dict[int, np.ndarray] = {}
        self.records: List[ServedQuery] = []

    def feed(self, t: float, patient: int, modality: str,
             samples: np.ndarray) -> Optional[ServedQuery]:
        if modality == "labs":
            self.labs_cache[patient] = np.asarray(samples)
            return None
        agg = self.aggs[patient]
        agg.ingest(t, modality, samples)
        if not agg.window_ready(t):
            return None
        windows = agg.pop_window(t)
        if patient in self.labs_cache:
            windows["labs"] = self.labs_cache[patient]
        t0 = time.perf_counter()
        score = self.service.predict(windows)
        wall = time.perf_counter() - t0
        rec = ServedQuery(patient=patient, t_window=t, t_done=t + wall,
                          score=score)
        self.records.append(rec)
        return rec

    def latencies(self) -> np.ndarray:
        return np.asarray([r.latency for r in self.records])
