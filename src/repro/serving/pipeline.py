"""The served ensemble pipeline (Fig. 4): HTTP-ingest stand-in ->
stateful aggregators -> ensemble query -> bagging combine.

``EnsembleService`` does real jitted inference with the selected ECG zoo
members plus the CPU-side vitals/labs models; ``StreamingPipeline`` drives
it from per-patient multi-modal streams and records end-to-end wall-clock
latencies (the measured counterpart of the DES simulator).

Fused serving (the hot path)
----------------------------
By default the service executes the zoo in **architecture buckets**
(``configs.ecg_zoo.bucket_zoo``): members with identical shapes — leads
differ only in which input slice they consume — are stacked along a
leading member axis (``launch.ensemble_parallel.stack_members``) and run
as ONE ``ecg_apply_stacked`` dispatch per bucket, so a query costs
``n_buckets`` jitted calls (4 on the reduced 12-member zoo, 20 on the
full 60) instead of ``n_members``.  ``predict_batch`` additionally
micro-batches windows from MANY patients into the same stacked call —
one host->device transfer in and one blocking device sync out per flush.
The per-member loop is kept (``fused=False``) as the equivalence oracle
and for per-member cost measurement (``measured_costs``).

Multi-device sharded serving (``placement=``)
---------------------------------------------
A ``serving.placement.Placement`` shards the stacked bucket params
across ``jax.devices()``: each placement slot's members are bucketed
independently and every (bucket, device) shard gets its own
``device_put``-pinned stacked pytree, so a flush issues one stacked
dispatch per shard — all async, on their own devices — and the scores
are combined by a single host-side gather at the end (the cross-device
gather/sum of Eq. 5).  Placement is controller-actuated state:
``control.swap.HotSwapper`` stages ``(selector, placement)`` pairs and
the adaptive controller re-derives the LPT plan from freshly measured
bucket costs (``measured_bucket_costs`` -> ``plan_placement``).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ecg_zoo import (CLIP_SECONDS, ECG_HZ, EcgModelSpec,
                                   VITALS_HZ, bucket_zoo)
from repro.launch.ensemble_parallel import stack_members
from repro.models.ecg_resnext import ecg_apply, ecg_apply_stacked
from repro.serving.aggregator import ModalitySpec, PatientAggregator
from repro.serving.placement import (Placement, grouped_lpt_placement,
                                     lpt_placement)


@dataclasses.dataclass
class ZooMember:
    spec: EcgModelSpec
    params: Dict


@dataclasses.dataclass
class _Bucket:
    """One stacked-execution group: structurally identical members.
    With a placement this is a (bucket, device) SHARD — the same bucket
    may appear once per device its members were assigned to."""
    spec: EcgModelSpec            # shape-defining representative
    idx: List[int]                # member indices into self.members
    leads: List[int]              # per stacked member, the lead it reads
    stacked: Dict                 # stack_members() pytree, leading axis M
    fn: Callable                  # jitted [M, P, L, 1] -> scores [M, P]
    device: object = None         # jax.Device the shard is pinned to


def _make_member_fn(params: Dict, spec: EcgModelSpec,
                    impl: str) -> Callable:
    return jax.jit(lambda x: jax.nn.softmax(
        ecg_apply(params, x, spec, impl=impl), axis=-1)[:, 1])


@functools.lru_cache(maxsize=None)
def _make_bucket_fn_cached(spec: EcgModelSpec, impl: str) -> Callable:
    @jax.jit
    def fn(stacked: Dict, xs: jax.Array) -> jax.Array:
        logits = ecg_apply_stacked(stacked, xs, spec, impl=impl)
        return jax.nn.softmax(logits, axis=-1)[..., 1]     # [M, P]
    return fn


def _make_bucket_fn(spec: EcgModelSpec, impl: str) -> Callable:
    """Shared per (architecture, impl): every service (and every staged
    (selector, placement) pair) reuses ONE jit object per bucket shape,
    so re-staging across swaps/placements hits the compile cache
    instead of recompiling identical programs.  ``name``/``lead`` are
    blanked from the cache key — lead selection happens on the host
    when the input is built, so two buckets whose representative
    members differ only by lead share the same XLA program."""
    return _make_bucket_fn_cached(
        dataclasses.replace(spec, name="", lead=0), impl)


class EnsembleService:
    """Stateless ensemble actors with a bucketed fused dispatch plan.

    ``fused=True`` (default): one stacked jitted call per architecture
    bucket per flush, micro-batched across patients.  ``fused=False``:
    the original one-call-per-member-per-patient loop (kept as the
    numerical oracle).  ``dispatch_count`` tallies jitted zoo dispatches
    issued by ``predict``/``predict_batch`` — the quantity the serving
    benchmark tracks per query.

    ``placement`` (a ``serving.placement.Placement`` whose assignment
    covers every member exactly once) shards the fused plan across
    ``devices`` (default ``jax.devices()``): slot d's members are
    bucketed on their own and pinned to device d, one stacked dispatch
    per (bucket, device) shard.  BUCKET-ALIGNED plans (each bucket
    whole on one device — what ``plan_placement`` emits) are bitwise
    identical to the unsharded path: the stacked grouping never
    changes, only where it runs.  Arbitrary member-level assignments
    are also valid but alter the stacked member-axis size, so they
    match to float tolerance only.
    """

    def __init__(self, members: Sequence[ZooMember],
                 vitals_model=None, labs_model=None,
                 n_devices: int = 1, fused: bool = True,
                 impl: str = "xla",
                 placement: Optional[Placement] = None,
                 devices: Optional[Sequence] = None):
        self.members = list(members)
        self.vitals_model = vitals_model
        self.labs_model = labs_model
        self.fused = fused
        self.impl = impl
        self.n_devices = n_devices
        self.placement = placement
        self._devices = list(devices) if devices is not None else None
        if placement is not None:
            if not fused:
                raise ValueError("placement requires the fused path")
            placed = sorted(i for slot in placement.assignment
                            for i in slot)
            if placed != list(range(len(self.members))):
                raise ValueError(
                    f"placement must cover every member exactly once: "
                    f"got {placed} for {len(self.members)} members")
        self.dispatch_count = 0
        self._count_lock = threading.Lock()    # server workers share us
        self._fns: List[Callable] = [
            _make_member_fn(m.params, m.spec, impl) for m in self.members]
        self._bucket_cache: Optional[List[_Bucket]] = None

    @classmethod
    def for_selector(cls, pool: Sequence["ZooMember"],
                     selector: np.ndarray, **kwargs) -> "EnsembleService":
        """Service over the subset of ``pool`` a binary selector picks —
        the control plane's staging constructor (swap.HotSwapper)."""
        idx = np.flatnonzero(np.asarray(selector, bool))
        return cls([pool[i] for i in idx], **kwargs)

    # ------------------------------------------------------------ plan
    @property
    def _buckets(self) -> List[_Bucket]:
        """Stacked dispatch plan, built lazily on first fused flush (so
        measurement-only services never pay the param stacking)."""
        if self._bucket_cache is None:
            with self._count_lock:
                if self._bucket_cache is None:
                    self._bucket_cache = self._build_buckets()
        return self._bucket_cache

    def _build_buckets(self) -> List[_Bucket]:
        specs = [m.spec for m in self.members]
        if self.placement is None:
            groups = [(None, list(range(len(specs))))]
        else:
            devs = self._devices if self._devices is not None \
                else jax.devices()
            used = [d for d, slot
                    in enumerate(self.placement.assignment) if slot]
            if used and used[-1] >= len(devs):
                # refuse to silently fold slots onto fewer devices: the
                # plan's makespan/imbalance would describe parallelism
                # that does not exist, poisoning the controller's T_s
                raise ValueError(
                    f"placement uses slot {used[-1]} but only "
                    f"{len(devs)} device(s) are available")
            groups = [(devs[d], list(slot))
                      for d, slot in enumerate(self.placement.assignment)
                      if slot]
        out = []
        for dev, mem_idx in groups:
            for local in bucket_zoo([specs[i] for i in mem_idx]).values():
                idx = [mem_idx[j] for j in local]
                spec = specs[idx[0]]
                stacked = stack_members([self.members[i].params
                                         for i in idx])
                if dev is not None:
                    stacked = jax.device_put(stacked, dev)
                out.append(_Bucket(
                    spec=spec, idx=idx,
                    leads=[specs[i].lead for i in idx],
                    stacked=stacked,
                    fn=_make_bucket_fn(spec, self.impl),
                    device=dev))
        return out

    @property
    def n_buckets(self) -> int:
        """Stacked dispatches per flush: architecture buckets, or
        (bucket, device) shards when a placement is active."""
        return len(self._buckets)

    def plan_placement(self, n_devices: int,
                       bucket_costs: Optional[Sequence[float]] = None,
                       reps: int = 3) -> Placement:
        """LPT plan over measured (or given) per-bucket costs, at BUCKET
        granularity: a stacked bucket is atomic, so the plan never splits
        one stacked dispatch across devices.  The returned assignment is
        in member indices, ready for ``EnsembleService(placement=...)``."""
        groups = list(bucket_zoo([m.spec for m in self.members]).values())
        if bucket_costs is None:
            if self.placement is not None:
                raise ValueError("measure bucket costs on an unsharded "
                                 "service (or pass bucket_costs)")
            bucket_costs = self.measured_bucket_costs(reps=reps)
        return grouped_lpt_placement(groups, list(bucket_costs),
                                     n_devices)

    # ---------------------------------------------------------- warmup
    def _bucket_input(self, b: _Bucket, p: int) -> jax.Array:
        x = np.zeros((len(b.idx), p, b.spec.input_len, 1), np.float32)
        if b.device is not None:
            return jax.device_put(x, b.device)
        return jnp.asarray(x)

    def warmup(self, batch_sizes: Sequence[int] = (1,)) -> None:
        if self.fused:
            for b in self._buckets:
                for p in batch_sizes:
                    b.fn(b.stacked,
                         self._bucket_input(b, p)).block_until_ready()
        else:
            for m, fn in zip(self.members, self._fns):
                fn(jnp.zeros((1, m.spec.input_len, 1)))

    def measured_costs(self, reps: int = 3,
                       warmup: int = 1) -> List[float]:
        """Closed-loop per-member seconds/query (the mu measurement).
        Always uses the per-member fns — the composer's latency profiler
        needs individual member costs regardless of fused serving.
        ``warmup`` untimed calls precede the timed reps so compile time
        never leaks into the estimate."""
        out = []
        for m, fn in zip(self.members, self._fns):
            x = jnp.zeros((1, m.spec.input_len, 1))
            for _ in range(max(1, warmup)):
                fn(x).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(x).block_until_ready()
            out.append((time.perf_counter() - t0) / reps)
        return out

    def measured_bucket_costs(self, reps: int = 3, batch: int = 1,
                              warmup: int = 1) -> List[float]:
        """Closed-loop seconds per stacked bucket dispatch — the cost
        vector the LPT placement planner consumes.  Each bucket is
        warmed with ``warmup`` untimed calls first: without that, the
        first call's compile time would fold into the cost estimate and
        skew the plan toward whichever bucket compiled first."""
        out = []
        for b in self._buckets:
            x = self._bucket_input(b, batch)
            for _ in range(max(1, warmup)):
                b.fn(b.stacked, x).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                b.fn(b.stacked, x).block_until_ready()
            out.append((time.perf_counter() - t0) / reps)
        return out

    # --------------------------------------------------------- serving
    def predict(self, windows: Dict[str, np.ndarray]) -> float:
        """windows: {"ecg": [3, L], "vitals": [7, W], "labs": [8]}.
        Returns the bagged P(stable) (Eq. 5)."""
        return self.predict_batch([windows])[0]

    def predict_batch(self, batch: Sequence[Dict[str, np.ndarray]]
                      ) -> List[float]:
        """Micro-batched form of ``predict``: one flush for windows from
        len(batch) patients.  Fused path: per bucket, ONE [M, P, L, 1]
        host->device transfer and ONE stacked dispatch; all device work
        is retired with a single blocking gather at the end.  ECG
        windows shorter than a member's input_len are left-zero-padded
        (the aggregator's zero-fill convention), keeping compile shapes
        static."""
        if not len(batch):
            return []
        if not self.fused:
            return [self._predict_one_unfused(w) for w in batch]

        P = len(batch)
        # pad the micro-batch to the next power of two: per-window
        # forward passes are batch-independent, so zero rows are inert,
        # and flushes of any size hit one of log2(max_batch) compiled
        # programs instead of recompiling per distinct size
        Ppad = 1 << (P - 1).bit_length()
        score_mat = np.zeros((len(self.members), P))
        pending = []
        for b in self._buckets:
            L = b.spec.input_len
            xs = np.zeros((len(b.idx), Ppad, L, 1), np.float32)
            for j, lead in enumerate(b.leads):
                for p, w in enumerate(batch):
                    clip = np.asarray(w["ecg"])[lead, -L:]
                    xs[j, p, L - clip.shape[-1]:, 0] = clip
            # sharded plan: pin the input beside its pinned params so
            # the dispatch runs on (and stays on) the shard's device
            x = jax.device_put(xs, b.device) if b.device is not None \
                else jnp.asarray(xs)
            y = b.fn(b.stacked, x)
            pending.append((b, y))                     # async dispatch
        with self._count_lock:
            self.dispatch_count += len(pending)
        for b, y in pending:      # one sync point: cross-device gather
            score_mat[b.idx] = np.asarray(
                jax.block_until_ready(y))[:, :P]

        return self._combine(score_mat, batch)

    def _predict_one_unfused(self, windows: Dict[str, np.ndarray]
                             ) -> float:
        ecg = windows.get("ecg")
        score_mat = np.zeros((len(self.members), 1))
        for i, (m, fn) in enumerate(zip(self.members, self._fns)):
            L = m.spec.input_len
            clip = np.asarray(ecg)[m.spec.lead, -L:]
            if clip.shape[-1] < L:     # zero-fill short windows (matches
                clip = np.pad(clip, (L - clip.shape[-1], 0))  # aggregator)
            score_mat[i, 0] = float(fn(jnp.asarray(clip)[None, :, None])[0])
        with self._count_lock:
            self.dispatch_count += len(self.members)
        return self._combine(score_mat, [windows])[0]

    def _combine(self, score_mat: np.ndarray,
                 batch: Sequence[Dict[str, np.ndarray]]) -> List[float]:
        """Per-patient Eq. 5 mean over zoo scores + CPU-side models."""
        out = []
        for p, windows in enumerate(batch):
            scores = list(score_mat[:, p]) if len(self.members) else []
            if self.vitals_model is not None and "vitals" in windows:
                scores.append(float(self.vitals_model.predict_proba(
                    windows["vitals"][None])[0]))
            if self.labs_model is not None and "labs" in windows:
                scores.append(float(self.labs_model.predict_proba(
                    windows["labs"][None])[0]))
            out.append(float(np.mean(scores)) if scores else 0.5)
        return out


class TierRouter:
    """Routes each query through its acuity tier's service (the data-
    plane face of per-tier degradation ladders).

    ``services`` maps tier -> anything with ``predict``/``predict_batch``
    (plain ``EnsembleService``s, or ``SwappableService`` facades when the
    control plane hot-swaps per-tier pairs underneath).  Batches must be
    tier-homogeneous — the tier-keyed batcher upstream
    (``serving.queues.KeyedMicroBatcher``) guarantees that — so one
    flush is always answered by exactly one tier's selector.
    """

    def __init__(self, services: Dict[str, object],
                 default: Optional[str] = None):
        if not services:
            raise ValueError("services must be non-empty")
        self.services = dict(services)
        self.default = default if default is not None \
            else next(iter(self.services))
        if self.default not in self.services:
            raise ValueError(f"default {self.default!r} not in "
                             f"{tuple(self.services)}")

    def service(self, tier: Optional[str] = None):
        return self.services[tier if tier in self.services
                             else self.default]

    def predict(self, windows: Dict[str, np.ndarray],
                tier: Optional[str] = None) -> float:
        return self.service(tier).predict(windows)

    def predict_batch(self, batch: Sequence[Dict[str, np.ndarray]],
                      tier: Optional[str] = None) -> List[float]:
        return self.service(tier).predict_batch(batch)


@dataclasses.dataclass
class ServedQuery:
    patient: int
    t_window: float
    t_done: float
    score: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_window


class StreamingPipeline:
    """Stateful aggregators + the ensemble service, driven by a stream.

    With ``tier_of`` (patient -> acuity tier) the service must be
    tier-routing (``TierRouter`` / ``control.tiers.TieredEnsemble``):
    each closed window is answered by the patient's CURRENT tier's
    service."""

    def __init__(self, service, n_patients: int,
                 window_seconds: float = float(CLIP_SECONDS),
                 tier_of: Optional[Callable[[int], str]] = None):
        mods = [ModalitySpec("ecg", ECG_HZ, 3),
                ModalitySpec("vitals", VITALS_HZ, 7)]
        self.service = service
        self.tier_of = tier_of
        self.aggs = [PatientAggregator(mods, window_seconds)
                     for _ in range(n_patients)]
        self.labs_cache: Dict[int, np.ndarray] = {}
        self.records: List[ServedQuery] = []

    def feed(self, t: float, patient: int, modality: str,
             samples: np.ndarray) -> Optional[ServedQuery]:
        if modality == "labs":
            self.labs_cache[patient] = np.asarray(samples)
            return None
        agg = self.aggs[patient]
        agg.ingest(t, modality, samples)
        if not agg.window_ready(t):
            return None
        windows = agg.pop_window(t)
        if patient in self.labs_cache:
            windows["labs"] = self.labs_cache[patient]
        t0 = time.perf_counter()
        if self.tier_of is not None:
            score = self.service.predict(windows, self.tier_of(patient))
        else:
            score = self.service.predict(windows)
        wall = time.perf_counter() - t0
        rec = ServedQuery(patient=patient, t_window=t, t_done=t + wall,
                          score=score)
        self.records.append(rec)
        return rec

    def latencies(self) -> np.ndarray:
        return np.asarray([r.latency for r in self.records])
