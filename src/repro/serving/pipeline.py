"""The served ensemble pipeline (Fig. 4): HTTP-ingest stand-in ->
stateful aggregators -> ensemble query -> bagging combine.

``EnsembleService`` does real jitted inference with the selected ECG zoo
members plus the CPU-side vitals/labs models; ``StreamingPipeline`` drives
it from per-patient multi-modal streams and records end-to-end wall-clock
latencies (the measured counterpart of the DES simulator).

Fused serving (the hot path)
----------------------------
By default the service executes the zoo in **architecture buckets**
(``configs.ecg_zoo.bucket_zoo``): members with identical shapes — leads
differ only in which input slice they consume — are stacked along a
leading member axis (``launch.ensemble_parallel.stack_members``) and run
as ONE ``ecg_apply_stacked`` dispatch per bucket, so a query costs
``n_buckets`` jitted calls (4 on the reduced 12-member zoo, 20 on the
full 60) instead of ``n_members``.  ``predict_batch`` additionally
micro-batches windows from MANY patients into the same stacked call.
The per-member loop is kept (``fused=False``) as the equivalence oracle
and for per-member cost measurement (``measured_costs``).

The one-transfer-per-device flush contract
------------------------------------------
A flush ships each patient's raw ``[ECG_LEADS, L]`` window to a device
AT MOST ONCE — never once per stacked member.  The host builds one
``[Ppad, ECG_LEADS, L]`` window pack per distinct input length (a
single O(P) pass; left-zero-padding of short windows and pow2 batch
padding land here), transfers it once per device that hosts a bucket
shard, and every bucket's jitted dispatch does its own **lead-gather**
on device: the bucket's static lead indices select member rows out of
the shared pack inside the same XLA program as the stacked forward
pass, so the old O(M x P) per-(member, patient) host marshaling loop —
and its M-times-redundant H2D traffic (M x L floats per patient
instead of ECG_LEADS x L) — is gone.  With **device-resident ingest**
(``serving.aggregator.DeviceIngest``), a batch of
``DeviceWindowRef``s skips even that single transfer: the pack is
gathered straight out of the on-device ring buffers
(``gather_windows``), and only the flushed (patient, end, valid) int32
triples cross the host boundary.  The pre-refactor marshaling loop is
preserved as ``marshal="legacy"`` — the ingest microbench's baseline
and a second equivalence oracle.  ``h2d_bytes`` / ``marshal_seconds``
counters account both regimes for ``BENCH_serving.json["ingest"]``.

Multi-device sharded serving (``placement=``)
---------------------------------------------
A ``serving.placement.Placement`` shards the stacked bucket params
across ``jax.devices()``: each placement slot's members are bucketed
independently and every (bucket, device) shard gets its own
``device_put``-pinned stacked pytree, so a flush issues one stacked
dispatch per shard — all async, on their own devices — and the scores
are combined by a single host-side gather at the end (the cross-device
gather/sum of Eq. 5).  Placement is controller-actuated state:
``control.swap.HotSwapper`` stages ``(selector, placement)`` pairs and
the adaptive controller re-derives the LPT plan from freshly measured
bucket costs (``measured_bucket_costs`` -> ``plan_placement``).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ecg_zoo import (CLIP_SECONDS, ECG_HZ, ECG_LEADS,
                                   EcgModelSpec, VITALS_HZ, bucket_zoo)
from repro.obs import spans as _spans
from repro.launch.ensemble_parallel import stack_members
from repro.models.ecg_resnext import ecg_apply, ecg_apply_stacked
from repro.serving.aggregator import (DeviceIngest, DeviceWindowRef,
                                      ModalitySpec, PatientAggregator,
                                      gather_windows, pow2_rung)
from repro.serving.placement import (Placement, grouped_lpt_placement,
                                     lpt_placement)


@dataclasses.dataclass
class ZooMember:
    spec: EcgModelSpec
    params: Dict


@dataclasses.dataclass
class _Bucket:
    """One stacked-execution group: structurally identical members.
    With a placement this is a (bucket, device) SHARD — the same bucket
    may appear once per device its members were assigned to."""
    spec: EcgModelSpec            # shape-defining representative
    idx: List[int]                # member indices into self.members
    leads: List[int]              # per stacked member, the lead it reads
    stacked: Dict                 # stack_members() pytree, leading axis M
    fn: Callable                  # jitted [M, P, L, 1] -> scores [M, P]
    device: object = None         # jax.Device the shard is pinned to
    slot: int = 0                 # placement slot index (0 if unsharded)


def _make_member_fn(params: Dict, spec: EcgModelSpec,
                    impl: str) -> Callable:
    return jax.jit(lambda x: jax.nn.softmax(
        ecg_apply(params, x, spec, impl=impl), axis=-1)[:, 1])


@functools.lru_cache(maxsize=None)
def _make_bucket_fn_cached(spec: EcgModelSpec, leads: Tuple[int, ...],
                           impl: str) -> Callable:
    @jax.jit
    def fn(stacked: Dict, win: jax.Array) -> jax.Array:
        # on-device lead-gather: the shared [Ppad, C, L] window pack is
        # expanded to the stacked [M, Ppad, L, 1] bucket view INSIDE
        # the dispatch — the member axis never exists host-side, so the
        # pack crosses to the device once per flush, not once per member
        xs = jnp.transpose(win[:, leads, :], (1, 0, 2))[..., None]
        logits = ecg_apply_stacked(stacked, xs, spec, impl=impl)
        return jax.nn.softmax(logits, axis=-1)[..., 1]     # [M, P]
    return fn


@functools.lru_cache(maxsize=None)
def _make_bucket_fn_legacy_cached(spec: EcgModelSpec,
                                  impl: str) -> Callable:
    """Pre-refactor dispatch: takes the host-marshaled [M, Ppad, L, 1]
    member-expanded input (``marshal="legacy"``) — kept as the ingest
    microbench baseline and equivalence oracle."""
    @jax.jit
    def fn(stacked: Dict, xs: jax.Array) -> jax.Array:
        logits = ecg_apply_stacked(stacked, xs, spec, impl=impl)
        return jax.nn.softmax(logits, axis=-1)[..., 1]     # [M, P]
    return fn


def _make_bucket_fn(spec: EcgModelSpec, leads: Sequence[int],
                    impl: str, marshal: str = "packed") -> Callable:
    """Shared per (architecture, leads, impl): every service (and every
    staged (selector, placement) pair) reuses ONE jit object per bucket
    shape, so re-staging across swaps/placements hits the compile cache
    instead of recompiling identical programs.  ``name``/``lead`` are
    blanked from the cache key; the packed form instead carries the
    bucket's full lead TUPLE statically — the on-device gather is baked
    into the program, and two buckets whose representative members
    differ only by name share it."""
    blank = dataclasses.replace(spec, name="", lead=0)
    if marshal == "legacy":
        return _make_bucket_fn_legacy_cached(blank, impl)
    return _make_bucket_fn_cached(blank, tuple(leads), impl)


# flush-size ladder: micro-batches pad up to aggregator.pow2_rung so
# every path (packed / refs / legacy) and the ingest side share one
# log2-bounded set of compiled shapes
_next_pow2 = pow2_rung

# representative flush rung for placement-planning cost measurement:
# serving flushes pad to the pow2 ladder (top default warmup rung 8),
# and per-bucket cost RATIOS at batch 1 differ from ratios at flush
# size (fixed dispatch overhead dominates small stacked calls), so
# planning from batch-1 timings skews the LPT plan
PLAN_BATCH = 8

# EWMA weight for per-shard retire-time tracking (O(1) state per
# (bucket, device) shard; higher = drift shows faster, noisier)
RETIRE_ALPHA = 0.3


@functools.lru_cache(maxsize=None)
def _warmup_pack(L: int, p: int, channels: int = ECG_LEADS
                 ) -> np.ndarray:
    """Shared zero window packs for warmup/staging: every bucket (and
    every service being staged for a hot swap) warms the same
    (length, flush-size) buffer instead of re-materializing windows
    per staged selector."""
    return np.zeros((p, channels, L), np.float32)


class EnsembleService:
    """Stateless ensemble actors with a bucketed fused dispatch plan.

    ``fused=True`` (default): one stacked jitted call per architecture
    bucket per flush, micro-batched across patients.  ``fused=False``:
    the original one-call-per-member-per-patient loop (kept as the
    numerical oracle).  ``dispatch_count`` tallies jitted zoo dispatches
    issued by ``predict``/``predict_batch`` — the quantity the serving
    benchmark tracks per query.

    ``placement`` (a ``serving.placement.Placement`` whose assignment
    covers every member exactly once) shards the fused plan across
    ``devices`` (default ``jax.devices()``): slot d's members are
    bucketed on their own and pinned to device d, one stacked dispatch
    per (bucket, device) shard.  BUCKET-ALIGNED plans (each bucket
    whole on one device — what ``plan_placement`` emits) are bitwise
    identical to the unsharded path: the stacked grouping never
    changes, only where it runs.  Arbitrary member-level assignments
    are also valid but alter the stacked member-axis size, so they
    match to float tolerance only.
    """

    def __init__(self, members: Sequence[ZooMember],
                 vitals_model=None, labs_model=None,
                 n_devices: int = 1, fused: bool = True,
                 impl: str = "xla",
                 placement: Optional[Placement] = None,
                 devices: Optional[Sequence] = None,
                 marshal: str = "packed"):
        self.members = list(members)
        self.vitals_model = vitals_model
        self.labs_model = labs_model
        self.fused = fused
        self.impl = impl
        self.n_devices = n_devices
        self.placement = placement
        if marshal not in ("packed", "legacy"):
            raise ValueError(f"unknown marshal mode {marshal!r}")
        self.marshal = marshal
        self._devices = list(devices) if devices is not None else None
        if placement is not None:
            if not fused:
                raise ValueError("placement requires the fused path")
            placed = sorted(i for slot in placement.assignment
                            for i in slot)
            if placed != list(range(len(self.members))):
                raise ValueError(
                    f"placement must cover every member exactly once: "
                    f"got {placed} for {len(self.members)} members")
        self.dispatch_count = 0
        # fault-injection seam (control.faults.FaultPlane): when set,
        # called with the bucket's pinned device (None = default) right
        # before each stacked dispatch; raising DeviceLostError here is
        # how a "device died mid-flush" materialises to the serving path
        self.dispatch_guard: Optional[Callable] = None
        # ingest-side accounting for BENCH_serving.json["ingest"]:
        # bytes shipped host->device for flush inputs, and host seconds
        # spent building/transferring them (the marshaling cost)
        self.h2d_bytes = 0
        self.marshal_seconds = 0.0
        # live per-shard retire times: (bucket member tuple) -> EWMA of
        # wall-clock seconds from that shard's dispatch to its retire
        # on the fused flush path.  O(1) state per shard (no lists) —
        # the drift signal HotSwapper.re_place / the controller's
        # finish-time imbalance consume.
        self.retire_alpha = RETIRE_ALPHA
        self._shard_ewma: Dict[Tuple[int, ...], float] = {}
        self._count_lock = threading.Lock()    # server workers share us
        self._fns: List[Callable] = [
            _make_member_fn(m.params, m.spec, impl) for m in self.members]
        self._bucket_cache: Optional[List[_Bucket]] = None

    @classmethod
    def for_selector(cls, pool: Sequence["ZooMember"],
                     selector: np.ndarray, **kwargs) -> "EnsembleService":
        """Service over the subset of ``pool`` a binary selector picks —
        the control plane's staging constructor (swap.HotSwapper)."""
        idx = np.flatnonzero(np.asarray(selector, bool))
        return cls([pool[i] for i in idx], **kwargs)

    # ------------------------------------------------------------ plan
    @property
    def _buckets(self) -> List[_Bucket]:
        """Stacked dispatch plan, built lazily on first fused flush (so
        measurement-only services never pay the param stacking)."""
        if self._bucket_cache is None:
            with self._count_lock:
                if self._bucket_cache is None:
                    self._bucket_cache = self._build_buckets()
        return self._bucket_cache

    def _build_buckets(self) -> List[_Bucket]:
        specs = [m.spec for m in self.members]
        if self.placement is None:
            groups = [(0, None, list(range(len(specs))))]
        else:
            devs = self._devices if self._devices is not None \
                else jax.devices()
            used = [d for d, slot
                    in enumerate(self.placement.assignment) if slot]
            if used and used[-1] >= len(devs):
                # refuse to silently fold slots onto fewer devices: the
                # plan's makespan/imbalance would describe parallelism
                # that does not exist, poisoning the controller's T_s
                raise ValueError(
                    f"placement uses slot {used[-1]} but only "
                    f"{len(devs)} device(s) are available")
            groups = [(d, devs[d], list(slot))
                      for d, slot in enumerate(self.placement.assignment)
                      if slot]
        out = []
        for slot_idx, dev, mem_idx in groups:
            for local in bucket_zoo([specs[i] for i in mem_idx]).values():
                idx = [mem_idx[j] for j in local]
                spec = specs[idx[0]]
                stacked = stack_members([self.members[i].params
                                         for i in idx])
                if dev is not None:
                    stacked = jax.device_put(stacked, dev)
                leads = [specs[i].lead for i in idx]
                out.append(_Bucket(
                    spec=spec, idx=idx,
                    leads=leads,
                    stacked=stacked,
                    fn=_make_bucket_fn(spec, leads, self.impl,
                                       self.marshal),
                    device=dev, slot=slot_idx))
        return out

    @property
    def n_buckets(self) -> int:
        """Stacked dispatches per flush: architecture buckets, or
        (bucket, device) shards when a placement is active."""
        return len(self._buckets)

    def plan_placement(self, n_devices: int,
                       bucket_costs: Optional[Sequence[float]] = None,
                       reps: int = 3,
                       batch: Optional[int] = None,
                       speeds: Optional[Sequence[float]] = None
                       ) -> Placement:
        """LPT plan over measured (or given) per-bucket costs, at BUCKET
        granularity: a stacked bucket is atomic, so the plan never splits
        one stacked dispatch across devices.  The returned assignment is
        in member indices, ready for ``EnsembleService(placement=...)``.

        Costs are measured at a REPRESENTATIVE FLUSH RUNG (``batch``,
        default ``PLAN_BATCH``): serving pads flushes to the pow2
        ladder, and per-bucket cost ratios at batch 1 differ from the
        ratios the plan will actually see.  ``speeds`` (one per slot)
        makes the plan heterogeneity-aware — see ``lpt_placement``."""
        groups = list(bucket_zoo([m.spec for m in self.members]).values())
        if bucket_costs is None:
            if self.placement is not None:
                raise ValueError("measure bucket costs on an unsharded "
                                 "service (or pass bucket_costs)")
            bucket_costs = self.measured_bucket_costs(
                reps=reps, batch=PLAN_BATCH if batch is None else batch)
        return grouped_lpt_placement(groups, list(bucket_costs),
                                     n_devices, speeds=speeds)

    # ---------------------------------------------------------- warmup
    def _bucket_input(self, b: _Bucket, p: int) -> jax.Array:
        if self.marshal == "legacy":
            x = np.zeros((len(b.idx), p, b.spec.input_len, 1),
                         np.float32)
        else:
            x = _warmup_pack(b.spec.input_len, p)
        if b.device is not None:
            return jax.device_put(x, b.device)
        return jnp.asarray(x)

    def warmup(self, batch_sizes: Sequence[int] = (1, 2, 4, 8)) -> None:
        """Compile every bucket dispatch at the pow2 flush-size ladder
        (the sizes ``predict_batch`` pads to), so the first full-census
        flush after build/staging never pays XLA compile on the
        latency-critical path.  Packed mode shares one zero window pack
        per (input length, flush size, device) across all buckets."""
        if self.fused:
            shared: Dict = {}
            for b in self._buckets:
                for p in batch_sizes:
                    key = (b.spec.input_len, b.device, p)
                    x = shared.get(key)
                    if x is None or self.marshal == "legacy":
                        x = self._bucket_input(b, p)
                        shared[key] = x
                    b.fn(b.stacked, x).block_until_ready()
        else:
            for m, fn in zip(self.members, self._fns):
                fn(jnp.zeros((1, m.spec.input_len, 1)))

    def measured_costs(self, reps: int = 3,
                       warmup: int = 1) -> List[float]:
        """Closed-loop per-member seconds/query (the mu measurement).
        Always uses the per-member fns — the composer's latency profiler
        needs individual member costs regardless of fused serving.
        ``warmup`` untimed calls precede the timed reps so compile time
        never leaks into the estimate."""
        out = []
        for m, fn in zip(self.members, self._fns):
            x = jnp.zeros((1, m.spec.input_len, 1))
            for _ in range(max(1, warmup)):
                fn(x).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(x).block_until_ready()
            out.append((time.perf_counter() - t0) / reps)
        return out

    def measured_bucket_costs(self, reps: int = 3, batch: int = 1,
                              warmup: int = 1) -> List[float]:
        """Closed-loop seconds per stacked bucket dispatch — the cost
        vector the LPT placement planner consumes.  Each bucket is
        warmed with ``warmup`` untimed calls first: without that, the
        first call's compile time would fold into the cost estimate and
        skew the plan toward whichever bucket compiled first."""
        out = []
        for b in self._buckets:
            x = self._bucket_input(b, batch)
            for _ in range(max(1, warmup)):
                b.fn(b.stacked, x).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                b.fn(b.stacked, x).block_until_ready()
            out.append((time.perf_counter() - t0) / reps)
        return out

    # --------------------------------------------------------- serving
    def predict(self, windows) -> float:
        """windows: {"ecg": [3, L], "vitals": [7, W], "labs": [8]} or a
        ``DeviceWindowRef``.  Returns the bagged P(stable) (Eq. 5)."""
        return self.predict_batch([windows])[0]

    def predict_batch(self, batch) -> List[float]:
        """Micro-batched form of ``predict``: one flush for windows
        from len(batch) patients — host window dicts or
        ``DeviceWindowRef``s (never mixed).  Fused packed path: ONE
        [Ppad, 3, L] window pack per distinct input length, shipped at
        most once per device, lead-expanded to the stacked bucket view
        inside each bucket's dispatch; all device work is retired with
        a single blocking gather at the end.  ECG windows shorter than
        a member's input_len are left-zero-padded (the aggregator's
        zero-fill convention), keeping compile shapes static."""
        if not len(batch):
            return []
        if isinstance(batch[0], DeviceWindowRef):
            return self._predict_refs(batch)
        if not self.fused:
            return [self._predict_one_unfused(w) for w in batch]
        if self.marshal == "legacy":
            return self._predict_batch_legacy(batch)

        P = len(batch)
        # pad the micro-batch to the next power of two: per-window
        # forward passes are batch-independent, so zero rows are inert,
        # and flushes of any size hit one of log2(max_batch) compiled
        # programs instead of recompiling per distinct size
        Ppad = _next_pow2(P)
        t_marshal = time.perf_counter()
        packs: Dict[int, np.ndarray] = {}
        for L in sorted({b.spec.input_len for b in self._buckets}):
            win = np.zeros((Ppad, ECG_LEADS, L), np.float32)
            for p, w in enumerate(batch):
                clip = np.asarray(w["ecg"], np.float32)[:, -L:]
                win[p, :, L - clip.shape[-1]:] = clip
            packs[L] = win
        dev_wins, h2d = self._ship_packs(packs)
        marshal_s = time.perf_counter() - t_marshal
        _spans.note("marshal", marshal_s)
        scores = self._flush(dev_wins, P)
        with self._count_lock:
            self.h2d_bytes += h2d
            self.marshal_seconds += marshal_s
        return self._combine(scores, batch)

    def _ship_packs(self, packs: Dict[int, np.ndarray]
                    ) -> Tuple[Dict, int]:
        """Transfer each window pack AT MOST once per device hosting a
        bucket shard; every shard on that device reads the same pinned
        copy.  Returns ({(L, device): array}, bytes shipped)."""
        dev_wins: Dict = {}
        h2d = 0
        for b in self._buckets:
            key = (b.spec.input_len, b.device)
            if key in dev_wins:
                continue
            win = packs[b.spec.input_len]
            nbytes = win.nbytes if isinstance(win, np.ndarray) else 0
            dev_wins[key] = jax.device_put(win, b.device) \
                if b.device is not None else jnp.asarray(win)
            h2d += nbytes
        return dev_wins, h2d

    def _flush(self, dev_wins: Dict, P: int) -> np.ndarray:
        """Issue one stacked dispatch per bucket shard against the
        shipped packs (async), then retire everything with a single
        cross-device gather."""
        score_mat = np.zeros((len(self.members), P))
        pending = []
        guard = self.dispatch_guard
        t_dispatch = time.perf_counter()
        for b in self._buckets:
            # per-shard clock starts BEFORE the guard: an injected
            # per-device stall (faults seam) is device time and must
            # drift that shard's retire EWMA
            t_b = time.perf_counter()
            if guard is not None:
                guard(b.device)
            y = b.fn(b.stacked, dev_wins[(b.spec.input_len, b.device)])
            pending.append((b, y, t_b))                # async dispatch
        with self._count_lock:
            self.dispatch_count += len(pending)
        t_gather = time.perf_counter()
        _spans.note("dispatch", t_gather - t_dispatch)
        for b, y, t_b in pending: # one sync point: cross-device gather
            score_mat[b.idx] = np.asarray(
                jax.block_until_ready(y))[:, :P]
            self._record_retire(b, time.perf_counter() - t_b)
        _spans.note("gather", time.perf_counter() - t_gather)
        return score_mat

    # ------------------------------------------- live shard cost drift
    def _record_retire(self, b: _Bucket, dt: float) -> None:
        """Fold one shard's dispatch->retire wall-clock into its EWMA.
        Attribution is gather-order conservative: shards retired behind
        a slower same-flush shard inherit some of its wait, but a
        persistently slow DEVICE inflates its own shards' EWMAs on
        every flush, so the drift signal converges over repeated
        flushes."""
        key = tuple(sorted(b.idx))
        with self._count_lock:
            prev = self._shard_ewma.get(key)
            self._shard_ewma[key] = dt if prev is None else (
                self.retire_alpha * dt
                + (1.0 - self.retire_alpha) * prev)

    def shard_cost_snapshot(self) -> Dict[Tuple[int, ...], float]:
        """Live per-shard retire EWMAs, keyed by the shard's sorted
        member-index tuple (stable across re-placements for
        bucket-aligned plans).  Empty until the first fused flush."""
        with self._count_lock:
            return dict(self._shard_ewma)

    def live_bucket_costs(self) -> Optional[List[float]]:
        """Measured per-architecture-bucket costs in DEVICE-INDEPENDENT
        work units (retire EWMA x the speed of the slot the bucket
        currently runs on), ordered like ``plan_placement``'s groups —
        i.e. a drop-in ``bucket_costs`` vector for re-planning from
        drift instead of a fresh offline measurement pass.  None until
        every bucket has been observed, or when the active plan is not
        bucket-aligned (member-split shards don't map back to
        architecture buckets)."""
        snap = self.shard_cost_snapshot()
        if not snap:
            return None
        groups = list(bucket_zoo([m.spec for m in self.members]).values())
        speed_of = {}
        if self._bucket_cache is not None:
            sp = self.placement.speeds if self.placement is not None \
                else None
            for b in self._bucket_cache:
                speed_of[tuple(sorted(b.idx))] = (
                    sp[b.slot] if sp is not None else 1.0)
        out = []
        for g in groups:
            key = tuple(sorted(g))
            dt = snap.get(key)
            if dt is None:
                return None
            out.append(dt * speed_of.get(key, 1.0))
        return out

    def measured_finish_times(self) -> Optional[List[float]]:
        """Live per-slot finish times (device wall-clock seconds): the
        max retire EWMA over the shards pinned to each slot — the
        last shard to retire IS the device's finish.  None until every
        shard has been observed.  Idle slots report 0.0, so the
        finish-time imbalance over this vector catches stranded
        devices."""
        if self._bucket_cache is None:
            return None
        snap = self.shard_cost_snapshot()
        n_slots = self.placement.n_slots if self.placement is not None \
            else 1
        fin = [0.0] * n_slots
        for b in self._bucket_cache:
            dt = snap.get(tuple(sorted(b.idx)))
            if dt is None:
                return None
            fin[b.slot] = max(fin[b.slot], dt)
        return fin

    def _predict_refs(self, batch: Sequence[DeviceWindowRef]
                      ) -> List[float]:
        """Device-resident flush: the batch's windows already live in a
        ``DeviceIngest`` ring, so the pack is GATHERED on device
        (``gather_windows`` fuses ring unwrap + zero-fill + batch
        padding) and only the flushed (patient, end, valid) int32
        triples cross the host boundary — zero sample bytes of H2D.
        Sharded plans copy the gathered pack device-to-device once per
        shard device.  Bitwise-identical to the host-dict path fed the
        same windows."""
        if not self.fused:
            return [self._predict_one_unfused(self._ref_windows(r))
                    for r in batch]
        if self.marshal == "legacy":
            raise ValueError("DeviceWindowRef flushes need the packed "
                             "marshal (legacy expects member-expanded "
                             "host inputs)")
        ingest = batch[0].ingest
        if any(r.ingest is not ingest for r in batch):
            raise ValueError("a flush must come from one DeviceIngest")
        state = ingest.states["ecg"]
        cap = state.buf.shape[-1]
        P = len(batch)
        Ppad = _next_pow2(P)
        t_marshal = time.perf_counter()
        lens = sorted({b.spec.input_len for b in self._buckets})
        # staleness guard: a ref enqueued behind a long stall can be
        # OUTLIVED by the ring — newer samples overwrite its window.
        # The oldest position any gather will read-and-use is
        # end - min(valid, max L); if ingest has advanced more than cap
        # past it, serving would silently score the WRONG window's
        # data, so refuse instead (the server's safe-batch wrapper
        # turns that into a NaN score for the stale query only).  Two
        # host integers per ref — nothing touches the device.
        l_max = max(lens, default=0)
        for r in batch:
            oldest = r.ends["ecg"] - min(r.valid["ecg"], l_max)
            if int(ingest.fed["ecg"][r.patient]) - oldest > cap:
                raise ValueError(
                    f"stale DeviceWindowRef for patient {r.patient}: "
                    f"the ring (capacity {cap}) has overwritten its "
                    f"window; flush sooner or raise capacity_windows")
        patients = np.zeros(Ppad, np.int32)
        ends = np.zeros(Ppad, np.int32)
        valid = np.zeros(Ppad, np.int32)
        for p, r in enumerate(batch):
            patients[p] = r.patient
            ends[p] = r.ends["ecg"] % cap
            valid[p] = r.valid["ecg"]
        pj, ej, vj = (jnp.asarray(patients), jnp.asarray(ends),
                      jnp.asarray(valid))
        h2d = patients.nbytes + ends.nbytes + valid.nbytes
        packs: Dict[int, jax.Array] = {}
        for L in lens:
            packs[L] = gather_windows(state.buf, pj, ej, vj, L)
        dev_wins, _ = self._ship_packs(packs)   # D2D for remote shards
        marshal_s = time.perf_counter() - t_marshal
        _spans.note("marshal", marshal_s)
        scores = self._flush(dev_wins, P)
        with self._count_lock:
            self.h2d_bytes += h2d
            self.marshal_seconds += marshal_s
        return self._combine(scores, self._refs_side_batch(batch))

    def _refs_side_batch(self, batch: Sequence[DeviceWindowRef]):
        """CPU-side model inputs for a ref flush: with a vitals model
        attached, read ALL flushed patients' vitals windows back in ONE
        batched gather (low-rate, tiny; index arrays padded to the same
        pow2 rung as the ECG path, so flush-size churn never recompiles
        it) instead of one device round-trip per patient, and hand
        ``_combine`` plain dicts.  Without CPU-side models the refs
        pass through untouched and nothing is ever read back."""
        if self.vitals_model is None \
                or "vitals" not in batch[0].ingest.states:
            return batch
        ingest = batch[0].ingest
        st = ingest.states["vitals"]
        cap = st.buf.shape[-1]
        want = ingest.want["vitals"]
        # the low-rate ring needs its own staleness guard: its (small)
        # capacity is overrun on a different clock than the ECG ring's
        for r in batch:
            oldest = r.ends["vitals"] - min(r.valid["vitals"], want)
            if int(ingest.fed["vitals"][r.patient]) - oldest > cap:
                raise ValueError(
                    f"stale DeviceWindowRef for patient {r.patient}: "
                    f"the vitals ring (capacity {cap}) has overwritten"
                    f" its window; flush sooner or raise "
                    f"capacity_windows")
        Ppad = _next_pow2(len(batch))
        patients = np.zeros(Ppad, np.int32)
        ends = np.zeros(Ppad, np.int32)
        valid = np.zeros(Ppad, np.int32)
        for p, r in enumerate(batch):
            patients[p] = r.patient
            ends[p] = r.ends["vitals"] % cap
            valid[p] = r.valid["vitals"]
        win = np.asarray(gather_windows(
            st.buf, jnp.asarray(patients), jnp.asarray(ends),
            jnp.asarray(valid), want))
        return [{**r.extra, "vitals": win[p]}
                for p, r in enumerate(batch)]

    def _ref_windows(self, r: DeviceWindowRef) -> Dict[str, np.ndarray]:
        """Materialize a ref as the oracle's host window dict (unfused
        fallback only — the fused path never reads samples back)."""
        out = dict(r.extra)
        for name in r.ends:
            out[name] = r.host_window(name)
        return out

    def _predict_batch_legacy(self, batch) -> List[float]:
        """Pre-refactor hot path: per bucket an [M, Ppad, L, 1] input
        is marshaled by a host (member, patient) double loop and
        shipped whole — M x L floats per patient per bucket.  Kept
        behind ``marshal="legacy"`` as the ingest bench baseline."""
        P = len(batch)
        Ppad = _next_pow2(P)
        score_mat = np.zeros((len(self.members), P))
        pending = []
        h2d = 0
        t_marshal = time.perf_counter()
        guard = self.dispatch_guard
        for b in self._buckets:
            if guard is not None:
                guard(b.device)
            L = b.spec.input_len
            xs = np.zeros((len(b.idx), Ppad, L, 1), np.float32)
            for j, lead in enumerate(b.leads):
                for p, w in enumerate(batch):
                    clip = np.asarray(w["ecg"])[lead, -L:]
                    xs[j, p, L - clip.shape[-1]:, 0] = clip
            h2d += xs.nbytes
            # sharded plan: pin the input beside its pinned params so
            # the dispatch runs on (and stays on) the shard's device
            x = jax.device_put(xs, b.device) if b.device is not None \
                else jnp.asarray(xs)
            y = b.fn(b.stacked, x)
            pending.append((b, y))                     # async dispatch
        marshal_s = time.perf_counter() - t_marshal
        # legacy interleaves marshal + dispatch per bucket; attribute
        # the whole pre-gather segment to marshal
        _spans.note("marshal", marshal_s)
        with self._count_lock:
            self.dispatch_count += len(pending)
            self.h2d_bytes += h2d
            self.marshal_seconds += marshal_s
        t_gather = time.perf_counter()
        for b, y in pending:      # one sync point: cross-device gather
            score_mat[b.idx] = np.asarray(
                jax.block_until_ready(y))[:, :P]
        _spans.note("gather", time.perf_counter() - t_gather)
        return self._combine(score_mat, batch)

    def _predict_one_unfused(self, windows: Dict[str, np.ndarray]
                             ) -> float:
        ecg = windows.get("ecg")
        if self.dispatch_guard is not None:
            self.dispatch_guard(None)       # unfused runs on the default
        score_mat = np.zeros((len(self.members), 1))
        for i, (m, fn) in enumerate(zip(self.members, self._fns)):
            L = m.spec.input_len
            clip = np.asarray(ecg)[m.spec.lead, -L:]
            if clip.shape[-1] < L:     # zero-fill short windows (matches
                clip = np.pad(clip, (L - clip.shape[-1], 0))  # aggregator)
            score_mat[i, 0] = float(fn(jnp.asarray(clip)[None, :, None])[0])
        with self._count_lock:
            self.dispatch_count += len(self.members)
        return self._combine(score_mat, [windows])[0]

    def _side_input(self, item, name: str) -> Optional[np.ndarray]:
        """The CPU-side models' input for one batch item: a window-dict
        key, or — for a ``DeviceWindowRef`` — the labs side channel /
        a lazy readback of the (tiny, low-rate) vitals window.  Only
        read when the matching model is attached, so the fused ECG
        path stays readback-free."""
        if isinstance(item, DeviceWindowRef):
            if name in item.extra:
                return item.extra[name]
            if name in item.ends:
                return item.host_window(name)
            return None
        return item.get(name)

    def _combine(self, score_mat: np.ndarray, batch) -> List[float]:
        """Per-patient Eq. 5 mean over zoo scores + CPU-side models."""
        out = []
        for p, windows in enumerate(batch):
            scores = list(score_mat[:, p]) if len(self.members) else []
            if self.vitals_model is not None:
                vit = self._side_input(windows, "vitals")
                if vit is not None:
                    scores.append(float(self.vitals_model.predict_proba(
                        vit[None])[0]))
            if self.labs_model is not None:
                labs = self._side_input(windows, "labs")
                if labs is not None:
                    scores.append(float(self.labs_model.predict_proba(
                        labs[None])[0]))
            out.append(float(np.mean(scores)) if scores else 0.5)
        return out


class TierRouter:
    """Routes each query through its acuity tier's service (the data-
    plane face of per-tier degradation ladders).

    ``services`` maps tier -> anything with ``predict``/``predict_batch``
    (plain ``EnsembleService``s, or ``SwappableService`` facades when the
    control plane hot-swaps per-tier pairs underneath).  Batches must be
    tier-homogeneous — the tier-keyed batcher upstream
    (``serving.queues.KeyedMicroBatcher``) guarantees that — so one
    flush is always answered by exactly one tier's selector.
    """

    def __init__(self, services: Dict[str, object],
                 default: Optional[str] = None):
        if not services:
            raise ValueError("services must be non-empty")
        self.services = dict(services)
        self.default = default if default is not None \
            else next(iter(self.services))
        if self.default not in self.services:
            raise ValueError(f"default {self.default!r} not in "
                             f"{tuple(self.services)}")

    def service(self, tier: Optional[str] = None):
        return self.services[tier if tier in self.services
                             else self.default]

    def predict(self, windows: Dict[str, np.ndarray],
                tier: Optional[str] = None) -> float:
        return self.service(tier).predict(windows)

    def predict_batch(self, batch: Sequence[Dict[str, np.ndarray]],
                      tier: Optional[str] = None) -> List[float]:
        return self.service(tier).predict_batch(batch)


@dataclasses.dataclass
class ServedQuery:
    patient: int
    t_window: float
    t_done: float
    score: float
    # per-stage service attribution (obs.spans stage keys -> seconds),
    # populated when the pipeline serves under span collection
    stages: Optional[Dict[str, float]] = None

    @property
    def latency(self) -> float:
        return self.t_done - self.t_window


class StreamingPipeline:
    """Stateful aggregators + the ensemble service, driven by a stream.

    ``device_ingest=True`` replaces the per-sample python tuple buffers
    with ``serving.aggregator.DeviceIngest``: 250 Hz chunks land in
    device-resident ring buffers via the compiled pow2-ladder
    ``ingest_chunk``, and a closed window is served as a
    ``DeviceWindowRef`` — the ensemble's flush gathers the samples on
    device, so the ingest->inference path never marshals waveforms
    through the host.  ``PatientAggregator`` (the default) is kept as
    the semantics oracle; the two paths score bitwise-identically
    under the equivalence suite's aligned-feed contract.

    With ``tier_of`` (patient -> acuity tier) the service must be
    tier-routing (``TierRouter`` / ``control.tiers.TieredEnsemble``):
    each closed window is answered by the patient's CURRENT tier's
    service.

    ``engine="slots"`` (requires ``device_ingest=True``, untiered, a
    plain fused ``EnsembleService``) switches from flush-per-window to
    the continuous slot engine (``serving.slots.SlotEngine``): a
    closed window UPDATES the bed's slot, and every ``tick_seconds``
    of logical stream time one tick rescores all occupied slots —
    records are emitted per (window, covering tick) with the slot's
    oracle-exact score."""

    def __init__(self, service, n_patients: int,
                 window_seconds: float = float(CLIP_SECONDS),
                 tier_of: Optional[Callable[[int], str]] = None,
                 device_ingest: bool = False,
                 capacity_windows: float = 2.0,
                 trace_stages: bool = False,
                 engine: str = "flush",
                 tick_seconds: Optional[float] = None):
        mods = [ModalitySpec("ecg", ECG_HZ, ECG_LEADS),
                ModalitySpec("vitals", VITALS_HZ, 7)]
        if engine not in ("flush", "slots"):
            raise ValueError(f"unknown engine {engine!r}")
        if engine == "slots" and not device_ingest:
            raise ValueError('engine="slots" requires device_ingest='
                             "True (slots ARE the device rings)")
        if engine == "slots" and tier_of is not None:
            raise ValueError('engine="slots" is untiered')
        self.engine = engine
        self.tick_seconds = (tick_seconds if tick_seconds is not None
                             else window_seconds)
        self.slot_engine = None
        self._last_tick_t: Optional[float] = None
        self._pending_close: Dict[int, float] = {}
        self.service = service
        self.tier_of = tier_of
        self.device_ingest: Optional[DeviceIngest] = None
        if device_ingest:
            self.device_ingest = DeviceIngest(
                mods, n_patients, window_seconds,
                capacity_windows=capacity_windows)
            # pre-compile the flush gather for every window length the
            # service can ask for (best effort: facades/routers don't
            # expose members — call warm_gather yourself there), so the
            # first closed window never pays XLA compile at serve time
            members = getattr(service, "members", None)
            if members:
                self.device_ingest.warm_gather(
                    tuple(sorted({m.spec.input_len for m in members})))
            # the CPU-side vitals model's batched readback gathers at
            # the same pow2 rungs over the (differently shaped) vitals
            # ring — warm those too, it costs milliseconds
            self.device_ingest.warm_gather(
                (self.device_ingest.want["vitals"],),
                modality="vitals")
            self.aggs = []
        else:
            self.aggs = [PatientAggregator(mods, window_seconds)
                         for _ in range(n_patients)]
        if engine == "slots":
            from repro.serving.slots import SlotEngine
            self.slot_engine = SlotEngine(service, self.device_ingest)
        self.labs_cache: Dict[int, np.ndarray] = {}
        self.records: List[ServedQuery] = []
        self.trace_stages = trace_stages

    def _close(self, t: float, patient: int):
        """The closed window in whichever representation the ingest
        side keeps: a host window dict, or a DeviceWindowRef."""
        if self.device_ingest is not None:
            extra = {}
            if patient in self.labs_cache:
                extra["labs"] = self.labs_cache[patient]
            return self.device_ingest.close_window(patient, t,
                                                   extra=extra)
        windows = self.aggs[patient].pop_window(t)
        if patient in self.labs_cache:
            windows["labs"] = self.labs_cache[patient]
        return windows

    def feed(self, t: float, patient: int, modality: str,
             samples: np.ndarray) -> Optional[ServedQuery]:
        if modality == "labs":
            self.labs_cache[patient] = np.asarray(samples)
            return None
        if self.device_ingest is not None:
            self.device_ingest.ingest(t, patient, modality, samples)
            if not self.device_ingest.window_ready(patient, t):
                return self._maybe_tick(t, patient) \
                    if self.engine == "slots" else None
        else:
            agg = self.aggs[patient]
            agg.ingest(t, modality, samples)
            if not agg.window_ready(t):
                return None
        windows = self._close(t, patient)
        if self.engine == "slots":
            # the closed window updates the bed's slot; scoring happens
            # at the next tick boundary of LOGICAL stream time, covering
            # every slot that closed a window since the last tick
            self.slot_engine.update(windows)
            self._pending_close[patient] = t
            return self._maybe_tick(t, patient)
        t0 = time.perf_counter()
        stages: Optional[Dict[str, float]] = None
        if self.trace_stages:
            with _spans.collect() as acc:
                if self.tier_of is not None:
                    score = self.service.predict(windows,
                                                 self.tier_of(patient))
                else:
                    score = self.service.predict(windows)
            stages = dict(acc)
        elif self.tier_of is not None:
            score = self.service.predict(windows, self.tier_of(patient))
        else:
            score = self.service.predict(windows)
        wall = time.perf_counter() - t0
        rec = ServedQuery(patient=patient, t_window=t, t_done=t + wall,
                          score=score, stages=stages)
        self.records.append(rec)
        return rec

    def _maybe_tick(self, t: float,
                    patient: Optional[int] = None
                    ) -> Optional[ServedQuery]:
        """Fire a slot tick when a tick interval of logical time has
        passed and windows are pending; emit one ``ServedQuery`` per
        pending closed window the tick covered.  Returns ``patient``'s
        record when this tick scored it."""
        if self._last_tick_t is None:
            self._last_tick_t = t
        if t - self._last_tick_t < self.tick_seconds \
                or not self._pending_close:
            return None
        return self.tick_now(t, patient)

    def tick_now(self, t: float,
                 patient: Optional[int] = None) -> Optional[ServedQuery]:
        """Force a slot tick at logical time ``t`` (drain helper: score
        whatever closed windows are still pending)."""
        eng = self.slot_engine
        if eng is None:
            raise ValueError("tick_now needs engine='slots'")
        t0 = time.perf_counter()
        report = eng.tick()
        wall = time.perf_counter() - t0
        self._last_tick_t = t
        out = None
        for s in map(int, report.scored):
            tw = self._pending_close.pop(s, None)
            if tw is None:
                continue        # rescored slot with no new window
            rec = ServedQuery(patient=s, t_window=tw, t_done=t + wall,
                              score=eng.read(s))
            self.records.append(rec)
            if s == patient:
                out = rec
        return out

    def latencies(self) -> np.ndarray:
        return np.asarray([r.latency for r in self.records])
