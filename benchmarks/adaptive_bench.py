"""Adaptive-vs-static serving under patient churn: the control plane's
acceptance harness.

A DES load spike — the census tripling mid-run by default — is served
two ways:

* ``static``   — the selector composed for the initial load, frozen
                 forever (the pre-control-plane behaviour);
* ``adaptive`` — the full loop: per-epoch telemetry (arrivals +
                 latencies replayed into ``SloTelemetry``) -> controller
                 decision (shed / recompose / climb) -> warm-started
                 ``recompose`` at the OBSERVED arrival rate -> selector
                 swap for the next epoch.

Writes ``BENCH_adaptive.json`` (per-epoch census, p50/p99, violation
rate and the served selector's accuracy, plus a REAL wall-clock
hot-swap segment demonstrating zero dropped queries) so the trajectory
is tracked across PRs.  ``synthetic_testbed`` keeps the default run
fast and deterministic; ``examples/serve_icu.py --adaptive`` drives the
same harness with the trained zoo and measured member costs.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.control.controller import (AdaptiveController, ControllerConfig,
                                      TieredController,
                                      TieredControllerConfig)
from repro.control.swap import SelectorLadder
from repro.control.telemetry import SloTelemetry, TieredTelemetry
from repro.core.bagging import roc_auc
from repro.core.composer import ComposerParams, compose, recompose
from repro.core.profiles import ModelProfile, ModelZoo, SystemConfig
from repro.serving.latency import LatencyProfiler
from repro.serving.placement import lpt_placement
from repro.serving.simulator import SimConfig, simulate

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_adaptive.json")


class _DesLadder(SelectorLadder):
    """Ladder whose activation is a no-op: the DES reads
    ``active_selector`` when it builds the next epoch's cost list."""

    def _activate(self, selector: np.ndarray) -> None:
        pass


def synthetic_testbed(n: int = 10, n_val: int = 400, seed: int = 0,
                      cost_lo: float = 0.04, cost_hi: float = 0.22
                      ) -> Tuple[ModelZoo, np.ndarray, Callable]:
    """A zoo where accuracy genuinely trades against latency: richer
    (slower) members are individually stronger, and independent score
    noise means bagging more members helps."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n_val)
    quality = np.linspace(0.5, 1.8, n) + rng.normal(0, 0.1, n)
    scores = np.stack([
        1.0 / (1.0 + np.exp(-(q * (2 * y - 1)
                              + rng.normal(0, 2.0, n_val))))
        for q in quality])
    costs = np.linspace(cost_lo, cost_hi, n)
    labels = (y == 1).astype(int)
    profiles = [ModelProfile(
        name=f"m{i}", depth=2 + i, width=16, macs=costs[i] * 1e9,
        memory_bytes=1e6, modality=0, input_len=100,
        val_auc=roc_auc(labels, scores[i])) for i in range(n)]
    zoo = ModelZoo(profiles, val_scores=scores, val_labels=labels)

    def f_a(b) -> float:
        sel = scores[np.asarray(b, bool)]
        return roc_auc(labels, sel.mean(axis=0)) if len(sel) else 0.5
    return zoo, costs, f_a


def _ladder_from(res, costs: np.ndarray) -> List[np.ndarray]:
    """Cheapest -> richest degradation ladder around a composition:
    the cheapest single member, the best previously profiled selector
    at <= half the incumbent's cost, and the incumbent itself."""
    costs = np.asarray(costs)

    def cost_of(b):
        return float(costs[np.asarray(b, bool)].sum())

    cheap = np.zeros(len(costs), np.int8)
    cheap[int(np.argmin(costs))] = 1
    levels = [cheap]
    half = cost_of(res.b_star) / 2
    mid = [(a, b) for b, a in zip(res.B, res.Y_acc)
           if 0 < cost_of(b) <= half and not np.array_equal(b, cheap)]
    if mid:
        levels.append(np.asarray(
            max(mid, key=lambda t: t[0])[1], np.int8))
    if not any(np.array_equal(l, res.b_star) for l in levels):
        levels.append(res.b_star.astype(np.int8))
    return levels


def run_adaptive_sim(zoo: ModelZoo, costs: Sequence[float], f_a: Callable,
                     slo: float, schedule: Sequence[Tuple[int, int]],
                     adaptive: bool = True, epoch_seconds: float = 40.0,
                     window_seconds: float = 10.0, n_devices: int = 2,
                     seed: int = 0,
                     compose_params: ComposerParams = None,
                     recompose_params: ComposerParams = None,
                     verbose: bool = False,
                     telemetry_exact: bool = False) -> Dict:
    """Epoch-driven closed loop over the DES.  ``schedule`` is a list of
    (n_epochs, census) phases; the initial composition always targets
    the FIRST phase's census (that is the point: the static selector is
    right for the load it was composed for)."""
    costs = np.asarray(costs, np.float64)
    epochs = [c for n_ep, c in schedule for _ in range(n_ep)]

    def f_l_for(n_patients: int) -> LatencyProfiler:
        return LatencyProfiler(
            zoo, SystemConfig(n_devices=n_devices, n_patients=n_patients,
                              window_seconds=window_seconds),
            cost_fn=lambda i: costs[i], seed=seed)

    res0 = compose(len(zoo), f_a, f_l_for(epochs[0]), slo,
                   compose_params or ComposerParams(N=6, M=80, K=4,
                                                    N0=10, seed=seed))
    swapper = _DesLadder(res0.b_star)
    swapper.set_ladder(_ladder_from(res0, costs))
    telemetry = SloTelemetry(slo_seconds=slo,
                             window_seconds=epoch_seconds,
                             clock=lambda: 0.0,
                             exact=telemetry_exact)
    state = {"warm": res0}

    def recompose_fn(snap):
        n_est = max(1, int(round(snap.arrival_rate * window_seconds)))
        r = recompose(f_a, f_l_for(n_est), slo, warm_start=state["warm"],
                      params=recompose_params
                      or ComposerParams(N=4, M=80, K=4, N0=8, seed=seed))
        state["warm"] = r
        swapper.set_ladder(_ladder_from(r, costs))
        return r.b_star

    def profile_fn():
        c = costs[swapper.active_selector.astype(bool)]
        if not len(c):
            return float("inf"), 0.0
        # Ts is the slowest device's total work under the LPT plan —
        # the same per-device-makespan model serving_latency uses — not
        # the single heaviest member
        pl = lpt_placement(list(c), n_devices)
        return n_devices / float(c.sum()), pl.makespan, pl.imbalance

    ctl = AdaptiveController(
        telemetry, swapper, recompose_fn=recompose_fn,
        config=ControllerConfig(slo_seconds=slo, cooldown_seconds=0.0,
                                min_samples=10),
        service_profile_fn=profile_fn, sync=True)

    records: List[Dict] = []
    carry = np.asarray([])                # unfinished-query backlog
    for e, census in enumerate(epochs):
        sel = swapper.active_selector.copy()
        c_sel = list(costs[sel.astype(bool)])
        r = simulate(c_sel, SimConfig(
            n_patients=census, n_devices=n_devices,
            window_seconds=window_seconds,
            duration_seconds=epoch_seconds, seed=seed + 17 * e,
            carry_backlog=True), backlog=carry)
        t0 = e * epoch_seconds
        if adaptive:                          # static arm has no reader
            for q in r.queries:
                if q.t_window >= 0:    # backlog arrivals were recorded
                    telemetry.record_arrival(t0 + q.t_window)
                telemetry.record_served(
                    q.latency, t0 + min(q.t_done, epoch_seconds))
            for age in r.backlog:      # born here, served next epoch
                # age > epoch_seconds means the query was carried IN
                # (born in an earlier epoch, arrival already recorded)
                if age <= epoch_seconds:
                    telemetry.record_arrival(t0 + epoch_seconds - age)
        lat = r.latencies()
        rec = {"epoch": e, "t0_s": t0, "census": census,
               "selector": np.flatnonzero(sel).tolist(),
               "n_members": int(sel.sum()),
               "accuracy": float(f_a(sel)),
               "served": len(r.queries),
               "backlog_in": len(carry),
               "backlog_out": len(r.backlog),
               # births this epoch: everything retired or carried out,
               # minus what was carried in — the conservation identity
               "born": len(r.queries) + len(r.backlog) - len(carry),
               "p50_s": r.p(50), "p99_s": r.p(99),
               "violation_rate": float(np.mean(lat > slo))
               if len(lat) else 0.0}
        carry = r.backlog
        if adaptive:
            rec["decision"] = ctl.step(now=(e + 1) * epoch_seconds).value
        records.append(rec)
        if verbose:
            print(f"  [{'adpt' if adaptive else 'stat'}] epoch {e} "
                  f"census {census:3d} members {rec['n_members']:2d} "
                  f"acc {rec['accuracy']:.3f} p99 {rec['p99_s']:7.3f}s "
                  f"viol {rec['violation_rate']:.2f} "
                  f"backlog {rec['backlog_out']:3d}"
                  + (f" -> {rec.get('decision', '')}" if adaptive else ""))

    served = sum(r["served"] for r in records)
    viol = sum(r["violation_rate"] * r["served"] for r in records)
    spike_start = schedule[0][0]
    return {"epochs": records,
            "violation_rate": viol / max(served, 1),
            "p99_final_spike_s":
                records[schedule[0][0] + schedule[1][0] - 1]["p99_s"]
                if len(schedule) > 1 else records[-1]["p99_s"],
            "mean_accuracy": float(np.mean(
                [r["accuracy"] for r in records])),
            "spike_start_epoch": spike_start,
            "initial_selector": np.flatnonzero(res0.b_star).tolist(),
            "actions": [(t, d.value) for t, d in ctl.log],
            "n_recomposes": ctl.n_recomposes,
            "served_total": served,
            "born_total": sum(r["born"] for r in records),
            "final_backlog": len(carry)}


DEFAULT_TIER_FRACS = {"stable": 0.60, "elevated": 0.25,
                      "critical": 0.15}


def run_tiered_sim(zoo: ModelZoo, costs: Sequence[float], f_a: Callable,
                   slo: float, schedule: Sequence[Tuple[int, int]],
                   tier_fracs: Dict[str, float] = None,
                   escalate_hazard: float = 0.02,
                   epoch_seconds: float = 40.0,
                   window_seconds: float = 10.0, n_devices: int = 2,
                   seed: int = 0, rho_max: float = 0.8,
                   compose_params: ComposerParams = None,
                   verbose: bool = False,
                   telemetry_exact: bool = False) -> Dict:
    """The per-acuity-tier closed loop over the DES: every tier starts
    on the RICH composed ensemble; under the census spike the
    priority-aware controller sheds stable-tier rungs first (and floors
    them in one actuation when the predicted device budget demands it)
    while the critical tier holds the rich ensemble — the headline
    claim is critical-tier p99/accuracy at rich-ensemble levels while
    only low-acuity rungs degrade.  Per-tier conservation fields
    (born = served + backlog_out - backlog_in, per tier, per epoch)
    sum to the fleet totals."""
    costs = np.asarray(costs, np.float64)
    fracs = dict(tier_fracs or DEFAULT_TIER_FRACS)
    tiers = tuple(fracs)
    epochs = [c for n_ep, c in schedule for _ in range(n_ep)]

    f_l0 = LatencyProfiler(
        zoo, SystemConfig(n_devices=n_devices, n_patients=epochs[0],
                          window_seconds=window_seconds),
        cost_fn=lambda i: costs[i], seed=seed)
    res0 = compose(len(zoo), f_a, f_l0, slo,
                   compose_params or ComposerParams(N=6, M=80, K=4,
                                                    N0=10, seed=seed))
    family = _ladder_from(res0, costs)
    lanes = {t: _DesLadder(res0.b_star) for t in tiers}
    for lane in lanes.values():
        lane.set_ladder(family)
    telemetry = TieredTelemetry(
        tier_of=lambda p: tiers[0], tiers=tiers, slo_seconds=slo,
        window_seconds=epoch_seconds, clock=lambda: 0.0,
        exact=telemetry_exact)
    ctl = TieredController(
        telemetry, lanes, tier_order=tiers,
        config=TieredControllerConfig(slo_seconds=slo,
                                      cooldown_seconds=0.0,
                                      min_samples=10, rho_max=rho_max),
        cost_fn=lambda sel: float(costs[np.asarray(sel, bool)].sum()),
        n_devices=n_devices)

    records: List[Dict] = []
    carry_ages, carry_tiers = np.asarray([]), []
    for e, census in enumerate(epochs):
        tier_costs = {
            t: list(costs[lanes[t].active_selector.astype(bool)])
            for t in tiers}
        r = simulate(tier_costs, SimConfig(
            n_patients=census, n_devices=n_devices,
            window_seconds=window_seconds,
            duration_seconds=epoch_seconds, seed=seed + 17 * e,
            carry_backlog=True, tiers=fracs,
            escalate_hazard=escalate_hazard),
            backlog=carry_ages, backlog_tiers=carry_tiers)
        t0 = e * epoch_seconds
        for q in r.queries:
            if q.t_window >= 0:    # backlog arrivals were recorded
                telemetry.record_arrival(t0 + q.t_window, tier=q.tier)
            telemetry.record_served(
                q.latency, t0 + min(q.t_done, epoch_seconds),
                tier=q.tier)
        for age, tr in zip(r.backlog, r.backlog_tiers):
            if age <= epoch_seconds:   # born here, served next epoch
                telemetry.record_arrival(t0 + epoch_seconds - age,
                                         tier=tr)
        per: Dict[str, Dict] = {}
        for t in tiers:
            qs = [q for q in r.queries if q.tier == t]
            lat = np.asarray([q.latency for q in qs])
            bl_in = sum(1 for x in carry_tiers if x == t)
            bl_out = sum(1 for x in r.backlog_tiers if x == t)
            sel_t = lanes[t].active_selector
            per[t] = {
                "rung": lanes[t].ladder_pos,
                "n_members": int(sel_t.sum()),
                "accuracy": float(f_a(sel_t)),
                "served": len(qs),
                "backlog_in": bl_in, "backlog_out": bl_out,
                "born": len(qs) + bl_out - bl_in,
                "p99_s": float(np.percentile(lat, 99))
                if len(lat) else 0.0,
                "violation_rate": float(np.mean(lat > slo))
                if len(lat) else 0.0}
        lat_all = r.latencies()
        rec = {"epoch": e, "t0_s": t0, "census": census,
               "served": len(r.queries),
               "born": len(r.queries) + len(r.backlog)
               - len(carry_tiers),
               "p50_s": r.p(50), "p99_s": r.p(99),
               "violation_rate": float(np.mean(lat_all > slo))
               if len(lat_all) else 0.0,
               "escalations": sum(1 for x in r.tier_log if x[2]),
               "tiers": per}
        carry_ages, carry_tiers = r.backlog, list(r.backlog_tiers)
        actions = ctl.step(now=(e + 1) * epoch_seconds)
        rec["decisions"] = [f"{d.value}:{t}" for d, t in actions]
        records.append(rec)
        if verbose:
            rungs = "/".join(str(per[t]["rung"]) for t in tiers)
            print(f"  [tier] epoch {e} census {census:3d} "
                  f"rungs {rungs} p99 {rec['p99_s']:7.3f}s "
                  f"viol {rec['violation_rate']:.2f} "
                  f"crit-viol {per[tiers[-1]]['violation_rate']:.2f}"
                  + (f" -> {','.join(rec['decisions'])}"
                     if rec["decisions"] else ""))

    per_tier: Dict[str, Dict] = {}
    for t in tiers:
        served = sum(r["tiers"][t]["served"] for r in records)
        viol = sum(r["tiers"][t]["violation_rate"]
                   * r["tiers"][t]["served"] for r in records)
        per_tier[t] = {
            "served": served,
            "born": sum(r["tiers"][t]["born"] for r in records),
            "final_backlog": sum(1 for x in carry_tiers if x == t),
            "violation_rate": viol / max(served, 1),
            "mean_accuracy": float(np.mean(
                [r["tiers"][t]["accuracy"] for r in records])),
            "final_rung": records[-1]["tiers"][t]["rung"],
            "min_rung": min(r["tiers"][t]["rung"] for r in records)}
    served_total = sum(r["served"] for r in records)
    return {"tier_fracs": fracs, "escalate_hazard": escalate_hazard,
            "rho_max": rho_max, "slo_s": slo,
            "epochs": records, "per_tier": per_tier,
            "served_total": served_total,
            "born_total": sum(r["born"] for r in records),
            "final_backlog": len(carry_tiers),
            # the conservation identity the acceptance tracks: per-tier
            # served sums to the fleet total, and per-tier born balances
            # served + final backlog
            "per_tier_served_sum": sum(
                v["served"] for v in per_tier.values()),
            "initial_selector": np.flatnonzero(res0.b_star).tolist(),
            "ladder_sizes": [int(s.sum()) for s in family],
            "actions": [(t, tier, d.value) for t, tier, d in ctl.log]}


def wallclock_hot_swap(n_queries: int = 48, n_swaps: int = 3,
                       input_len: int = 250, pool: Sequence = None,
                       sel_a: np.ndarray = None, sel_b: np.ndarray = None,
                       window_fn: Callable = None, n_workers: int = 2,
                       verbose: bool = True) -> Dict:
    """REAL jitted serving through the batch-aware server while the
    control plane hot-swaps selectors mid-stream: every submitted query
    must be served (zero dropped), across ``n_swaps`` swaps.  Defaults
    to a randomly-initialised reduced zoo split into even/odd selectors;
    pass ``pool``/``sel_a``/``sel_b``/``window_fn`` to run it on trained
    members (examples/serve_icu.py --adaptive)."""
    from repro.control.swap import HotSwapper
    from repro.serving.server import EnsembleServer

    if pool is None:
        import jax
        from repro.configs.ecg_zoo import zoo_specs
        from repro.models.ecg_resnext import init_ecg
        from repro.serving.pipeline import ZooMember
        specs = zoo_specs(reduced=True, input_len=input_len)
        pool = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
                for i, s in enumerate(specs)]
    n = len(pool)
    if sel_a is None:
        sel_a = np.asarray([i % 2 == 0 for i in range(n)], np.int8)
    if sel_b is None:
        sel_b = np.asarray([i % 2 == 1 for i in range(n)], np.int8)
    if window_fn is None:
        window_fn = lambda rng, i: {
            "ecg": rng.standard_normal((3, input_len))
            .astype(np.float32)}
    swapper = HotSwapper(pool, sel_a, warmup_batch_sizes=(1, 2, 4, 8))
    # register both selectors as the ladder so toggling between them
    # stays pre-staged (off-ladder selectors are evicted after a swap)
    swapper.set_ladder([sel_b, sel_a], prestage=True)
    srv = EnsembleServer(batch_handler=swapper.facade.predict_batch,
                         n_workers=n_workers, max_batch=8,
                         max_wait_ms=2.0).start()
    rng = np.random.default_rng(0)
    stride = max(1, n_queries // (n_swaps + 1))
    submitted = 0
    for i in range(n_queries):
        if i and i % stride == 0 and swapper.facade.swap_count < n_swaps:
            swapper.swap_to(sel_b if (i // stride) % 2 else sel_a)
        submitted += bool(srv.submit(i, window_fn(rng, i)))
    stats = srv.stop()
    out = {"submitted": submitted, "served": stats.served,
           "dropped": submitted - stats.served,
           "swaps": swapper.facade.swap_count,
           "p95_ms": stats.p(95) * 1e3}
    if verbose:
        print(f"  wall-clock hot-swap: {out['served']}/{out['submitted']}"
              f" served across {out['swaps']} swaps "
              f"({out['dropped']} dropped), p95 {out['p95_ms']:.1f} ms")
    return out


def bench_adaptive(slo: float = 1.0, n1: int = 24,
                   schedule: Sequence[Tuple[int, int]] = None,
                   seed: int = 0, verbose: bool = True,
                   write_json: bool = True, wallclock: bool = True) -> Dict:
    """Static-vs-adaptive under a census spike (n_patients tripling
    mid-run by default, then receding).  Records per-epoch violation
    rate, p99, and the served selector's accuracy over time."""
    zoo, costs, f_a = synthetic_testbed(seed=seed)
    schedule = schedule or [(3, n1), (4, 3 * n1), (3, n1)]
    common = dict(zoo=zoo, costs=costs, f_a=f_a, slo=slo,
                  schedule=schedule, seed=seed, verbose=verbose)
    if verbose:
        print(f"\nadaptive serving bench (census "
              f"{' -> '.join(str(c) for _, c in schedule)}, "
              f"SLO {slo:.1f}s):")
    static = run_adaptive_sim(adaptive=False, **common)
    adaptive = run_adaptive_sim(adaptive=True, **common)
    tiered = run_tiered_sim(zoo=zoo, costs=costs, f_a=f_a, slo=slo,
                            schedule=schedule, seed=seed,
                            verbose=verbose)
    # the headline comparison: the critical tier must do no worse than
    # the PR 2 global adaptive ladder (which degrades EVERY bed alike)
    # while only low-acuity rungs absorb the shed
    tiered["global_adaptive_violation_rate"] = \
        adaptive["violation_rate"]
    crit = list(tiered["tier_fracs"])[-1]
    tiered["critical_violation_rate"] = \
        tiered["per_tier"][crit]["violation_rate"]
    out = {"slo_s": slo, "schedule": [list(s) for s in schedule],
           "static": static, "adaptive": adaptive, "tiered": tiered}
    if wallclock:
        out["wallclock_swap"] = wallclock_hot_swap(verbose=verbose)
    if verbose:
        print(f"  static  : viol {static['violation_rate']:.2f}  "
              f"p99@spike {static['p99_final_spike_s']:.2f}s  "
              f"mean acc {static['mean_accuracy']:.3f}")
        print(f"  adaptive: viol {adaptive['violation_rate']:.2f}  "
              f"p99@spike {adaptive['p99_final_spike_s']:.2f}s  "
              f"mean acc {adaptive['mean_accuracy']:.3f}  "
              f"({adaptive['n_recomposes']} recomposes, "
              f"{len(adaptive['actions'])} actions)")
        pt = tiered["per_tier"]
        print(f"  tiered  : crit viol "
              f"{tiered['critical_violation_rate']:.2f} "
              f"(global adaptive {adaptive['violation_rate']:.2f})  "
              f"crit acc {pt[crit]['mean_accuracy']:.3f}  "
              f"stable min rung "
              f"{pt[list(tiered['tier_fracs'])[0]]['min_rung']}  "
              f"{len(tiered['actions'])} tier actions")
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(out, f, indent=2)
    return out
