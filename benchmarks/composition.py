"""Table 2 / Fig. 6 / Fig. 7 / Fig. 8: ensemble composition benchmarks.

Reproduces the paper's comparisons on the synthetic cohort:
  * table2: RD / AF / LF / NPO / HOLMES under a fixed latency budget,
    mean +/- std over seeds, all four metrics.
  * fig6: search trajectory (accuracy & latency per iteration).
  * fig7: final ROC-AUC across latency budgets, HOLMES vs NPO.
  * fig8: surrogate R2 vs profiler interactions.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.baselines import (accuracy_first, latency_first, npo,
                                  random_baseline)
from repro.core.bagging import all_metrics, bagging_predict
from repro.core.composer import ComposerParams, compose
from repro.core.profiles import SystemConfig

from benchmarks.zoo_setup import (binding_budget, build_zoo,
                                  make_profilers, single_model_stats)


def _ensemble_metrics(zoo, extras, b) -> Dict[str, float]:
    side = [extras["vitals_scores"], extras["labs_scores"]]
    sel = list(zoo.val_scores[np.asarray(b, bool)]) + side
    return all_metrics(zoo.val_labels, np.mean(sel, axis=0))


def run_all_methods(zoo, extras, budget: float, seed: int,
                    sysconf: SystemConfig, n_iters: int = 10, K: int = 6):
    f_a, f_l = make_profilers(zoo, sysconf, extras)
    acc1, lat1 = single_model_stats(zoo, f_a, f_l)
    n = len(zoo)
    rd = random_baseline(n, f_a, f_l, budget, seed=seed)
    af = accuracy_first(n, f_a, f_l, budget, acc1)
    lf = latency_first(n, f_a, f_l, budget, lat1)
    warm = [r.b_star for r in (rd, af, lf)]
    calls = n_iters * K + 12
    nr = npo(n, f_a, f_l, budget,
             max_subset=max(1, int(lf.b_star.sum())),
             n_calls=calls, seed=seed, warm_start=warm)
    hb = compose(n, f_a, f_l, budget,
                 ComposerParams(N=n_iters, K=K, N0=12, seed=seed),
                 warm_start=warm)
    return {"RD": rd, "AF": af, "LF": lf, "NPO": nr, "HOLMES": hb}


def bench_table2(budget: float = None, seeds=(0, 1, 2), verbose=True,
                 zoo=None, extras=None) -> Dict:
    if zoo is None:
        zoo, extras = build_zoo(verbose=verbose)
    sysconf = SystemConfig(n_devices=2, n_patients=64)
    if budget is None:
        _, f_l = make_profilers(zoo, sysconf, extras)
        budget = binding_budget(zoo, f_l)
    t0 = time.time()
    per_method: Dict[str, List[Dict[str, float]]] = {}
    for seed in seeds:
        res = run_all_methods(zoo, extras, budget, seed, sysconf)
        for name, r in res.items():
            m = _ensemble_metrics(zoo, extras, r.b_star)
            m["latency"] = r.latency
            m["feasible"] = float(r.feasible)
            per_method.setdefault(name, []).append(m)
    table = {}
    for name, rows in per_method.items():
        table[name] = {k: (float(np.mean([r[k] for r in rows])),
                           float(np.std([r[k] for r in rows])))
                       for k in rows[0]}
    if verbose:
        print(f"\nTable 2 (budget {budget * 1000:.0f} ms, "
              f"{len(seeds)} seeds, {time.time() - t0:.0f}s):")
        print(f"{'method':8s} {'ROC-AUC':>16s} {'PR-AUC':>16s} "
              f"{'F1':>16s} {'Accuracy':>16s} {'latency':>10s}")
        for name in ("RD", "AF", "LF", "NPO", "HOLMES"):
            r = table[name]
            print(f"{name:8s} "
                  f"{r['roc_auc'][0]:.4f}±{r['roc_auc'][1]:.4f} "
                  f"{r['pr_auc'][0]:.4f}±{r['pr_auc'][1]:.4f} "
                  f"{r['f1'][0]:.4f}±{r['f1'][1]:.4f} "
                  f"{r['accuracy'][0]:.4f}±{r['accuracy'][1]:.4f} "
                  f"{r['latency'][0] * 1000:9.1f}ms")
    return table


def bench_fig6(budget: float = None, seed: int = 0, verbose=True,
               zoo=None, extras=None) -> Dict:
    if zoo is None:
        zoo, extras = build_zoo(verbose=verbose)
    sysconf = SystemConfig(n_devices=2, n_patients=64)
    if budget is None:
        _, f_l = make_profilers(zoo, sysconf, extras)
        budget = binding_budget(zoo, f_l)
    res = run_all_methods(zoo, extras, budget, seed, sysconf, n_iters=12)
    out = {}
    for name, r in res.items():
        out[name] = [{"calls": h["profiler_calls"],
                      "acc": h["new_acc"], "lat": h["new_lat"],
                      "best_acc": h.get("best_acc")}
                     for h in r.history]
    if verbose:
        print("\nFig 6 trajectory (best feasible AUC by profiler calls):")
        for name in ("NPO", "HOLMES"):
            tr = out[name]
            line = " ".join(f"{h['best_acc']:.3f}" if h["best_acc"] ==
                            h["best_acc"] else "  -  "
                            for h in tr[:: max(1, len(tr) // 8)])
            print(f"  {name:7s} {line}")
    return out


def bench_fig7(budgets=None, seeds=(0, 1, 2),
               verbose=True, zoo=None, extras=None) -> Dict:
    if zoo is None:
        zoo, extras = build_zoo(verbose=verbose)
    sysconf = SystemConfig(n_devices=2, n_patients=64)
    if budgets is None:
        _, f_l = make_profilers(zoo, sysconf, extras)
        full = binding_budget(zoo, f_l, frac=1.0)
        budgets = tuple(round(full * f, 4) for f in
                        (0.15, 0.3, 0.5, 0.8))
    out = {}
    for budget in budgets:
        h_acc, n_acc = [], []
        for seed in seeds:
            res = run_all_methods(zoo, extras, budget, seed, sysconf,
                                  n_iters=8)
            h_acc.append(res["HOLMES"].accuracy)
            n_acc.append(res["NPO"].accuracy)
        out[budget] = {
            "HOLMES": (float(np.mean(h_acc)), float(np.std(h_acc))),
            "NPO": (float(np.mean(n_acc)), float(np.std(n_acc)))}
        if verbose:
            h, n = out[budget]["HOLMES"], out[budget]["NPO"]
            print(f"Fig 7 budget {budget * 1000:5.0f}ms: "
                  f"HOLMES {h[0]:.4f}±{h[1]:.4f}  "
                  f"NPO {n[0]:.4f}±{n[1]:.4f}")
    return out


def bench_fig8(budget: float = None, seed: int = 0, verbose=True,
               zoo=None, extras=None) -> List[Dict]:
    if zoo is None:
        zoo, extras = build_zoo(verbose=verbose)
    sysconf = SystemConfig(n_devices=2, n_patients=64)
    f_a, f_l = make_profilers(zoo, sysconf, extras)
    if budget is None:
        budget = binding_budget(zoo, f_l)
    rng = np.random.default_rng(seed + 100)
    n = len(zoo)
    held = []
    for _ in range(60):
        size = int(rng.integers(1, max(2, n // 2)))
        b = np.zeros(n, np.int8)
        b[rng.choice(n, size=size, replace=False)] = 1
        held.append(b)
    held = np.stack(held)
    ha = np.asarray([f_a(b) for b in held])
    hl = np.asarray([f_l(b) for b in held])
    res = compose(n, f_a, f_l, budget,
                  ComposerParams(N=12, K=6, N0=12, seed=seed),
                  heldout_B=held, heldout_acc=ha, heldout_lat=hl)
    traj = [{"calls": h["profiler_calls"], "r2_acc": h["r2_acc"],
             "r2_lat": h["r2_lat"]} for h in res.history]
    if verbose:
        print("\nFig 8 surrogate R2 (calls: acc / lat):")
        for h in traj:
            print(f"  {h['calls']:4d}: {h['r2_acc']:+.3f} / "
                  f"{h['r2_lat']:+.3f}")
    return traj
