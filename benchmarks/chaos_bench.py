"""Chaos soak harness: replay a streamed ICU trace through the FULL
device-ingest serving stack while a seeded ``FaultPlane`` injects
device loss, a worker stall, and an ingest-backpressure episode — then
hold the whole run to four invariants:

1. **conservation** — every submitted query is accounted exactly once:
   real-scored + NaN-failed + rejected == submitted (nothing silently
   dropped, nothing double-served);
2. **bitwise-vs-oracle** — every query that delivered a REAL score is
   bitwise-identical to a fault-free oracle rescoring of the exact same
   flush composition (window snapshot + member selection), so a fault
   can delay or fail a score but never silently change one;
3. **bounded recovery** — after each fault clears, the sliding-window
   p99 is back under the SLO within ``recovery_slo_s``;
4. **no leaked threads** — server workers/watchdog and controller
   monitor/recompose/replace threads (all ``repro-`` named) are gone
   after shutdown.

The run drives the real wiring end to end: ``DeviceIngest`` rings ->
``DeviceWindowRef`` submit -> bounded priority-aware ``ShedQueue`` ->
batch workers + watchdog -> ``HotSwapper`` facade armed by the fault
plane -> live ``AdaptiveController`` monitor loop
(``control.faults.wire_controller``) actuating on wall-clock telemetry.

``BENCH_chaos.json`` records both lanes: ``single_device`` (transient
device loss — the only recoverable shape without a survivor) and
``forced_8_device`` (permanent loss -> quarantine + re-place onto
survivors, run in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``--smoke`` is the CI tier1-chaos entry: tiny trace, fixed seed and
schedule, both lanes, schema-gated, writes nothing.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_chaos.json")
N_FORCED = 8

CHAOS_LANE_KEYS = (
    "n_devices", "n_patients", "windows_per_patient", "seed", "slo_s",
    "deadline_s", "schedule", "submitted", "ring_rejected", "served",
    "served_real", "failed", "rejected", "rejected_by_tier",
    "critical_rejected", "stalls", "quarantined", "recoveries",
    "controller", "faults", "p50_ms", "p99_ms",
    "conservation_ok", "bitwise_ok", "n_bitwise_checked",
    "recovery_ok", "no_leaked_threads", "leaked_threads",
)
FAULT_KINDS_REQUIRED = ("device_loss", "worker_stall", "backpressure")


def default_schedule(n_devices: int, t0: float = 0.45):
    """One of each fault kind.  With survivors the device loss is
    PERMANENT (recovery == quarantine + re-place); on a lone device it
    is transient (recovery == the device coming back) — the only
    recoverable shape there."""
    from repro.control.faults import FaultEvent
    if n_devices >= 2:
        loss = FaultEvent(t0, "device_loss", target=1, duration=0.0)
    else:
        loss = FaultEvent(t0, "device_loss", target=0, duration=0.35)
    return [loss,
            FaultEvent(t0 + 0.55, "worker_stall", duration=0.5),
            FaultEvent(t0 + 1.25, "backpressure", duration=0.4)]


def run_chaos(n_patients: int = 6, windows_per_patient: int = 10,
              input_len: int = 250, n_devices: int = 1, seed: int = 0,
              slo: float = 1.0, deadline: float = 0.25,
              max_queue: int = 32, window_wall_s: float = 0.25,
              recovery_slo_s: Optional[float] = None, schedule=None,
              use_controller: bool = True, verbose: bool = True) -> Dict:
    """One soak lane.  Returns the result dict (see CHAOS_LANE_KEYS)."""
    import jax

    if recovery_slo_s is None:
        # a PERMANENT loss on the sharded lane recovers by failover
        # restage — the moved buckets recompile, which on the forced
        # host-device rig costs real seconds; transient recovery on the
        # single-device lane is bounded by the fault duration itself
        recovery_slo_s = 30.0 if n_devices >= 2 else 5.0

    from repro.configs.ecg_zoo import ECG_LEADS, zoo_specs
    from repro.control.faults import FaultPlane, wire_controller
    from repro.control.swap import HotSwapper
    from repro.control.telemetry import SloTelemetry
    from repro.models.ecg_resnext import init_ecg
    from repro.obs.spans import SpanRecorder
    from repro.serving.aggregator import DeviceIngest, ModalitySpec
    from repro.serving.pipeline import EnsembleService, ZooMember
    from repro.serving.server import EnsembleServer

    n_devices = min(n_devices, jax.device_count())
    rng = np.random.default_rng(seed)
    specs = zoo_specs(reduced=True, input_len=input_len)
    pool = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
            for i, s in enumerate(specs)]
    n = len(pool)
    rich = np.ones(n, np.int8)
    mid = np.zeros(n, np.int8)
    mid[::2] = 1
    cheap = np.zeros(n, np.int8)
    cheap[0] = 1

    member_costs = EnsembleService(pool).measured_costs(reps=1) \
        if use_controller else None

    swapper = HotSwapper(pool, rich, n_devices=n_devices,
                         warmup_batch_sizes=(1, 2, 4, 8))
    swapper.set_ladder([cheap, mid, rich])
    telemetry = SloTelemetry(slo_seconds=slo, window_seconds=3.0)

    schedule = schedule if schedule is not None \
        else default_schedule(n_devices)
    plane = FaultPlane(schedule, seed=seed)

    # the member identity of each flush's service keys the oracle: a
    # controller shed/climb or fault re-place mid-run changes WHICH
    # selector scored a query, and the oracle must rescore with exactly
    # that selector (placement is bitwise-irrelevant: bucket-granular
    # plans reproduce the single-device scores exactly)
    pool_ids = {id(m): i for i, m in enumerate(pool)}
    flush_log: List = []            # (member_key, [qid], [score])
    log_lock = threading.Lock()

    def scoring(windows):
        svc = swapper.facade.current
        scores = list(svc.predict_batch(windows))
        key = tuple(pool_ids[id(m)] for m in svc.members)
        with log_lock:
            flush_log.append(
                (key, [w.extra["qid"] for w in windows], scores))
        return scores

    # heartbeat: the retry/failover wait inside protect() refreshes the
    # watchdog deadline (late-bound; srv is created just below)
    handler = plane.protect(scoring, swapper,
                            heartbeat=lambda: srv.heartbeat())

    def tier_of(patient):
        return "critical" if patient % 3 == 0 else "stable"

    tracer = SpanRecorder()
    srv = EnsembleServer(
        batch_handler=lambda ws, tier=None: handler(ws),
        n_workers=2, slo_seconds=slo, max_queue=max_queue,
        max_batch=8, max_wait_ms=2.0, telemetry=telemetry,
        tier_of=tier_of, tier_priority={"critical": 2, "stable": 0},
        deadline_seconds=deadline, tracer=tracer).start()

    ctl = wire_controller(telemetry, swapper, member_costs=member_costs,
                          period_seconds=0.2) if use_controller else None

    # logical ingest time: 1.0 "second" per window round (input_len
    # samples at input_len Hz), decoupled from window_wall_s wall pacing
    # vitals ride along so ring backpressure reflects the TIGHTEST
    # modality, not just ecg: headroom(p) aggregates min across rings
    # in window units (< 1.0 = can't absorb one more window)
    vitals_hz, vitals_ch = 5.0, 6
    di = DeviceIngest([ModalitySpec("ecg", float(input_len), ECG_LEADS),
                       ModalitySpec("vitals", vitals_hz, vitals_ch)],
                      n_patients, window_seconds=1.0,
                      capacity_windows=4.0)
    di.warm_gather(sorted({s.input_len for s in specs}))

    # arm LAST: the schedule clock starts when traffic starts, not while
    # warmup is still compiling (at 8 forced devices warm-up alone can
    # outlast the first scheduled fault, which would make every query in
    # the run land on an already-lost device)
    plane.arm(swapper)

    qid = 0
    oracle_windows: Dict[int, np.ndarray] = {}
    submitted = 0
    ring_rejected = 0
    fault_recovery: Dict[int, Optional[float]] = {
        i: None for i in range(len(schedule))}

    def check_recoveries():
        t_now = plane.now()
        for i, ev in enumerate(schedule):
            if fault_recovery[i] is not None:
                continue
            end = ev.t + ev.duration
            if t_now <= end + 0.05:
                continue
            snap = telemetry.snapshot(
                since=plane._armed_at + end + deadline)
            # recovered = REAL scores flowing again under the SLO;
            # NaN-failed retires also hit record_served, so subtract
            # them — a watchdog NaN storm must not count as recovery
            if snap.n_served - snap.n_failed >= 2 and snap.p99 <= slo:
                fault_recovery[i] = t_now - end

    zero_win = np.zeros((ECG_LEADS, input_len), np.float32)

    def submit_ref(p, ref):
        """Snapshot the ref's window AT SUBMIT TIME (the ring moves on;
        the oracle must see what a timely flush would have gathered).
        A ref closed with no fresh samples (the flood path) gathers the
        zero-filled dropout window — no device round-trip needed, which
        keeps the flood fast enough to actually overrun the queue."""
        nonlocal submitted
        qid_ = ref.extra["qid"]
        if all(v == 0 for v in ref.valid.values()):
            oracle_windows[qid_] = zero_win
        else:
            oracle_windows[qid_] = ref.host_window("ecg")
        submitted += 1
        srv.submit(p, ref)

    def maybe_flood():
        """During a backpressure episode, overrun the bounded queue with
        stable-tier queries: the priority-aware ShedQueue must shed
        these, never a critical.  (Re-closing an unchanged ring yields
        the valid=0 all-zeros dropout window — a legitimate degenerate
        query the oracle rescores like any other.)"""
        nonlocal qid
        if not plane.backpressure_active():
            return
        flood = [p for p in range(n_patients) if p % 3 != 0]
        for _ in range(max(2, (2 * max_queue) // max(1, len(flood)))):
            for p in flood:
                ref = di.close_window(p, t_logical + 1.0,
                                      extra={"qid": qid})
                qid += 1
                submit_ref(p, ref)

    t_logical = 0.0
    chunks = (100, 75, 75)
    for _round in range(windows_per_patient):
        for p in range(n_patients):
            if di.headroom(p) < 1.0:
                # ring backpressure: feeding would push outstanding
                # windows past the staleness guard in SOME modality —
                # reject up front (aggregate min, window units)
                ring_rejected += 1
                continue
            sig = rng.standard_normal(
                (ECG_LEADS, input_len)).astype(np.float32)
            off = 0
            for k in chunks:
                di.ingest(t_logical + off / input_len, p, "ecg",
                          sig[:, off:off + k])
                off += k
            di.ingest(t_logical, p, "vitals", rng.standard_normal(
                (vitals_ch, int(vitals_hz))).astype(np.float32))
            ref = di.close_window(p, t_logical + 1.0,
                                  extra={"qid": qid})
            qid += 1
            submit_ref(p, ref)
        maybe_flood()
        t_logical += 1.0
        check_recoveries()
        time.sleep(window_wall_s)

    # keep a light pulse flowing until the schedule has fully fired and
    # every fault has had its recovery window measured
    t_wait = time.monotonic() + recovery_slo_s + 2.0
    while (not plane.done()
           or any(v is None for v in fault_recovery.values())) \
            and time.monotonic() < t_wait:
        for p in range(min(2, n_patients)):
            if srv.q.qsize() >= max(2, max_queue // 2):
                break       # polite pulse: recovery measurement traffic
                #             must not re-trigger backpressure shedding
            if di.headroom(p) < 1.0:
                ring_rejected += 1
                continue
            sig = rng.standard_normal(
                (ECG_LEADS, input_len)).astype(np.float32)
            di.ingest(t_logical, p, "ecg", sig)
            di.ingest(t_logical, p, "vitals", rng.standard_normal(
                (vitals_ch, int(vitals_hz))).astype(np.float32))
            ref = di.close_window(p, t_logical + 1.0,
                                  extra={"qid": qid})
            qid += 1
            submit_ref(p, ref)
        maybe_flood()      # a late-scheduled backpressure episode must
        #                    still be exercised after the main trace
        t_logical += 1.0
        check_recoveries()
        time.sleep(window_wall_s)

    srv.drain(timeout=30.0)
    check_recoveries()
    stats = srv.stop()
    ctl_ok = ctl.stop() if ctl is not None else True
    leaked = sorted({t.name for t in threading.enumerate()
                     if t.is_alive() and t.name.startswith("repro-")})

    # ---------------------------------------------------- invariants
    results = []
    while True:
        batch = srv.results()
        if not batch:
            break
        results.extend(batch)
    n_real = sum(1 for _, s, _, _ in results if s == s)
    n_nan = sum(1 for _, s, _, _ in results if s != s)
    conservation_ok = (stats.served + stats.shed == submitted
                       and len(results) == stats.served
                       and n_real + n_nan == stats.served
                       and n_nan == stats.failed)

    # fault-free oracle: rescore each logged flush (same windows, same
    # member selection, unsharded, no faults) and demand bitwise
    # equality for every query that DELIVERED a real score
    qid_flush: Dict[int, tuple] = {}
    with log_lock:
        for key, qids, scores in flush_log:
            for q, s in zip(qids, scores):
                qid_flush[q] = (key, qids, s)
    oracle_cache: Dict[tuple, EnsembleService] = {}
    oracle_scores: Dict[tuple, Dict[int, float]] = {}
    bitwise_ok = True
    n_checked = 0
    for patient, score, _lat, ref in results:
        if score != score:
            continue                      # NaN-failed: conservation's job
        q = ref.extra["qid"]
        ent = qid_flush.get(q)
        if ent is None:
            bitwise_ok = False
            break
        key, qids, logged = ent
        flush_id = (key, tuple(qids))
        if flush_id not in oracle_scores:
            svc = oracle_cache.get(key)
            if svc is None:
                svc = EnsembleService([pool[i] for i in key])
                oracle_cache[key] = svc
            want = svc.predict_batch(
                [{"ecg": oracle_windows[x]} for x in qids])
            oracle_scores[flush_id] = dict(zip(qids, want))
        ok = (score == logged == oracle_scores[flush_id][q])
        bitwise_ok = bitwise_ok and ok
        n_checked += 1
        if not ok:
            break

    recovery_s = [fault_recovery[i] for i in range(len(schedule))]
    recovery_ok = all(r is not None and r <= recovery_slo_s
                      for r in recovery_s)
    no_leaked = (not leaked) and (not srv.leaked) and ctl_ok

    out = {
        "n_devices": n_devices, "n_patients": n_patients,
        "windows_per_patient": windows_per_patient, "seed": seed,
        "slo_s": slo, "deadline_s": deadline,
        "schedule": [ev.to_dict() for ev in schedule],
        "submitted": submitted, "ring_rejected": ring_rejected,
        "served": stats.served, "served_real": n_real,
        "failed": stats.failed, "rejected": stats.shed,
        "rejected_by_tier": {str(k): v
                             for k, v in stats.rejected.items()},
        "critical_rejected": stats.rejected.get("critical", 0),
        "stalls": stats.stalls,
        "quarantined": [str(d) for d in swapper.quarantined],
        "recoveries": plane.recoveries,
        "controller": {
            "enabled": use_controller,
            "actions": [[round(t, 3), d.name] for t, d in ctl.log]
            if ctl is not None else [],
            "n_recomposes": ctl.n_recomposes if ctl is not None else 0},
        "faults": [{**ev.to_dict(),
                    "recovery_s": recovery_s[i]}
                   for i, ev in enumerate(schedule)],
        "p50_ms": stats.p(50) * 1e3, "p99_ms": stats.p(99) * 1e3,
        "conservation_ok": bool(conservation_ok),
        "bitwise_ok": bool(bitwise_ok), "n_bitwise_checked": n_checked,
        "recovery_ok": bool(recovery_ok),
        "no_leaked_threads": bool(no_leaked),
        "leaked_threads": leaked + list(srv.leaked)
        + (list(ctl.leaked) if ctl is not None else []),
    }
    # span-trace digest (optional key — not part of the gated schema):
    # under chaos the by_status mix is the interesting bit, e.g. the
    # watchdog-killed co-batch shows up as status="watchdog" spans
    att = tracer.attribution()
    out["obs"] = {
        "n_spans": att["n_spans"], "by_status": att["by_status"],
        "coverage": round(att["coverage"], 4),
        "stage_ms": {k: round(1e3 * v / max(att["n_spans"], 1), 3)
                     for k, v in att["stage_seconds"].items()},
    }
    if verbose:
        print(f"\nchaos soak ({n_devices} device(s), "
              f"{n_patients} patients x {windows_per_patient} windows):")
        print(f"  submitted {submitted}  real {n_real}  failed "
              f"{stats.failed}  rejected {stats.shed} "
              f"(ring {ring_rejected})  stalls {stats.stalls}  "
              f"quarantined {out['quarantined']}")
        print(f"  conservation {conservation_ok}  bitwise {bitwise_ok} "
              f"({n_checked} checked)  recovery {recovery_ok} "
              f"{[None if r is None else round(r, 2) for r in recovery_s]}"
              f"  no_leaked_threads {no_leaked}")
    return out


# ------------------------------------------------------------- schema
def check_chaos_schema(lane: Dict) -> None:
    """Gate one lane's result: every tracked key present, all four
    whole-run invariants TRUE, and the schedule actually contained at
    least one fault of each required kind."""
    for k in CHAOS_LANE_KEYS:
        assert k in lane, f"missing chaos lane key: {k}"
    kinds = {ev["kind"] for ev in lane["schedule"]}
    for k in FAULT_KINDS_REQUIRED:
        assert k in kinds, f"schedule missing fault kind {k}"
    for inv in ("conservation_ok", "bitwise_ok", "recovery_ok",
                "no_leaked_threads"):
        assert lane[inv] is True, f"invariant failed: {inv} ({lane})"
    assert lane["n_bitwise_checked"] > 0, "oracle checked nothing"
    assert lane["stalls"] >= 1, "worker stall never detected"
    assert lane["rejected"] >= 1, "backpressure never shed anything"
    assert lane["critical_rejected"] == 0, \
        "a critical query was rejected"


def check_chaos_file(path: str = BENCH_JSON) -> None:
    """CI gate on the committed BENCH_chaos.json: both lanes present
    and individually valid."""
    with open(path) as f:
        data = json.load(f)
    for lane_name in ("single_device", "forced_8_device"):
        assert lane_name in data, f"missing lane {lane_name}"
        check_chaos_schema(data[lane_name])
    assert data["forced_8_device"]["n_devices"] >= 2
    assert data["forced_8_device"]["quarantined"], \
        "multi-device lane never quarantined the lost device"
    print(f"chaos schema OK ({path})")


# ----------------------------------------------------- lane dispatch
def _subprocess_lane(n_patients: int, windows: int,
                     seed: int = 0) -> Dict:
    """Run the forced-8-device lane in a subprocess (XLA device count
    is fixed at jax init, so the multi-device lane needs its own
    process)."""
    import tempfile
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={N_FORCED}")
    env.pop("PYTEST_CURRENT_TEST", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out_path = f.name
    try:
        r = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--emit",
             out_path, "--devices", str(N_FORCED),
             "--n-patients", str(n_patients),
             "--windows", str(windows), "--seed", str(seed)],
            cwd=repo, env=env, capture_output=True, text=True,
            timeout=1200)
        if r.returncode != 0:
            raise RuntimeError("forced-8-device lane failed:\n"
                               + (r.stdout or "")[-2000:]
                               + (r.stderr or "")[-4000:])
        with open(out_path) as f:
            return json.load(f)
    finally:
        os.unlink(out_path)


def _merge_bench_json(updates: Dict) -> None:
    merged = {}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            merged = json.load(f)
    merged.update(updates)
    with open(BENCH_JSON, "w") as f:
        json.dump(merged, f, indent=2)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-trace CI invocation: both lanes, schema "
                         "gates, writes nothing")
    ap.add_argument("--emit", default=None,
                    help="run ONE lane in this process and write its "
                         "result dict to this path (subprocess entry)")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--n-patients", type=int, default=None)
    ap.add_argument("--windows", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.emit:
        out = run_chaos(n_patients=args.n_patients or 6,
                        windows_per_patient=args.windows or 10,
                        n_devices=args.devices, seed=args.seed)
        check_chaos_schema(out)
        with open(args.emit, "w") as f:
            json.dump(out, f, indent=2)
    elif args.smoke:
        lane1 = run_chaos(n_patients=args.n_patients or 4,
                          windows_per_patient=args.windows or 8,
                          n_devices=1, seed=args.seed)
        check_chaos_schema(lane1)
        lane8 = _subprocess_lane(args.n_patients or 4,
                                 args.windows or 8, seed=args.seed)
        check_chaos_schema(lane8)
        assert lane8["n_devices"] >= 2 and lane8["quarantined"]
        print("chaos smoke OK (single-device + forced-8-device lanes)")
    else:
        lane1 = run_chaos(n_patients=args.n_patients or 6,
                          windows_per_patient=args.windows or 10,
                          n_devices=1, seed=args.seed)
        check_chaos_schema(lane1)
        lane8 = _subprocess_lane(args.n_patients or 6,
                                 args.windows or 10, seed=args.seed)
        check_chaos_schema(lane8)
        _merge_bench_json({"single_device": lane1,
                           "forced_8_device": lane8})
        check_chaos_file()
