"""Chaos soak harness: replay a streamed ICU trace through the FULL
device-ingest serving stack while a seeded ``FaultPlane`` injects
device loss, a worker stall, and an ingest-backpressure episode — then
hold the whole run to four invariants:

1. **conservation** — every submitted query is accounted exactly once:
   real-scored + NaN-failed + rejected == submitted (nothing silently
   dropped, nothing double-served);
2. **bitwise-vs-oracle** — every query that delivered a REAL score is
   bitwise-identical to a fault-free oracle rescoring of the exact same
   flush composition (window snapshot + member selection), so a fault
   can delay or fail a score but never silently change one;
3. **bounded recovery** — after each fault clears, the sliding-window
   p99 is back under the SLO within ``recovery_slo_s``;
4. **no leaked threads** — server workers/watchdog and controller
   monitor/recompose/replace threads (all ``repro-`` named) are gone
   after shutdown.

The run drives the real wiring end to end: ``DeviceIngest`` rings ->
``DeviceWindowRef`` submit -> bounded priority-aware ``ShedQueue`` ->
batch workers + watchdog -> ``HotSwapper`` facade armed by the fault
plane -> live ``AdaptiveController`` monitor loop
(``control.faults.wire_controller``) actuating on wall-clock telemetry.

``BENCH_chaos.json`` records both lanes: ``single_device`` (transient
device loss — the only recoverable shape without a survivor) and
``forced_8_device`` (permanent loss -> quarantine + re-place onto
survivors, run in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``--smoke`` is the CI tier1-chaos entry: tiny trace, fixed seed and
schedule, both lanes, schema-gated, writes nothing.

The SLOT lane (``BENCH_chaos.json["slots"]``) soaks the continuous
slot engine instead of the flush path: a compressed-time MIMIC-style
cohort trace (each driver step is ``step_logical_s`` of ICU time, so
minutes of wall clock replay tens of logical hours of census churn —
Poisson admissions through ``SlotEngine.acquire_slot`` growing the
census past its initial ``n_slots``, lognormal length-of-stay
discharges, escalated beds closing windows faster than stable ones)
under ``slot_compound_schedule`` (a ticker-stall cascade the
``TickerWatchdog`` must respawn through, plus overlapping device
losses inside a backpressure episode).  Its bitwise oracle is the
TICK REPORT: every ``(slot, close-version, pad-rung)`` a tick ever
stamped is re-scored offline by an unsharded fault-free
``EnsembleService`` at exactly that pad rung, and every REAL score a
query served must be one of its slot's stamped scores — a fault can
delay a tick or NaN a read, never alter a score.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_chaos.json")
N_FORCED = 8

CHAOS_LANE_KEYS = (
    "n_devices", "n_patients", "windows_per_patient", "seed", "slo_s",
    "deadline_s", "schedule", "submitted", "ring_rejected", "served",
    "served_real", "failed", "rejected", "rejected_by_tier",
    "critical_rejected", "stalls", "quarantined", "recoveries",
    "controller", "faults", "p50_ms", "p99_ms",
    "conservation_ok", "bitwise_ok", "n_bitwise_checked",
    "recovery_ok", "no_leaked_threads", "leaked_threads",
)
FAULT_KINDS_REQUIRED = ("device_loss", "worker_stall", "backpressure")


def default_schedule(n_devices: int, t0: float = 0.45):
    """One of each fault kind.  With survivors the device loss is
    PERMANENT (recovery == quarantine + re-place); on a lone device it
    is transient (recovery == the device coming back) — the only
    recoverable shape there."""
    from repro.control.faults import FaultEvent
    if n_devices >= 2:
        loss = FaultEvent(t0, "device_loss", target=1, duration=0.0)
    else:
        loss = FaultEvent(t0, "device_loss", target=0, duration=0.35)
    return [loss,
            FaultEvent(t0 + 0.55, "worker_stall", duration=0.5),
            FaultEvent(t0 + 1.25, "backpressure", duration=0.4)]


def run_chaos(n_patients: int = 6, windows_per_patient: int = 10,
              input_len: int = 250, n_devices: int = 1, seed: int = 0,
              slo: float = 1.0, deadline: float = 0.25,
              max_queue: int = 32, window_wall_s: float = 0.25,
              recovery_slo_s: Optional[float] = None, schedule=None,
              use_controller: bool = True, verbose: bool = True) -> Dict:
    """One soak lane.  Returns the result dict (see CHAOS_LANE_KEYS)."""
    import jax

    if recovery_slo_s is None:
        # a PERMANENT loss on the sharded lane recovers by failover
        # restage — the moved buckets recompile, which on the forced
        # host-device rig costs real seconds; transient recovery on the
        # single-device lane is bounded by the fault duration itself
        recovery_slo_s = 30.0 if n_devices >= 2 else 5.0

    from repro.configs.ecg_zoo import ECG_LEADS, zoo_specs
    from repro.control.faults import FaultPlane, wire_controller
    from repro.control.swap import HotSwapper
    from repro.control.telemetry import SloTelemetry
    from repro.models.ecg_resnext import init_ecg
    from repro.obs.spans import SpanRecorder
    from repro.serving.aggregator import DeviceIngest, ModalitySpec
    from repro.serving.pipeline import EnsembleService, ZooMember
    from repro.serving.server import EnsembleServer

    n_devices = min(n_devices, jax.device_count())
    rng = np.random.default_rng(seed)
    specs = zoo_specs(reduced=True, input_len=input_len)
    pool = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
            for i, s in enumerate(specs)]
    n = len(pool)
    rich = np.ones(n, np.int8)
    mid = np.zeros(n, np.int8)
    mid[::2] = 1
    cheap = np.zeros(n, np.int8)
    cheap[0] = 1

    member_costs = EnsembleService(pool).measured_costs(reps=1) \
        if use_controller else None

    swapper = HotSwapper(pool, rich, n_devices=n_devices,
                         warmup_batch_sizes=(1, 2, 4, 8))
    swapper.set_ladder([cheap, mid, rich])
    telemetry = SloTelemetry(slo_seconds=slo, window_seconds=3.0)

    schedule = schedule if schedule is not None \
        else default_schedule(n_devices)
    plane = FaultPlane(schedule, seed=seed)

    # the member identity of each flush's service keys the oracle: a
    # controller shed/climb or fault re-place mid-run changes WHICH
    # selector scored a query, and the oracle must rescore with exactly
    # that selector (placement is bitwise-irrelevant: bucket-granular
    # plans reproduce the single-device scores exactly)
    pool_ids = {id(m): i for i, m in enumerate(pool)}
    flush_log: List = []            # (member_key, [qid], [score])
    log_lock = threading.Lock()

    def scoring(windows):
        svc = swapper.facade.current
        scores = list(svc.predict_batch(windows))
        key = tuple(pool_ids[id(m)] for m in svc.members)
        with log_lock:
            flush_log.append(
                (key, [w.extra["qid"] for w in windows], scores))
        return scores

    # heartbeat: the retry/failover wait inside protect() refreshes the
    # watchdog deadline (late-bound; srv is created just below)
    handler = plane.protect(scoring, swapper,
                            heartbeat=lambda: srv.heartbeat())

    def tier_of(patient):
        return "critical" if patient % 3 == 0 else "stable"

    tracer = SpanRecorder()
    srv = EnsembleServer(
        batch_handler=lambda ws, tier=None: handler(ws),
        n_workers=2, slo_seconds=slo, max_queue=max_queue,
        max_batch=8, max_wait_ms=2.0, telemetry=telemetry,
        tier_of=tier_of, tier_priority={"critical": 2, "stable": 0},
        deadline_seconds=deadline, tracer=tracer).start()

    ctl = wire_controller(telemetry, swapper, member_costs=member_costs,
                          period_seconds=0.2) if use_controller else None

    # logical ingest time: 1.0 "second" per window round (input_len
    # samples at input_len Hz), decoupled from window_wall_s wall pacing
    # vitals ride along so ring backpressure reflects the TIGHTEST
    # modality, not just ecg: headroom(p) aggregates min across rings
    # in window units (< 1.0 = can't absorb one more window)
    vitals_hz, vitals_ch = 5.0, 6
    di = DeviceIngest([ModalitySpec("ecg", float(input_len), ECG_LEADS),
                       ModalitySpec("vitals", vitals_hz, vitals_ch)],
                      n_patients, window_seconds=1.0,
                      capacity_windows=4.0)
    di.warm_gather(sorted({s.input_len for s in specs}))

    # arm LAST: the schedule clock starts when traffic starts, not while
    # warmup is still compiling (at 8 forced devices warm-up alone can
    # outlast the first scheduled fault, which would make every query in
    # the run land on an already-lost device)
    plane.arm(swapper)

    qid = 0
    oracle_windows: Dict[int, np.ndarray] = {}
    submitted = 0
    ring_rejected = 0
    fault_recovery: Dict[int, Optional[float]] = {
        i: None for i in range(len(schedule))}

    def check_recoveries():
        t_now = plane.now()
        for i, ev in enumerate(schedule):
            if fault_recovery[i] is not None:
                continue
            end = ev.t + ev.duration
            if t_now <= end + 0.05:
                continue
            snap = telemetry.snapshot(
                since=plane._armed_at + end + deadline)
            # recovered = REAL scores flowing again under the SLO;
            # NaN-failed retires also hit record_served, so subtract
            # them — a watchdog NaN storm must not count as recovery
            if snap.n_served - snap.n_failed >= 2 and snap.p99 <= slo:
                fault_recovery[i] = t_now - end

    zero_win = np.zeros((ECG_LEADS, input_len), np.float32)

    def submit_ref(p, ref):
        """Snapshot the ref's window AT SUBMIT TIME (the ring moves on;
        the oracle must see what a timely flush would have gathered).
        A ref closed with no fresh samples (the flood path) gathers the
        zero-filled dropout window — no device round-trip needed, which
        keeps the flood fast enough to actually overrun the queue."""
        nonlocal submitted
        qid_ = ref.extra["qid"]
        if all(v == 0 for v in ref.valid.values()):
            oracle_windows[qid_] = zero_win
        else:
            oracle_windows[qid_] = ref.host_window("ecg")
        submitted += 1
        srv.submit(p, ref)

    def maybe_flood():
        """During a backpressure episode, overrun the bounded queue with
        stable-tier queries: the priority-aware ShedQueue must shed
        these, never a critical.  (Re-closing an unchanged ring yields
        the valid=0 all-zeros dropout window — a legitimate degenerate
        query the oracle rescores like any other.)"""
        nonlocal qid
        if not plane.backpressure_active():
            return
        flood = [p for p in range(n_patients) if p % 3 != 0]
        for _ in range(max(2, (2 * max_queue) // max(1, len(flood)))):
            for p in flood:
                ref = di.close_window(p, t_logical + 1.0,
                                      extra={"qid": qid})
                qid += 1
                submit_ref(p, ref)

    t_logical = 0.0
    chunks = (100, 75, 75)
    for _round in range(windows_per_patient):
        for p in range(n_patients):
            if di.headroom(p) < 1.0:
                # ring backpressure: feeding would push outstanding
                # windows past the staleness guard in SOME modality —
                # reject up front (aggregate min, window units)
                ring_rejected += 1
                continue
            sig = rng.standard_normal(
                (ECG_LEADS, input_len)).astype(np.float32)
            off = 0
            for k in chunks:
                di.ingest(t_logical + off / input_len, p, "ecg",
                          sig[:, off:off + k])
                off += k
            di.ingest(t_logical, p, "vitals", rng.standard_normal(
                (vitals_ch, int(vitals_hz))).astype(np.float32))
            ref = di.close_window(p, t_logical + 1.0,
                                  extra={"qid": qid})
            qid += 1
            submit_ref(p, ref)
        maybe_flood()
        t_logical += 1.0
        check_recoveries()
        time.sleep(window_wall_s)

    # keep a light pulse flowing until the schedule has fully fired and
    # every fault has had its recovery window measured
    t_wait = time.monotonic() + recovery_slo_s + 2.0
    while (not plane.done()
           or any(v is None for v in fault_recovery.values())) \
            and time.monotonic() < t_wait:
        for p in range(min(2, n_patients)):
            if srv.q.qsize() >= max(2, max_queue // 2):
                break       # polite pulse: recovery measurement traffic
                #             must not re-trigger backpressure shedding
            if di.headroom(p) < 1.0:
                ring_rejected += 1
                continue
            sig = rng.standard_normal(
                (ECG_LEADS, input_len)).astype(np.float32)
            di.ingest(t_logical, p, "ecg", sig)
            di.ingest(t_logical, p, "vitals", rng.standard_normal(
                (vitals_ch, int(vitals_hz))).astype(np.float32))
            ref = di.close_window(p, t_logical + 1.0,
                                  extra={"qid": qid})
            qid += 1
            submit_ref(p, ref)
        maybe_flood()      # a late-scheduled backpressure episode must
        #                    still be exercised after the main trace
        t_logical += 1.0
        check_recoveries()
        time.sleep(window_wall_s)

    srv.drain(timeout=30.0)
    check_recoveries()
    stats = srv.stop()
    ctl_ok = ctl.stop() if ctl is not None else True
    leaked = sorted({t.name for t in threading.enumerate()
                     if t.is_alive() and t.name.startswith("repro-")})

    # ---------------------------------------------------- invariants
    results = []
    while True:
        batch = srv.results()
        if not batch:
            break
        results.extend(batch)
    n_real = sum(1 for _, s, _, _ in results if s == s)
    n_nan = sum(1 for _, s, _, _ in results if s != s)
    conservation_ok = (stats.served + stats.shed == submitted
                       and len(results) == stats.served
                       and n_real + n_nan == stats.served
                       and n_nan == stats.failed)

    # fault-free oracle: rescore each logged flush (same windows, same
    # member selection, unsharded, no faults) and demand bitwise
    # equality for every query that DELIVERED a real score
    qid_flush: Dict[int, tuple] = {}
    with log_lock:
        for key, qids, scores in flush_log:
            for q, s in zip(qids, scores):
                qid_flush[q] = (key, qids, s)
    oracle_cache: Dict[tuple, EnsembleService] = {}
    oracle_scores: Dict[tuple, Dict[int, float]] = {}
    bitwise_ok = True
    n_checked = 0
    for patient, score, _lat, ref in results:
        if score != score:
            continue                      # NaN-failed: conservation's job
        q = ref.extra["qid"]
        ent = qid_flush.get(q)
        if ent is None:
            bitwise_ok = False
            break
        key, qids, logged = ent
        flush_id = (key, tuple(qids))
        if flush_id not in oracle_scores:
            svc = oracle_cache.get(key)
            if svc is None:
                svc = EnsembleService([pool[i] for i in key])
                oracle_cache[key] = svc
            want = svc.predict_batch(
                [{"ecg": oracle_windows[x]} for x in qids])
            oracle_scores[flush_id] = dict(zip(qids, want))
        ok = (score == logged == oracle_scores[flush_id][q])
        bitwise_ok = bitwise_ok and ok
        n_checked += 1
        if not ok:
            break

    recovery_s = [fault_recovery[i] for i in range(len(schedule))]
    recovery_ok = all(r is not None and r <= recovery_slo_s
                      for r in recovery_s)
    no_leaked = (not leaked) and (not srv.leaked) and ctl_ok

    out = {
        "n_devices": n_devices, "n_patients": n_patients,
        "windows_per_patient": windows_per_patient, "seed": seed,
        "slo_s": slo, "deadline_s": deadline,
        "schedule": [ev.to_dict() for ev in schedule],
        "submitted": submitted, "ring_rejected": ring_rejected,
        "served": stats.served, "served_real": n_real,
        "failed": stats.failed, "rejected": stats.shed,
        "rejected_by_tier": {str(k): v
                             for k, v in stats.rejected.items()},
        "critical_rejected": stats.rejected.get("critical", 0),
        "stalls": stats.stalls,
        "quarantined": [str(d) for d in swapper.quarantined],
        "recoveries": plane.recoveries,
        "controller": {
            "enabled": use_controller,
            "actions": [[round(t, 3), d.name] for t, d in ctl.log]
            if ctl is not None else [],
            "n_recomposes": ctl.n_recomposes if ctl is not None else 0},
        "faults": [{**ev.to_dict(),
                    "recovery_s": recovery_s[i]}
                   for i, ev in enumerate(schedule)],
        "p50_ms": stats.p(50) * 1e3, "p99_ms": stats.p(99) * 1e3,
        "conservation_ok": bool(conservation_ok),
        "bitwise_ok": bool(bitwise_ok), "n_bitwise_checked": n_checked,
        "recovery_ok": bool(recovery_ok),
        "no_leaked_threads": bool(no_leaked),
        "leaked_threads": leaked + list(srv.leaked)
        + (list(ctl.leaked) if ctl is not None else []),
    }
    # span-trace digest (optional key — not part of the gated schema):
    # under chaos the by_status mix is the interesting bit, e.g. the
    # watchdog-killed co-batch shows up as status="watchdog" spans
    att = tracer.attribution()
    out["obs"] = {
        "n_spans": att["n_spans"], "by_status": att["by_status"],
        "coverage": round(att["coverage"], 4),
        "stage_ms": {k: round(1e3 * v / max(att["n_spans"], 1), 3)
                     for k, v in att["stage_seconds"].items()},
    }
    if verbose:
        print(f"\nchaos soak ({n_devices} device(s), "
              f"{n_patients} patients x {windows_per_patient} windows):")
        print(f"  submitted {submitted}  real {n_real}  failed "
              f"{stats.failed}  rejected {stats.shed} "
              f"(ring {ring_rejected})  stalls {stats.stalls}  "
              f"quarantined {out['quarantined']}")
        print(f"  conservation {conservation_ok}  bitwise {bitwise_ok} "
              f"({n_checked} checked)  recovery {recovery_ok} "
              f"{[None if r is None else round(r, 2) for r in recovery_s]}"
              f"  no_leaked_threads {no_leaked}")
    return out


# ------------------------------------------------- slot-engine lane
SLOT_LANE_KEYS = (
    "n_devices", "seed", "slo_s", "slot_wait_s", "ticker_deadline_s",
    "schedule", "trace", "n_slots_initial", "n_slots_final",
    "spad_final", "submitted", "ring_rejected", "served", "served_real",
    "failed", "rejected", "ticks", "tick_skips", "tick_faults",
    "tick_aborts", "rebinds", "ticker_respawns", "watchdog_events",
    "grows", "admits", "discharges", "stale_ticks", "quarantined",
    "recoveries", "controller", "faults", "p50_ms", "p99_ms",
    "conservation_ok", "bitwise_ok", "n_bitwise_checked",
    "recovery_ok", "no_leaked_threads", "leaked_threads",
)
SLOT_FAULT_KINDS_REQUIRED = ("device_loss", "ticker_stall",
                             "backpressure")


def run_slot_chaos(n_beds: int = 5, n_steps: int = 240,
                   step_wall_s: float = 0.05,
                   step_logical_s: float = 120.0,
                   input_len: int = 250, n_devices: int = 1,
                   seed: int = 0, slo: float = 2.0,
                   slot_wait: float = 0.5,
                   ticker_deadline: float = 0.35,
                   tick_interval: float = 0.02,
                   max_queue: int = 32,
                   lam_admit: float = 0.05,
                   los_median_steps: float = 60.0,
                   recovery_slo_s: Optional[float] = None,
                   schedule=None, verbose: bool = True) -> Dict:
    """One slot-engine soak lane (see module doc).  The driver clock is
    COMPRESSED: each step is ``step_logical_s`` of ICU time but only
    ``step_wall_s`` of wall clock, so a default full run replays
    ``n_steps * step_logical_s / 3600`` logical hours of cohort churn
    in under a minute.  Returns the result dict (SLOT_LANE_KEYS)."""
    import jax

    if recovery_slo_s is None:
        # recovery here is queue-drain bound: queries queued during an
        # outage each burn up to ``slot_wait`` before NaN-retiring, and
        # a permanent loss additionally restages + rebinds (the moved
        # buckets recompile) before fresh ticks can stamp real scores
        recovery_slo_s = 45.0 if n_devices >= 2 else 15.0

    from repro.configs.ecg_zoo import ECG_LEADS, zoo_specs
    from repro.control.faults import (FaultPlane, slot_compound_schedule,
                                      wire_controller)
    from repro.control.swap import HotSwapper
    from repro.control.telemetry import SloTelemetry
    from repro.models.ecg_resnext import init_ecg
    from repro.obs.spans import SpanRecorder
    from repro.serving.aggregator import DeviceIngest, ModalitySpec
    from repro.serving.pipeline import EnsembleService, ZooMember
    from repro.serving.server import EnsembleServer
    from repro.serving.slots import SlotEngine, TickLadder

    n_devices = min(n_devices, jax.device_count())
    rng = np.random.default_rng(seed)
    specs = zoo_specs(reduced=True, input_len=input_len)
    pool = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
            for i, s in enumerate(specs)]
    rich = np.ones(len(pool), np.int8)

    swapper = HotSwapper(pool, rich, n_devices=n_devices,
                         warmup_batch_sizes=(8,))
    # single-rung MEMBER ladder: a controller shed falls through to the
    # aux TickLadder (freshness degrades before accuracy) and a
    # failover restage keeps the composition rebind-compatible
    swapper.set_ladder([rich])
    telemetry = SloTelemetry(slo_seconds=slo, window_seconds=3.0)

    di = DeviceIngest([ModalitySpec("ecg", float(input_len), ECG_LEADS)],
                      n_beds, window_seconds=1.0, capacity_windows=4.0)
    eng = SlotEngine(swapper.facade.current, di)
    # respawned ticker generations skip a held tick lock FAST, so they
    # beat well inside the watchdog deadline during a long failover
    # (no respawn pile-up behind a recovering tick)
    eng.tick_lock_timeout = 0.2
    n_slots_initial = eng.n_slots

    tracer = SpanRecorder()
    srv = EnsembleServer(engine="slots", slot_engine=eng, n_workers=4,
                         slo_seconds=slo, max_queue=max_queue,
                         tick_interval=tick_interval,
                         slot_wait_timeout=slot_wait,
                         ticker_deadline_seconds=ticker_deadline,
                         telemetry=telemetry, tracer=tracer)
    ladder = TickLadder(srv.ticker,
                        intervals=(4 * tick_interval, 2 * tick_interval,
                                   tick_interval))
    ctl = wire_controller(telemetry, swapper, aux_ladder=ladder,
                          period_seconds=0.2)

    schedule = schedule if schedule is not None \
        else slot_compound_schedule(n_devices, seed=seed)
    plane = FaultPlane(schedule, seed=seed)

    # the tick-report oracle log: every (slot, close-version, pad-rung)
    # a tick ever STAMPED, with its combined score.  The same key must
    # score identically every time it is stamped (same window, same
    # members — placement is bitwise-irrelevant even across a rebind).
    rec: Dict[tuple, float] = {}
    rec_lock = threading.Lock()
    restamp_consistent = [True]

    def on_tick(r):
        if r.stamped is None or not len(r.stamped):
            return
        with rec_lock:
            for s, v, sc in zip(r.stamped, r.versions, r.scores):
                key = (int(s), int(v), int(r.spad))
                prev = rec.get(key)
                if prev is None:
                    rec[key] = float(sc)
                elif prev != float(sc):
                    restamp_consistent[0] = False

    eng.on_tick = on_tick

    eng.warm()
    # pre-warm the NEXT pad rung too: the census provably outgrows its
    # initial slots mid-soak, and the bucket recompile at the grown
    # rung should not masquerade as fault-recovery latency
    swapper.facade.current.warmup(
        batch_sizes=(2 * eng._Spad,))
    srv.start()
    # arm AFTER warmup (schedule clock starts with traffic), then wire
    # tick-path recovery: ticker-stall injection, device-loss
    # quarantine + TickLadder shed + rebind, flush-quarantine rebinds
    plane.arm(swapper)
    plane.protect_engine(eng, swapper, ticker=srv.ticker,
                         tick_ladder=ladder)

    # ------------------------------------------------ cohort trace
    beds: Dict[int, Dict] = {}          # slot -> bed state
    row_t: Dict[int, float] = {}        # slot -> ring close clock (kept
    #                                     across occupants: ring time is
    #                                     monotonic per ROW, not per bed)
    verc: Dict[int, int] = {}           # slot -> close version counter
    snaps: Dict[tuple, np.ndarray] = {}  # (slot, version) -> ecg window
    zero_win = np.zeros((ECG_LEADS, input_len), np.float32)
    qid = 0
    submitted = 0
    ring_rejected = 0
    n_admissions = 0

    def admit_bed(step: int) -> None:
        nonlocal n_admissions
        slot = eng.acquire_slot()       # lowest free, grows the census
        esc = bool(rng.random() < 0.25)
        los = max(3, int(rng.lognormal(np.log(los_median_steps), 0.5)))
        beds[slot] = {"esc": esc, "period": 1 if esc else 4,
                      "next": step + 1, "until": step + los}
        n_admissions += 1

    def close_and_submit(slot: int, fresh: bool = True) -> None:
        """One closed observation window -> one slot query.  The window
        is snapshotted AT CLOSE keyed by (slot, close version) — what a
        timely tick gathers — for the tick-report oracle.  ``fresh=
        False`` (the flood path) re-closes an unchanged ring: valid=0,
        the gather yields the all-zeros dropout window."""
        nonlocal qid, submitted
        t_row = row_t.get(slot, 0.0)
        if fresh:
            sig = rng.standard_normal(
                (ECG_LEADS, input_len)).astype(np.float32)
            di.ingest(t_row, slot, "ecg", sig)
            t_row += 1.0
            row_t[slot] = t_row
        ref = di.close_window(slot, t_row, extra={"qid": qid})
        qid += 1
        v = verc.get(slot, 0) + 1       # mirrors SlotEngine's close
        verc[slot] = v                  # version (one update per close)
        if all(x == 0 for x in ref.valid.values()):
            snaps[(slot, v)] = zero_win
        else:
            snaps[(slot, v)] = ref.host_window("ecg")
        submitted += 1
        srv.submit(slot, ref)

    def maybe_flood() -> None:
        """During a backpressure episode, overrun the bounded queue
        with re-closes of unchanged rings (cheap degenerate queries the
        oracle rescores like any other) — the ShedQueue must shed."""
        targets = [s for s in beds if s in row_t]
        if not plane.backpressure_active() or not targets:
            return
        # one invocation must overrun the queue BY ITSELF: the episode
        # can overlap as little as one driver step when a compile pause
        # stretches the step it lands on
        for _ in range(max(2, (2 * max_queue + 8) // len(targets))):
            for s in targets:
                close_and_submit(s, fresh=False)

    fault_recovery: Dict[int, Optional[float]] = {
        i: None for i in range(len(schedule))}

    def check_recoveries() -> None:
        t_now = plane.now()
        for i, ev in enumerate(schedule):
            if fault_recovery[i] is not None:
                continue
            end = ev.t + ev.duration
            if t_now <= end + 0.05:
                continue
            snap = telemetry.snapshot(
                since=plane._armed_at + end + slot_wait)
            if snap.n_served - snap.n_failed >= 2 and snap.p99 <= slo:
                fault_recovery[i] = t_now - end

    for _ in range(n_beds):
        admit_bed(0)

    for step in range(n_steps):
        for slot in [s for s, b in beds.items() if b["until"] <= step]:
            eng.discharge(slot)
            del beds[slot]
        # Poisson arrivals, plus a deterministic two-bed escalation
        # wing early on so the census provably outgrows n_slots on
        # every seed
        n_new = int(rng.poisson(lam_admit)) + (2 if step == 5 else 0)
        for _ in range(n_new):
            admit_bed(step)
        for slot, b in list(beds.items()):
            if step >= b["next"]:
                if di.headroom(slot) < 1.0:
                    ring_rejected += 1
                else:
                    close_and_submit(slot)
                b["next"] = step + b["period"]
        maybe_flood()
        check_recoveries()
        time.sleep(step_wall_s)

    # keep a light pulse flowing until the schedule has fully fired
    # and every fault's recovery window is measured
    t_wait = time.monotonic() + recovery_slo_s + 2.0
    while (not plane.done()
           or any(v is None for v in fault_recovery.values())) \
            and time.monotonic() < t_wait:
        if not beds:
            admit_bed(n_steps)
        for slot in list(beds)[:2]:
            if srv.q.qsize() >= max(2, max_queue // 2):
                break       # polite pulse: must not re-trigger shedding
            if di.headroom(slot) < 1.0:
                ring_rejected += 1
                continue
            close_and_submit(slot)
        maybe_flood()
        check_recoveries()
        time.sleep(step_wall_s)

    srv.drain(timeout=30.0)
    check_recoveries()
    stats = srv.stop()
    ctl_ok = ctl.stop()
    leaked = sorted({t.name for t in threading.enumerate()
                     if t.is_alive() and t.name.startswith("repro-")})

    # ---------------------------------------------------- invariants
    results = []
    while True:
        batch = srv.results()
        if not batch:
            break
        results.extend(batch)
    n_real = sum(1 for _, s, _, _ in results if s == s)
    n_nan = sum(1 for _, s, _, _ in results if s != s)
    conservation_ok = (stats.served + stats.shed == submitted
                       and len(results) == stats.served
                       and n_real + n_nan == stats.served
                       and n_nan == stats.failed)

    # tick-report oracle: re-score every stamped (slot, version) with
    # an UNSHARDED fault-free service in batches of exactly the pad
    # rung the tick dispatched at (bucket rows are independent, so
    # zero-window pad rows cannot perturb the real rows)
    oracle = EnsembleService(pool)
    bitwise_ok = restamp_consistent[0]
    n_checked = 0
    with rec_lock:
        entries = sorted(rec.items())
    by_spad: Dict[int, List] = {}
    for (s, v, spad), sc in entries:
        by_spad.setdefault(spad, []).append((s, v, sc))
    for spad, ents in sorted(by_spad.items()):
        for i in range(0, len(ents), spad):
            chunk = ents[i:i + spad]
            wins = []
            for s, v, _sc in chunk:
                w = snaps.get((s, v))
                if w is None:           # stamped a version the driver
                    bitwise_ok = False  # never closed: impossible
                    w = zero_win
                wins.append(w)
            while len(wins) < spad:
                wins.append(zero_win)
            want = oracle.predict_batch([{"ecg": w} for w in wins])
            for (s, v, sc), wsc in zip(chunk, want):
                bitwise_ok = bitwise_ok and (sc == wsc)
                n_checked += 1

    # ...and every REAL score a query served must be one of its slot's
    # stamped scores (reads come from the mirror, the mirror only ever
    # holds stamped ticks — NaN-or-stale during gaps, never invented)
    slot_scores: Dict[int, set] = {}
    for (s, _v, _spad), sc in entries:
        slot_scores.setdefault(s, set()).add(sc)
    for patient, score, _lat, _ref in results:
        if score == score and score not in slot_scores.get(patient, ()):
            bitwise_ok = False

    recovery_s = [fault_recovery[i] for i in range(len(schedule))]
    recovery_ok = all(r is not None and r <= recovery_slo_s
                      for r in recovery_s)
    no_leaked = (not leaked) and (not srv.leaked) and ctl_ok

    out = {
        "n_devices": n_devices, "seed": seed, "slo_s": slo,
        "slot_wait_s": slot_wait, "ticker_deadline_s": ticker_deadline,
        "schedule": [ev.to_dict() for ev in schedule],
        "trace": {
            "n_beds": n_beds, "n_steps": n_steps,
            "step_wall_s": step_wall_s,
            "step_logical_s": step_logical_s,
            "sim_hours": round(n_steps * step_logical_s / 3600.0, 2),
            "compression": round(step_logical_s / step_wall_s, 1),
            "lam_admit": lam_admit,
            "los_median_steps": los_median_steps,
            "admissions": n_admissions},
        "n_slots_initial": n_slots_initial,
        "n_slots_final": eng.n_slots, "spad_final": eng._Spad,
        "submitted": submitted, "ring_rejected": ring_rejected,
        "served": stats.served, "served_real": n_real,
        "failed": stats.failed, "rejected": stats.shed,
        "ticks": eng.tick_count, "tick_skips": eng.n_tick_skips,
        "tick_faults": eng.n_tick_faults,
        "tick_aborts": eng.n_tick_aborts, "rebinds": eng.n_rebinds,
        "ticker_respawns": srv.ticker.n_respawns,
        "watchdog_events": list(srv.ticker_watchdog.events),
        "grows": eng.n_grows, "admits": eng.n_admits,
        "discharges": eng.n_discharges,
        "stale_ticks": eng.n_stale_total,
        "quarantined": [str(d) for d in swapper.quarantined],
        "recoveries": plane.recoveries,
        "controller": {
            "actions": [[round(t, 3), d.name] for t, d in ctl.log],
            "n_recomposes": ctl.n_recomposes},
        "faults": [{**ev.to_dict(), "recovery_s": recovery_s[i]}
                   for i, ev in enumerate(schedule)],
        "p50_ms": stats.p(50) * 1e3, "p99_ms": stats.p(99) * 1e3,
        "conservation_ok": bool(conservation_ok),
        "bitwise_ok": bool(bitwise_ok), "n_bitwise_checked": n_checked,
        "recovery_ok": bool(recovery_ok),
        "no_leaked_threads": bool(no_leaked),
        "leaked_threads": leaked + list(srv.leaked)
        + list(ctl.leaked),
    }
    att = tracer.attribution()
    out["obs"] = {"n_spans": att["n_spans"],
                  "by_status": att["by_status"],
                  "coverage": round(att["coverage"], 4)}
    if verbose:
        print(f"\nslot chaos soak ({n_devices} device(s), "
              f"{out['trace']['sim_hours']}h logical / "
              f"{n_steps * step_wall_s:.0f}s wall):")
        print(f"  submitted {submitted}  real {n_real}  failed "
              f"{stats.failed}  rejected {stats.shed}  slots "
              f"{n_slots_initial}->{eng.n_slots}  ticks "
              f"{eng.tick_count} (faults {eng.n_tick_faults} aborts "
              f"{eng.n_tick_aborts})  respawns "
              f"{srv.ticker.n_respawns}  rebinds {eng.n_rebinds}  "
              f"quarantined {out['quarantined']}")
        print(f"  conservation {conservation_ok}  bitwise {bitwise_ok} "
              f"({n_checked} checked)  recovery {recovery_ok} "
              f"{[None if r is None else round(r, 2) for r in recovery_s]}"
              f"  no_leaked_threads {no_leaked}")
    return out


# ------------------------------------------------------------- schema
def check_chaos_schema(lane: Dict) -> None:
    """Gate one lane's result: every tracked key present, all four
    whole-run invariants TRUE, and the schedule actually contained at
    least one fault of each required kind."""
    for k in CHAOS_LANE_KEYS:
        assert k in lane, f"missing chaos lane key: {k}"
    kinds = {ev["kind"] for ev in lane["schedule"]}
    for k in FAULT_KINDS_REQUIRED:
        assert k in kinds, f"schedule missing fault kind {k}"
    for inv in ("conservation_ok", "bitwise_ok", "recovery_ok",
                "no_leaked_threads"):
        assert lane[inv] is True, f"invariant failed: {inv} ({lane})"
    assert lane["n_bitwise_checked"] > 0, "oracle checked nothing"
    assert lane["stalls"] >= 1, "worker stall never detected"
    assert lane["rejected"] >= 1, "backpressure never shed anything"
    assert lane["critical_rejected"] == 0, \
        "a critical query was rejected"


def check_slot_lane_schema(lane: Dict) -> None:
    """Gate one slot-engine lane: every tracked key, all four
    invariants, the compound fault kinds actually scheduled, and the
    chaos machinery provably EXERCISED (watchdog respawned, ticks
    faulted, census grew past its initial slots, queue shed)."""
    for k in SLOT_LANE_KEYS:
        assert k in lane, f"missing slot lane key: {k}"
    kinds = {ev["kind"] for ev in lane["schedule"]}
    for k in SLOT_FAULT_KINDS_REQUIRED:
        assert k in kinds, f"slot schedule missing fault kind {k}"
    for inv in ("conservation_ok", "bitwise_ok", "recovery_ok",
                "no_leaked_threads"):
        assert lane[inv] is True, f"slot invariant failed: {inv} ({lane})"
    assert lane["n_bitwise_checked"] > 0, "slot oracle checked nothing"
    assert lane["ticker_respawns"] >= 1, \
        "ticker watchdog never respawned through the stall cascade"
    assert lane["tick_faults"] >= 1, \
        "no tick ever hit an injected device loss"
    assert lane["grows"] >= 1 \
        and lane["n_slots_final"] > lane["n_slots_initial"], \
        "census never outgrew the initial slot count"
    assert lane["rejected"] >= 1, "backpressure never shed anything"


def check_chaos_file(path: str = BENCH_JSON) -> None:
    """CI gate on the committed BENCH_chaos.json: flush lanes AND slot
    lanes present and individually valid."""
    with open(path) as f:
        data = json.load(f)
    for lane_name in ("single_device", "forced_8_device"):
        assert lane_name in data, f"missing lane {lane_name}"
        check_chaos_schema(data[lane_name])
    assert data["forced_8_device"]["n_devices"] >= 2
    assert data["forced_8_device"]["quarantined"], \
        "multi-device lane never quarantined the lost device"
    assert "slots" in data, "missing slot-engine lanes"
    for lane_name in ("single_device", "forced_8_device"):
        assert lane_name in data["slots"], \
            f"missing slot lane {lane_name}"
        check_slot_lane_schema(data["slots"][lane_name])
    s8 = data["slots"]["forced_8_device"]
    assert s8["n_devices"] >= 2, "slot lane ran single-device"
    assert s8["quarantined"], \
        "slot lane never quarantined the lost device"
    assert s8["rebinds"] >= 1, \
        "slot engine never rebound onto the survivor facade"
    print(f"chaos schema OK ({path})")


# ----------------------------------------------------- lane dispatch
def _subprocess_lane(n_patients: int, windows: int,
                     seed: int = 0, lane: str = "flush") -> Dict:
    """Run a forced-8-device lane in a subprocess (XLA device count is
    fixed at jax init, so the multi-device lanes need their own
    process).  ``lane`` picks the flush soak or the slot-engine soak;
    for the slot lane ``n_patients``/``windows`` mean initial beds /
    driver steps."""
    import tempfile
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={N_FORCED}")
    env.pop("PYTEST_CURRENT_TEST", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out_path = f.name
    try:
        r = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--emit",
             out_path, "--lane", lane, "--devices", str(N_FORCED),
             "--n-patients", str(n_patients),
             "--windows", str(windows), "--seed", str(seed)],
            cwd=repo, env=env, capture_output=True, text=True,
            timeout=1200)
        if r.returncode != 0:
            raise RuntimeError("forced-8-device lane failed:\n"
                               + (r.stdout or "")[-2000:]
                               + (r.stderr or "")[-4000:])
        with open(out_path) as f:
            return json.load(f)
    finally:
        os.unlink(out_path)


def _merge_bench_json(updates: Dict) -> None:
    merged = {}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            merged = json.load(f)
    merged.update(updates)
    with open(BENCH_JSON, "w") as f:
        json.dump(merged, f, indent=2)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-trace CI invocation: both lanes, schema "
                         "gates, writes nothing")
    ap.add_argument("--emit", default=None,
                    help="run ONE lane in this process and write its "
                         "result dict to this path (subprocess entry)")
    ap.add_argument("--lane", choices=("flush", "slots"),
                    default="flush",
                    help="which soak --emit runs (flush path or the "
                         "continuous slot engine)")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--n-patients", type=int, default=None)
    ap.add_argument("--windows", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    SLOT_SMOKE_STEPS = 150
    SLOT_FULL_STEPS = 900

    if args.emit:
        if args.lane == "slots":
            out = run_slot_chaos(n_beds=args.n_patients or 5,
                                 n_steps=args.windows or SLOT_FULL_STEPS,
                                 n_devices=args.devices, seed=args.seed)
            check_slot_lane_schema(out)
        else:
            out = run_chaos(n_patients=args.n_patients or 6,
                            windows_per_patient=args.windows or 10,
                            n_devices=args.devices, seed=args.seed)
            check_chaos_schema(out)
        with open(args.emit, "w") as f:
            json.dump(out, f, indent=2)
    elif args.smoke:
        lane1 = run_chaos(n_patients=args.n_patients or 4,
                          windows_per_patient=args.windows or 8,
                          n_devices=1, seed=args.seed)
        check_chaos_schema(lane1)
        lane8 = _subprocess_lane(args.n_patients or 4,
                                 args.windows or 8, seed=args.seed)
        check_chaos_schema(lane8)
        assert lane8["n_devices"] >= 2 and lane8["quarantined"]
        slane1 = run_slot_chaos(n_steps=SLOT_SMOKE_STEPS,
                                n_devices=1, seed=args.seed)
        check_slot_lane_schema(slane1)
        slane8 = _subprocess_lane(5, SLOT_SMOKE_STEPS, seed=args.seed,
                                  lane="slots")
        check_slot_lane_schema(slane8)
        assert slane8["n_devices"] >= 2 and slane8["quarantined"] \
            and slane8["rebinds"] >= 1
        print("chaos smoke OK (flush + slot lanes, single-device + "
              "forced-8-device)")
    else:
        lane1 = run_chaos(n_patients=args.n_patients or 6,
                          windows_per_patient=args.windows or 10,
                          n_devices=1, seed=args.seed)
        check_chaos_schema(lane1)
        lane8 = _subprocess_lane(args.n_patients or 6,
                                 args.windows or 10, seed=args.seed)
        check_chaos_schema(lane8)
        slane1 = run_slot_chaos(n_steps=SLOT_FULL_STEPS, n_devices=1,
                                seed=args.seed)
        check_slot_lane_schema(slane1)
        slane8 = _subprocess_lane(5, SLOT_FULL_STEPS, seed=args.seed,
                                  lane="slots")
        check_slot_lane_schema(slane8)
        # the committed, replayable fault traces the soaks survived
        # (FaultPlane.to_json / from_json round-trips these)
        from repro.control.faults import (FaultPlane,
                                          slot_compound_schedule)
        tdir = os.path.join(os.path.dirname(__file__), "traces")
        os.makedirs(tdir, exist_ok=True)
        for nd, fname in ((1, "slot_compound_1dev.json"),
                          (N_FORCED, "slot_compound_8dev.json")):
            FaultPlane(slot_compound_schedule(nd, seed=args.seed),
                       seed=args.seed).to_json(
                os.path.join(tdir, fname))
        _merge_bench_json({"single_device": lane1,
                           "forced_8_device": lane8,
                           "slots": {"single_device": slane1,
                                     "forced_8_device": slane8}})
        check_chaos_file()
