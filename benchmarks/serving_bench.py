"""Fig. 9 / Fig. 10 / Fig. 13: serving-system benchmarks on the DES
(deterministic stand-in for the paper's HTTP/RPC testbed) plus real
wall-clock jitted-inference costs measured on this machine, the
fused-serving before/after microbench (``bench_fused_serving``), and
the multi-device placement sweep (``bench_placement_sweep``) — both
tracked in ``BENCH_serving.json``.

The placement sweep needs forced host devices; run it standalone as
``python benchmarks/serving_bench.py`` (which sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
initialises) or under the CI multi-device lane's env.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serving.json")

from repro.serving.latency import LatencyProfiler, queueing_bound
from repro.serving.simulator import SimConfig, simulate


def _merge_bench_json(updates: Dict) -> None:
    """Update BENCH_serving.json in place: each bench owns its keys and
    must not clobber the others' tracked trajectories."""
    merged = {}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            merged = json.load(f)
    merged.update(updates)
    with open(BENCH_JSON, "w") as f:
        json.dump(merged, f, indent=2)


def bench_fig9(model_cost: float = 0.02, batch_period: float = 3600.0,
               verbose=True) -> Dict:
    """Online (every 30 s) vs offline hourly batch, single patient."""
    dur = 2 * batch_period
    online = simulate([model_cost],
                      SimConfig(n_patients=1, n_devices=2,
                                duration_seconds=dur, window_seconds=30))
    offline = simulate([model_cost],
                       SimConfig(n_patients=1, n_devices=2,
                                 duration_seconds=dur, window_seconds=30,
                                 batch_period=batch_period))
    # inference-only latency (excludes staleness): queue wait + service
    inf_online = online.p(95)
    inf_offline = float(np.percentile(
        [q.t_done - q.t_start for q in offline.queries], 95)) \
        + 0.0  # service-side only
    staleness = float(np.mean(
        [q.t_start - q.t_window for q in offline.queries]))
    out = {"online_p95_s": inf_online,
           "offline_batch_p95_s": offline.p(95),
           "offline_inference_only_p95_s": inf_offline,
           "offline_mean_staleness_s": staleness,
           "staleness_ratio": offline.p(95) / max(inf_online, 1e-9)}
    if verbose:
        print(f"\nFig 9: online p95 {inf_online * 1000:.1f}ms vs "
              f"offline-batch p95 {offline.p(95):.0f}s "
              f"(mean staleness {staleness:.0f}s, "
              f"{out['staleness_ratio']:.0f}x)")
    return out


def bench_fig10(costs: List[float] = (0.01, 0.02, 0.015),
                patients=(8, 16, 32, 64, 100, 128),
                devices=(1, 2, 4, 8), verbose=True) -> Dict:
    left = {}
    for n in patients:
        r = simulate(list(costs), SimConfig(
            n_patients=n, n_devices=2, duration_seconds=120,
            window_seconds=30, seed=2))
        left[n] = {"p95_s": r.p(95), "p50_s": r.p(50),
                   "utilization": r.utilization,
                   "ingest_qps": n * 250}
    right = {}
    for d in devices:
        r = simulate(list(costs), SimConfig(
            n_patients=64, n_devices=d, duration_seconds=120,
            window_seconds=30, seed=2))
        right[d] = {"p95_s": r.p(95), "utilization": r.utilization}
    if verbose:
        print("\nFig 10 (left): latency vs #patients @2 devices")
        for n, v in left.items():
            print(f"  {n:4d} patients ({v['ingest_qps']:6d} qps ingest): "
                  f"p95 {v['p95_s'] * 1000:7.1f}ms "
                  f"util {v['utilization']:.2f}")
        print("Fig 10 (right): latency vs #devices @64 patients")
        for d, v in right.items():
            print(f"  {d} devices: p95 {v['p95_s'] * 1000:7.1f}ms")
    return {"vs_patients": left, "vs_devices": right}


def bench_fig13(windows=(5, 10, 30, 60), model_cost_per_s: float = 7e-4,
                verbose=True) -> Dict:
    """Larger observation window => more samples per query => larger
    T_s, and fewer-but-burstier queries => T_q effect (A.4)."""
    out = {}
    for w in windows:
        cost = model_cost_per_s * w          # inference cost grows w/ clip
        cfg = SimConfig(n_patients=64, n_devices=2,
                        duration_seconds=40 * w, window_seconds=float(w),
                        seed=3)
        r = simulate([cost], cfg)
        mu = cfg.n_devices / cost
        tq = queueing_bound(r.arrivals, mu, cost)
        out[w] = {"ts_s": cost, "tq_bound_s": tq,
                  "e2e_p95_s": r.p(95),
                  "tq_emp_max_s": float(r.queue_delays().max())}
        if verbose:
            v = out[w]
            print(f"Fig 13 window {w:3d}s: Ts {v['ts_s'] * 1000:6.1f}ms  "
                  f"Tq_bound {v['tq_bound_s'] * 1000:6.1f}ms  "
                  f"e2e_p95 {v['e2e_p95_s'] * 1000:6.1f}ms")
    return out


def bench_fused_serving(n_patients: int = 16, reps: int = 10,
                        input_len: int = 750, verbose=True,
                        write_json: bool = True) -> Dict:
    """Before/after microbench of the fused serving hot path on the
    reduced 12-member zoo x ``n_patients`` streaming patients:

    * ``per_member``       — the old loop: one jitted dispatch + sync
                             per member per patient (12/query);
    * ``fused``            — architecture-bucketed stacked execution,
                             one dispatch per bucket (4/query);
    * ``fused_microbatch`` — fused + cross-patient micro-batching: one
                             flush serves all ``n_patients`` windows
                             (4 dispatches per FLUSH, 4/P per query).

    Writes the result to BENCH_serving.json so the perf trajectory is
    tracked across PRs.
    """
    import jax
    from repro.configs.ecg_zoo import zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.pipeline import EnsembleService, ZooMember

    specs = zoo_specs(reduced=True, input_len=input_len)
    members = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
               for i, s in enumerate(specs)]
    rng = np.random.default_rng(0)
    windows = [{"ecg": rng.standard_normal((3, input_len))
                .astype(np.float32)} for _ in range(n_patients)]

    modes = (("per_member", False, False), ("fused", True, False),
             ("fused_microbatch", True, True))
    out: Dict = {"n_patients": n_patients, "n_members": len(members),
                 "reps": reps, "input_len": input_len, "modes": {}}
    for name, fused, microbatch in modes:
        svc = EnsembleService(members, fused=fused)
        if fused:
            out["n_buckets"] = svc.n_buckets
        if microbatch:
            svc.predict_batch(windows)                 # warmup/compile
        else:
            svc.predict(windows[0])
        d0, n_q = svc.dispatch_count, 0
        t0 = time.perf_counter()
        for _ in range(reps):
            if microbatch:
                svc.predict_batch(windows)
            else:
                for w in windows:
                    svc.predict(w)
            n_q += n_patients
        dt = time.perf_counter() - t0
        out["modes"][name] = {
            "per_query_ms": dt / n_q * 1e3,
            "sustained_qps": n_q / dt,
            "dispatches_per_query": (svc.dispatch_count - d0) / n_q,
        }
    base = out["modes"]["per_member"]
    best = out["modes"]["fused_microbatch"]
    out["speedup_fused_microbatch"] = (base["per_query_ms"]
                                       / best["per_query_ms"])
    if verbose:
        print(f"\nfused serving bench (reduced zoo x {n_patients} "
              f"patients, CPU):")
        for name, m in out["modes"].items():
            print(f"  {name:17s}: {m['per_query_ms']:7.2f} ms/query  "
                  f"{m['sustained_qps']:7.1f} q/s  "
                  f"{m['dispatches_per_query']:5.2f} dispatches/query")
        print(f"  speedup (fused+microbatch vs per-member): "
              f"{out['speedup_fused_microbatch']:.2f}x")
    if write_json:
        _merge_bench_json(out)
    return out


INGEST_MODE_KEYS = ("per_query_ms", "sustained_qps",
                    "h2d_bytes_per_query", "marshal_ms_per_flush",
                    "dispatches_per_query")
INGEST_TOP_KEYS = ("n_patients", "n_members", "reps", "input_len",
                   "modes", "h2d_reduction_x",
                   "h2d_reduction_device_x", "speedup_vs_legacy",
                   "bitwise_device_vs_packed")


def check_ingest_schema(out: Dict) -> None:
    """Schema guard for ``BENCH_serving.json["ingest"]`` — run by the
    ``--smoke`` CI invocation so the tracked section can't silently
    rot as the bench evolves."""
    for k in INGEST_TOP_KEYS:
        assert k in out, f"ingest bench missing key {k!r}"
    for mode in ("legacy_marshal", "packed_host", "device_resident"):
        assert mode in out["modes"], f"ingest bench missing mode {mode}"
        for k in INGEST_MODE_KEYS:
            assert k in out["modes"][mode], \
                f"ingest mode {mode} missing key {k!r}"
    assert out["bitwise_device_vs_packed"] is True


def bench_ingest(n_patients: int = 64, reps: int = 5,
                 input_len: int = 750, verbose=True,
                 write_json: bool = True) -> Dict:
    """Ingest-side microbench of the flush marshaling regimes on the
    reduced zoo x ``n_patients`` streaming patients:

    * ``legacy_marshal``   — the pre-refactor hot path: a host
                             (member, patient) double loop builds one
                             [M, Ppad, L, 1] input per bucket, M x L
                             floats of H2D per patient;
    * ``packed_host``      — one [Ppad, 3, L] window pack per flush,
                             shipped once per device, lead-expanded to
                             the stacked view ON device (3 x L floats
                             of H2D per patient);
    * ``device_resident``  — windows live in ``DeviceIngest`` ring
                             buffers; the flush gathers them on device
                             and only (patient, end, valid) int32
                             triples cross the host boundary.

    Scores are asserted equivalent across modes (bitwise for
    device-vs-packed).  Merged into ``BENCH_serving.json`` under
    ``"ingest"``.
    """
    import jax
    from repro.configs.ecg_zoo import ECG_LEADS, zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.aggregator import DeviceIngest, ModalitySpec
    from repro.serving.pipeline import EnsembleService, ZooMember

    specs = zoo_specs(reduced=True, input_len=input_len)
    members = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
               for i, s in enumerate(specs)]
    rng = np.random.default_rng(0)
    windows = [{"ecg": rng.standard_normal((ECG_LEADS, input_len))
                .astype(np.float32)} for _ in range(n_patients)]

    # stream the same windows into the device rings (mixed chunk sizes
    # exercise the pow2 ingest ladder), then serve them as refs
    di = DeviceIngest([ModalitySpec("ecg", float(input_len), ECG_LEADS)],
                      n_patients, window_seconds=1.0)
    refs = []
    for p in range(n_patients):
        ecg, off = windows[p]["ecg"], 0
        for k in (200, 250, 150, 100):
            di.ingest(off / input_len, p, "ecg", ecg[:, off:off + k])
            off += k
        while off < input_len:
            di.ingest(off / input_len, p, "ecg",
                      ecg[:, off:off + 250])
            off += 250
        refs.append(di.close_window(p, 1.0))

    feeds = {"legacy_marshal":
             (EnsembleService(members, marshal="legacy"), windows),
             "packed_host": (EnsembleService(members), windows),
             "device_resident": (EnsembleService(members), refs)}
    out: Dict = {"n_patients": n_patients, "n_members": len(members),
                 "reps": reps, "input_len": input_len, "modes": {}}
    scores = {}
    for name, (svc, feed) in feeds.items():
        scores[name] = svc.predict_batch(feed)     # warmup/compile
        d0, h0, m0 = (svc.dispatch_count, svc.h2d_bytes,
                      svc.marshal_seconds)
        t0 = time.perf_counter()
        for _ in range(reps):
            svc.predict_batch(feed)
        dt = time.perf_counter() - t0
        n_q = reps * n_patients
        out["modes"][name] = {
            "per_query_ms": dt / n_q * 1e3,
            "sustained_qps": n_q / dt,
            "h2d_bytes_per_query": (svc.h2d_bytes - h0) / n_q,
            "marshal_ms_per_flush":
                (svc.marshal_seconds - m0) / reps * 1e3,
            "dispatches_per_query": (svc.dispatch_count - d0) / n_q,
        }
    # the modes must agree on the answers, not just the speed: packed
    # vs legacy to float tolerance (different XLA programs), device vs
    # packed BITWISE (same program, device-gathered inputs)
    np.testing.assert_allclose(scores["packed_host"],
                               scores["legacy_marshal"], atol=1e-6)
    out["bitwise_device_vs_packed"] = bool(np.array_equal(
        np.asarray(scores["device_resident"]),
        np.asarray(scores["packed_host"])))
    leg = out["modes"]["legacy_marshal"]
    out["h2d_reduction_x"] = (leg["h2d_bytes_per_query"]
                              / out["modes"]["packed_host"]
                              ["h2d_bytes_per_query"])
    out["h2d_reduction_device_x"] = (leg["h2d_bytes_per_query"]
                                     / max(out["modes"]
                                           ["device_resident"]
                                           ["h2d_bytes_per_query"],
                                           1e-9))
    out["speedup_vs_legacy"] = {
        m: leg["per_query_ms"] / out["modes"][m]["per_query_ms"]
        for m in ("packed_host", "device_resident")}
    if verbose:
        print(f"\ningest bench (reduced zoo x {n_patients} patients, "
              f"L={input_len}):")
        for name, m in out["modes"].items():
            print(f"  {name:16s}: {m['per_query_ms']:7.2f} ms/query  "
                  f"{m['h2d_bytes_per_query']:9.0f} H2D B/query  "
                  f"marshal {m['marshal_ms_per_flush']:6.2f} ms/flush")
        print(f"  H2D reduction: {out['h2d_reduction_x']:.1f}x packed, "
              f"{out['h2d_reduction_device_x']:.0f}x device-resident; "
              f"device bitwise == packed: "
              f"{out['bitwise_device_vs_packed']}")
    if write_json:
        _merge_bench_json({"ingest": out})
    return out


SLOTS_TOP_KEYS = ("n_slots", "n_members", "n_buckets", "tick_reps",
                  "n_reads", "input_len", "tick_ms",
                  "dispatches_per_tick", "dispatches_per_query",
                  "reads_per_sec", "read_us", "flush_per_query_ms",
                  "read_vs_flush_ratio", "bitwise_equal")


def check_slots_schema(out: Dict) -> None:
    """Schema + invariant guard for ``BENCH_serving.json["slots"]``:
    queries must be free of device dispatch entirely, and a query read
    must cost at most a tenth of a flush-path query."""
    for k in SLOTS_TOP_KEYS:
        assert k in out, f"slots bench missing key {k!r}"
    assert out["bitwise_equal"] is True, \
        "slot engine diverged from the flush oracle"
    assert out["dispatches_per_query"] == 0.0, \
        "slot reads must not dispatch device work"
    assert out["read_vs_flush_ratio"] <= 0.10, \
        (f"slot read latency {out['read_us']:.1f}us is more than 10% "
         f"of a flush query ({out['flush_per_query_ms']:.3f}ms)")


def check_slots_file(path: str = BENCH_JSON) -> None:
    """CI gate on the committed BENCH_serving.json["slots"] section."""
    with open(path) as f:
        data = json.load(f)
    assert "slots" in data, "BENCH_serving.json missing 'slots'"
    check_slots_schema(data["slots"])
    print(f"slots schema OK ({path})")


def bench_slots(n_slots: int = 64, tick_reps: int = 20,
                n_reads: int = 200_000, input_len: int = 750,
                verbose=True, write_json: bool = True) -> Dict:
    """Slot-engine continuous serving vs the flush path on the reduced
    zoo x ``n_slots`` occupied beds:

    * ``tick_ms``             — one fused tick scoring ALL occupied
                                slots (ring gathers + the flush path's
                                cached bucket dispatches + one donated
                                masked update);
    * ``reads_per_sec``       — query cost once scores are resident:
                                ``read()`` is a host int read of the
                                mirror, zero H2D and zero dispatch;
    * ``flush_per_query_ms``  — the flush path serving the same refs,
                                for the read-vs-flush latency ratio.

    The engine's scores are asserted BITWISE equal to the flush oracle
    (same cached XLA programs, both at the ``n_slots`` pow2 pad).
    Merged into ``BENCH_serving.json`` under ``"slots"``.
    """
    import jax
    from repro.configs.ecg_zoo import ECG_LEADS, zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.aggregator import DeviceIngest, ModalitySpec
    from repro.serving.pipeline import EnsembleService, ZooMember
    from repro.serving.slots import SlotEngine

    specs = zoo_specs(reduced=True, input_len=input_len)
    members = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
               for i, s in enumerate(specs)]
    rng = np.random.default_rng(0)
    di = DeviceIngest([ModalitySpec("ecg", float(input_len), ECG_LEADS)],
                      n_slots, window_seconds=1.0)
    svc = EnsembleService(members)
    eng = SlotEngine(svc, di)

    refs = []
    for p in range(n_slots):
        sig = rng.standard_normal(
            (ECG_LEADS, input_len)).astype(np.float32)
        off = 0
        for k in (250, 250, input_len - 500):
            di.ingest(off / input_len, p, "ecg", sig[:, off:off + k])
            off += k
        ref = di.close_window(p, 1.0)
        refs.append(ref)
        eng.update(ref)

    eng.warm()
    eng.tick()                                     # first-tick residue
    d0 = eng.dispatch_count
    t0 = time.perf_counter()
    for _ in range(tick_reps):
        eng.tick()
    tick_dt = time.perf_counter() - t0
    dispatches_per_tick = (eng.dispatch_count - d0) / tick_reps

    d0 = eng.dispatch_count
    t0 = time.perf_counter()
    for i in range(n_reads):
        eng.read(i % n_slots)
    read_dt = time.perf_counter() - t0
    read_dispatches = (eng.dispatch_count - d0) / n_reads

    oracle = np.asarray(svc.predict_batch(refs), np.float64)
    svc.predict_batch(refs)                        # flush-path warm
    t0 = time.perf_counter()
    for _ in range(max(2, tick_reps // 4)):
        svc.predict_batch(refs)
    flush_dt = time.perf_counter() - t0
    flush_per_query_ms = (flush_dt / (max(2, tick_reps // 4) * n_slots)
                          * 1e3)

    read_us = read_dt / n_reads * 1e6
    out: Dict = {
        "n_slots": n_slots, "n_members": len(members),
        "n_buckets": svc.n_buckets, "tick_reps": tick_reps,
        "n_reads": n_reads, "input_len": input_len,
        "tick_ms": tick_dt / tick_reps * 1e3,
        "dispatches_per_tick": dispatches_per_tick,
        "dispatches_per_query": read_dispatches,
        "reads_per_sec": n_reads / read_dt,
        "read_us": read_us,
        "flush_per_query_ms": flush_per_query_ms,
        "read_vs_flush_ratio": (read_us * 1e-3) / flush_per_query_ms,
        "bitwise_equal": bool(np.array_equal(
            eng.scores(), oracle, equal_nan=True)),
    }
    if verbose:
        print(f"\nslot engine bench ({n_slots} occupied slots, "
              f"L={input_len}):")
        print(f"  tick: {out['tick_ms']:7.2f} ms for all {n_slots} "
              f"slots ({dispatches_per_tick:.1f} dispatches/tick)")
        print(f"  read: {read_us:7.2f} us/query  "
              f"{out['reads_per_sec']:10.0f} reads/s  "
              f"{read_dispatches:.2f} dispatches/query")
        print(f"  flush path: {flush_per_query_ms:7.3f} ms/query  "
              f"-> read/flush ratio {out['read_vs_flush_ratio']:.4f}")
        print(f"  bitwise vs flush oracle: {out['bitwise_equal']}")
    if write_json:
        _merge_bench_json({"slots": out})
    return out


def bench_placement_sweep(device_counts=(1, 2, 4, 8),
                          n_patients: int = 16, reps: int = 5,
                          input_len: int = 750, verbose=True,
                          write_json: bool = True) -> Dict:
    """Sharded-vs-unsharded serving on the reduced zoo: for each device
    count, LPT-place the measured bucket costs, run the sharded
    ``predict_batch`` hot path, and record

    * ``makespan_s``     — the plan's per-query service latency model
                           (slowest device's bucket-cost total), which
                           must fall strictly below
    * ``serial_s``       — the unsharded sum-of-buckets cost, for every
                           sweep point with >= 2 devices;
    * wall-clock per-query latency and shard/dispatch counts.

    Merged into ``BENCH_serving.json`` under ``"placement_sweep"`` so
    the multi-device trajectory is tracked alongside the fused-serving
    numbers."""
    import jax
    from repro.configs.ecg_zoo import zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.pipeline import EnsembleService, ZooMember

    avail = jax.device_count()
    device_counts = [d for d in device_counts if d <= avail]
    specs = zoo_specs(reduced=True, input_len=input_len)
    members = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
               for i, s in enumerate(specs)]
    rng = np.random.default_rng(0)
    windows = [{"ecg": rng.standard_normal((3, input_len))
                .astype(np.float32)} for _ in range(n_patients)]

    base = EnsembleService(members)
    bucket_costs = base.measured_bucket_costs(reps=reps,
                                              batch=n_patients)
    serial = float(sum(bucket_costs))
    out: Dict = {"n_devices_available": avail,
                 "n_patients": n_patients, "reps": reps,
                 "input_len": input_len,
                 "bucket_costs_ms": [c * 1e3 for c in bucket_costs],
                 "serial_s": serial, "sweep": {}}
    if verbose:
        print(f"\nplacement sweep (reduced zoo, {avail} host devices, "
              f"serial sum-of-buckets {serial * 1e3:.1f} ms):")
    for d in device_counts:
        pl = base.plan_placement(d, bucket_costs=bucket_costs)
        svc = EnsembleService(members, placement=pl,
                              devices=jax.devices()[:d])
        svc.predict_batch(windows)                 # warmup/compile
        d0 = svc.dispatch_count
        t0 = time.perf_counter()
        for _ in range(reps):
            svc.predict_batch(windows)
        dt = time.perf_counter() - t0
        n_q = reps * n_patients
        rec = {"makespan_s": pl.makespan,
               "imbalance": pl.imbalance,
               "n_shards": svc.n_buckets,
               "per_query_ms": dt / n_q * 1e3,
               "dispatches_per_query":
                   (svc.dispatch_count - d0) / n_q,
               # relative epsilon: at 1 device makespan == serial up to
               # float summation order, which must not read as "below"
               "makespan_below_serial":
                   bool(pl.makespan < serial * (1.0 - 1e-9))}
        out["sweep"][d] = rec
        if verbose:
            print(f"  {d} devices: makespan {pl.makespan * 1e3:6.1f} ms"
                  f" (imb {pl.imbalance:.2f}, {rec['n_shards']} shards)"
                  f"  wall {rec['per_query_ms']:6.2f} ms/query"
                  f"  {'<' if rec['makespan_below_serial'] else '>='}"
                  f" serial")
    # never clobber a tracked multi-device trajectory with a degenerate
    # sweep: a process launched without forced devices only covers d=1
    if write_json and len(device_counts) > 1:
        _merge_bench_json({"placement_sweep": out})
    elif write_json and verbose:
        print("  (single-device process: sweep NOT written to "
              "BENCH_serving.json — run benchmarks/serving_bench.py "
              "standalone for the tracked 8-device sweep)")
    return out


HETERO_TOP_KEYS = ("n_devices", "speeds", "bucket_costs_ms",
                   "makespan_blind_s", "makespan_aware_s",
                   "imbalance_blind", "imbalance_aware",
                   "aware_below_blind", "bitwise_equal")


def check_placement_hetero_schema(out: Dict) -> None:
    """Schema + invariant guard for
    ``BENCH_serving.json["placement_hetero"]``: on a 1x/4x split the
    speed-aware plan must land strictly below the speed-blind plan
    re-scored under the true speeds, and sharded serving under the
    aware plan must stay bitwise equal to the unsharded oracle."""
    for k in HETERO_TOP_KEYS:
        assert k in out, f"placement_hetero bench missing key {k!r}"
    assert out["bitwise_equal"] is True, \
        "hetero-placed sharded serving diverged from the oracle"
    assert out["aware_below_blind"] is True, \
        (f"speed-aware makespan {out['makespan_aware_s']:.4f}s not "
         f"below speed-blind {out['makespan_blind_s']:.4f}s")
    assert out["makespan_aware_s"] < out["makespan_blind_s"]


def check_placement_hetero_file(path: str = BENCH_JSON) -> None:
    """CI gate on the committed BENCH_serving.json["placement_hetero"]
    section."""
    with open(path) as f:
        data = json.load(f)
    assert "placement_hetero" in data, \
        "BENCH_serving.json missing 'placement_hetero'"
    check_placement_hetero_schema(data["placement_hetero"])
    print(f"placement_hetero schema OK ({path})")


def bench_placement_hetero(n_devices: int = 4,
                           speeds=(1.0, 1.0, 4.0, 4.0),
                           n_patients: int = 16, reps: int = 5,
                           input_len: int = 750, verbose=True,
                           write_json: bool = True) -> Dict:
    """Heterogeneous-pool placement on the reduced zoo: a synthetic
    1x/4x device-speed split (slow devices FIRST, so a speed-blind LPT
    plan is maximally unlucky — its heaviest buckets land on the slow
    half).  Records

    * ``makespan_blind_s`` — the speed-blind plan RE-SCORED under the
      true speed vector (``Placement(assignment, loads, speeds)``),
      i.e. what the pool would actually deliver if planned blind;
    * ``makespan_aware_s`` — the speed-vector LPT plan's finish time,
      which must land strictly below blind;
    * ``bitwise_equal``    — sharded serving under the aware plan vs
      the unsharded oracle (placement must never change scores).

    Merged into ``BENCH_serving.json`` under ``"placement_hetero"``."""
    import jax
    from repro.configs.ecg_zoo import zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.pipeline import EnsembleService, ZooMember
    from repro.serving.placement import Placement

    avail = jax.device_count()
    if avail < n_devices:
        if verbose:
            print(f"\nplacement hetero bench skipped: {avail} host "
                  f"devices < {n_devices} (force with XLA_FLAGS)")
        return {}
    speeds = [float(s) for s in speeds]
    assert len(speeds) == n_devices
    specs = zoo_specs(reduced=True, input_len=input_len)
    members = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
               for i, s in enumerate(specs)]
    rng = np.random.default_rng(0)
    windows = [{"ecg": rng.standard_normal((3, input_len))
                .astype(np.float32)} for _ in range(n_patients)]

    base = EnsembleService(members)
    oracle = np.asarray(base.predict_batch(windows), np.float64)
    bucket_costs = base.measured_bucket_costs(reps=reps,
                                              batch=n_patients)
    blind = base.plan_placement(n_devices, bucket_costs=bucket_costs)
    # what the blind plan actually costs on the heterogeneous pool
    blind_true = Placement(blind.assignment, blind.loads, speeds=speeds)
    aware = base.plan_placement(n_devices, bucket_costs=bucket_costs,
                                speeds=speeds)

    svc = EnsembleService(members, placement=aware,
                          devices=jax.devices()[:n_devices])
    got = np.asarray(svc.predict_batch(windows), np.float64)
    out: Dict = {
        "n_devices": n_devices, "speeds": speeds,
        "n_patients": n_patients, "reps": reps,
        "input_len": input_len,
        "bucket_costs_ms": [c * 1e3 for c in bucket_costs],
        "makespan_blind_s": blind_true.makespan,
        "makespan_aware_s": aware.makespan,
        "imbalance_blind": blind_true.imbalance,
        "imbalance_aware": aware.imbalance,
        "aware_below_blind":
            bool(aware.makespan < blind_true.makespan * (1.0 - 1e-9)),
        "bitwise_equal": bool(np.array_equal(got, oracle,
                                             equal_nan=True)),
    }
    if verbose:
        print(f"\nplacement hetero bench ({n_devices} devices, speeds "
              f"{speeds}):")
        print(f"  speed-blind plan under true speeds: "
              f"{blind_true.makespan * 1e3:6.1f} ms "
              f"(imb {blind_true.imbalance:.2f})")
        print(f"  speed-aware plan:                   "
              f"{aware.makespan * 1e3:6.1f} ms "
              f"(imb {aware.imbalance:.2f})")
        print(f"  aware below blind: {out['aware_below_blind']}   "
              f"bitwise vs oracle: {out['bitwise_equal']}")
    if write_json:
        _merge_bench_json({"placement_hetero": out})
    return out


def bench_measured_costs(verbose=True) -> Dict:
    """Real wall-clock per-member inference cost (timeit analogue of
    A.4's 'Time in PyTorch' curve) for a few zoo members."""
    import jax
    from repro.configs.ecg_zoo import zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.pipeline import EnsembleService, ZooMember
    specs = zoo_specs(reduced=True, input_len=750)[:4]
    members = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
               for i, s in enumerate(specs)]
    svc = EnsembleService(members)
    costs = svc.measured_costs(reps=5)
    out = {s.name: c for s, c in zip(specs, costs)}
    if verbose:
        print("\nmeasured per-member inference cost (CPU, jitted):")
        for k, v in out.items():
            print(f"  {k}: {v * 1000:.2f} ms/query")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-size CI invocation: run the fused + "
                         "ingest benches at toy sizes, validate the "
                         "BENCH_serving.json['ingest'] schema, write "
                         "nothing")
    args = ap.parse_args()
    # force host devices before jax initialises (jax is imported
    # lazily): the placement benches need a multi-device pool in BOTH
    # modes; the unsharded benches are indifferent to the count
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    if args.smoke:
        bench_fused_serving(n_patients=4, reps=2, input_len=250,
                            write_json=False)
        out = bench_ingest(n_patients=8, reps=2, input_len=250,
                           write_json=False)
        check_ingest_schema(out)
        print("ingest schema OK")
        out = bench_slots(n_slots=8, tick_reps=3, n_reads=20_000,
                          input_len=250, write_json=False)
        check_slots_schema(out)
        print("slots schema OK")
        out = bench_placement_hetero(n_patients=4, reps=2,
                                     input_len=250, write_json=False)
        check_placement_hetero_schema(out)
        print("placement_hetero schema OK")
    else:
        bench_fused_serving()
        bench_ingest()
        bench_slots()
        check_slots_file()
        bench_placement_sweep()
        bench_placement_hetero()
        check_placement_hetero_file()
