"""Shared benchmark substrate: trains the (reduced) ECG model zoo on the
synthetic ICU cohort, caches trained params + validation score vectors +
profiles, and exposes the accuracy/latency profilers every benchmark uses.

First call trains and caches under results/zoo_cache/; later calls load.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.ecg_zoo import EcgModelSpec, zoo_specs
from repro.core.bagging import bagging_predict, roc_auc
from repro.core.profiles import ModelProfile, ModelZoo, SystemConfig
from repro.models.ecg_resnext import ecg_macs, ecg_param_count
from repro.models.tabular import LogisticRegression, VitalsForest
from repro.serving.latency import LatencyProfiler
from repro.training import checkpoint
from repro.training.data import make_icu_dataset, split_by_patient
from repro.training.train_loop import (ecg_predict_proba, train_ecg_model)

CACHE = os.path.join(os.path.dirname(__file__), "..", "results",
                     "zoo_cache")


def build_zoo(reduced: bool = True, n_patients: int = 32,
              clips: int = 12, seconds: int = 3, steps: int = 160,
              seed: int = 0, verbose: bool = True, widths=None,
              blocks=None) -> Tuple[ModelZoo, Dict]:
    """Returns (zoo w/ cached val scores, extras dict)."""
    os.makedirs(CACHE, exist_ok=True)
    tag = f"r{int(reduced)}_p{n_patients}_c{clips}_s{seconds}_t{steps}" \
          f"_seed{seed}" + ("w" + "-".join(map(str, widths))
                            if widths else "") \
          + ("b" + "-".join(map(str, blocks)) if blocks else "")
    meta_path = os.path.join(CACHE, f"zoo_{tag}.json")

    data = make_icu_dataset(n_patients, clips, seed=seed, seconds=seconds)
    train, val = split_by_patient(data, holdout=max(4, n_patients // 3))
    specs = zoo_specs(reduced=reduced, input_len=seconds * 250,
                      widths=widths, blocks=blocks)

    profiles: List[ModelProfile] = []
    scores: List[np.ndarray] = []
    params_all = {}
    t0 = time.time()
    for i, spec in enumerate(specs):
        ck = os.path.join(CACHE, f"{tag}_{spec.name}.npz")
        x_tr = train["ecg"][:, spec.lead, :]
        from repro.models.ecg_resnext import init_ecg
        import jax
        template = init_ecg(jax.random.PRNGKey(seed + i), spec)
        if os.path.exists(ck):
            params = checkpoint.restore(ck, template)
        else:
            params, _ = train_ecg_model(spec, x_tr, train["label"],
                                        steps=steps, seed=seed + i)
            checkpoint.save(ck, params, {"spec": spec.name})
        sc = ecg_predict_proba(params, val["ecg"][:, spec.lead, :], spec)
        auc = roc_auc(val["label"] == 1, sc)
        profiles.append(ModelProfile(
            name=spec.name, depth=spec.blocks, width=spec.width,
            macs=ecg_macs(spec), memory_bytes=4.0 * ecg_param_count(params),
            modality=spec.lead, input_len=spec.input_len, val_auc=auc))
        scores.append(sc)
        params_all[spec.name] = params
        if verbose:
            print(f"[zoo] {spec.name}: val AUC {auc:.3f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    # CPU-side models (join the accuracy ensemble, not the latency zoo)
    vit = VitalsForest(n_channels=7, n_trees=15, seed=seed)
    vit.fit(train["vitals"], train["label"].astype(float))
    vit_scores = vit.predict_proba(val["vitals"])
    lab = LogisticRegression(steps=300, seed=seed)
    lab.fit(train["labs"], train["label"].astype(float))
    lab_scores = lab.predict_proba(val["labs"])

    zoo = ModelZoo(profiles, val_scores=np.stack(scores),
                   val_labels=(val["label"] == 1).astype(int))

    # measured per-member serving cost (closed-loop, jitted — the paper's
    # mu measurement), cached alongside the zoo
    costs_path = os.path.join(CACHE, f"costs_{tag}.json")
    if os.path.exists(costs_path):
        with open(costs_path) as f:
            measured = json.load(f)
    else:
        from repro.serving.pipeline import EnsembleService, ZooMember
        svc = EnsembleService([ZooMember(s, params_all[s.name])
                               for s in specs])
        cs = svc.measured_costs(reps=3)
        measured = {s.name: c for s, c in zip(specs, cs)}
        with open(costs_path, "w") as f:
            json.dump(measured, f)

    extras = {"train": train, "val": val, "params": params_all,
              "specs": specs, "vitals_scores": vit_scores,
              "labs_scores": lab_scores, "vitals_model": vit,
              "labs_model": lab,
              "measured_costs": [measured[s.name] for s in specs]}
    with open(meta_path, "w") as f:
        json.dump({"aucs": [p.val_auc for p in profiles]}, f)
    return zoo, extras


def make_profilers(zoo: ModelZoo, sysconf: SystemConfig,
                   extras: Dict = None, include_cpu_models: bool = True,
                   measured: bool = True):
    """(f_a, f_l): the paper's two profilers.  f_a evaluates the TRUE
    bagging ensemble on the validation set (side CPU models included per
    §4.1.1); f_l is the network-calculus latency profiler, fed by the
    MEASURED closed-loop per-member costs when available (§3.4)."""
    y = zoo.val_labels
    side = []
    if include_cpu_models and extras is not None:
        side = [extras["vitals_scores"], extras["labs_scores"]]

    def f_a(b) -> float:
        sel = zoo.val_scores[np.asarray(b, bool)]
        rows = list(sel) + side
        if not rows:
            return 0.5
        return roc_auc(y, np.mean(rows, axis=0))

    cost_fn = None
    if measured and extras is not None and "measured_costs" in extras:
        costs = extras["measured_costs"]
        cost_fn = lambda i: costs[i]
    f_l = LatencyProfiler(zoo, sysconf, cost_fn=cost_fn)
    return f_a, f_l


def binding_budget(zoo: ModelZoo, f_l, frac: float = 0.6) -> float:
    """A latency budget at which selection genuinely binds: frac x the
    latency of serving the ENTIRE zoo (the paper's 200 ms plays the same
    role against its 60-model zoo on 2 V100s)."""
    full = f_l(np.ones(len(zoo), np.int8))
    return float(frac * full)


def single_model_stats(zoo: ModelZoo, f_a, f_l):
    n = len(zoo)
    eye = np.eye(n, dtype=np.int8)
    acc = np.asarray([f_a(eye[i]) for i in range(n)])
    lat = np.asarray([f_l(eye[i]) for i in range(n)])
    return acc, lat
