"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints a ``name,us_per_call,derived`` CSV summary at the end (us_per_call
is the benchmark's own wall time; derived is its headline metric).
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller zoo / fewer seeds")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    from benchmarks import composition, serving_bench
    from benchmarks.roofline_table import bench_roofline
    from benchmarks.zoo_setup import build_zoo

    seeds = (0,) if args.quick else (0, 1, 2)
    print("[run] building/loading model zoo ...", flush=True)
    zoo, extras = build_zoo(
        n_patients=16 if args.quick else 32,
        clips=8 if args.quick else 12,
        steps=120 if args.quick else 160)

    rows = []

    def bench(name, fn, derive):
        if args.only and name not in args.only.split(","):
            return
        t0 = time.time()
        out = fn()
        dt = time.time() - t0
        rows.append((name, dt * 1e6, derive(out)))

    bench("table2_composition",
          lambda: composition.bench_table2(seeds=seeds, zoo=zoo,
                                           extras=extras),
          lambda t: f"HOLMES_auc={t['HOLMES']['roc_auc'][0]:.4f}")
    bench("fig6_trajectory",
          lambda: composition.bench_fig6(zoo=zoo, extras=extras),
          lambda t: f"holmes_iters={len(t['HOLMES'])}")
    bench("fig7_budget_sweep",
          lambda: composition.bench_fig7(seeds=seeds, zoo=zoo,
                                         extras=extras),
          lambda t: "holmes_wins="
          + str(sum(v["HOLMES"][0] >= v["NPO"][0] - 1e-6
                    for v in t.values())) + f"/{len(t)}")
    bench("fig8_surrogate_r2",
          lambda: composition.bench_fig8(zoo=zoo, extras=extras),
          lambda t: f"final_r2_lat={t[-1]['r2_lat']:.3f}")
    bench("fig9_online_vs_offline",
          serving_bench.bench_fig9,
          lambda t: f"staleness_ratio={t['staleness_ratio']:.0f}x")
    bench("fig10_scalability",
          serving_bench.bench_fig10,
          lambda t: "p95_64pat="
          + f"{t['vs_patients'][64]['p95_s'] * 1000:.1f}ms")
    bench("fig13_window_effects",
          serving_bench.bench_fig13,
          lambda t: f"ts_30s={t[30]['ts_s'] * 1000:.1f}ms")
    bench("measured_member_costs",
          serving_bench.bench_measured_costs,
          lambda t: f"n_members={len(t)}")
    # quick mode: fewer reps, and don't clobber the tracked
    # BENCH_serving.json trajectory with the noisy numbers
    bench("fused_serving",
          lambda: serving_bench.bench_fused_serving(
              reps=3 if args.quick else 10,
              write_json=not args.quick),
          lambda t: f"speedup={t['speedup_fused_microbatch']:.2f}x")
    # multi-device placement sweep: degrades to whatever device count
    # this process was launched with (run serving_bench.py standalone,
    # or under the CI lane's XLA_FLAGS, for the full 8-device sweep)
    bench("placement_sweep",
          lambda: serving_bench.bench_placement_sweep(
              reps=3 if args.quick else 5,
              write_json=not args.quick),
          lambda t: "makespan_min="
          + f"{min(v['makespan_s'] for v in t['sweep'].values()) * 1e3:.1f}ms")
    # adaptive control plane: static-vs-adaptive under a census spike
    # (quick mode keeps the noisy numbers out of the tracked JSON)
    from benchmarks.adaptive_bench import bench_adaptive
    bench("adaptive_serving",
          lambda: bench_adaptive(write_json=not args.quick,
                                 wallclock=not args.quick),
          lambda t: "viol_static/adaptive="
          + f"{t['static']['violation_rate']:.2f}/"
          + f"{t['adaptive']['violation_rate']:.2f}")
    bench("roofline_table",
          bench_roofline,
          lambda t: f"n_records={len(t)}")

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
