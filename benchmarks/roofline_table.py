"""§Roofline table: renders the dry-run/probe JSON artifacts into the
per-(arch x shape) roofline table for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load_records(pattern: str = "roofline_*.json") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            data = json.load(f)
        recs.extend(data.get("results", []))
    # last write wins per (arch, shape, mesh, variant)
    dedup = {}
    for r in recs:
        key = (r["arch"], r["shape"], r.get("mesh"), r.get("variant", ""))
        dedup[key] = r
    return list(dedup.values())


def render(recs: List[Dict], only_baseline: bool = True) -> str:
    rows = [r for r in recs if not r.get("variant")] if only_baseline \
        else recs
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = (f"| {'arch':24s} | {'shape':11s} | {'compute(s)':>10s} | "
           f"{'memory(s)':>10s} | {'collective(s)':>13s} | {'dominant':>10s} "
           f"| {'useful':>6s} | {'MFU-bound':>9s} |")
    sep = "|" + "-" * 26 + "|" + "-" * 13 + "|" + "-" * 12 + "|" \
        + "-" * 12 + "|" + "-" * 15 + "|" + "-" * 12 + "|" + "-" * 8 \
        + "|" + "-" * 11 + "|"
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']:24s} | {r['shape']:11s} "
            f"| {r['compute_s']:10.3e} | {r['memory_s']:10.3e} "
            f"| {r['collective_s']:13.3e} | {r['dominant']:>10s} "
            f"| {r['useful_ratio']:6.2f} | {r['mfu_bound']:9.2%} |")
    return "\n".join(lines)


def bench_roofline(verbose: bool = True) -> List[Dict]:
    recs = load_records()
    if verbose:
        if recs:
            print("\n§Roofline baseline table (single-pod 16x16, "
                  "per-device terms):")
            print(render(recs))
        else:
            print("\n[roofline_table] no results/roofline_*.json yet — "
                  "run PYTHONPATH=src python -m repro.launch.roofline")
    return recs


if __name__ == "__main__":
    bench_roofline()
