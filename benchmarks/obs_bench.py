"""Observability bench: the tracing plane must be CHEAP, HONEST and
EXPORTABLE — three lanes, one ``BENCH_obs.json``.

1. **overhead** — real jitted serving (reduced ECG zoo, batch-aware
   ``EnsembleServer``) over a 64-patient trace, run in interleaved
   repetitions with span tracing OFF and ON.  Gates:

   * spans-enabled median per-query latency is within
     ``overhead_budget_pct`` (5%) of spans-disabled — observing the
     plane must not move the plane;
   * stage attribution explains the measured end-to-end latency:
     ``coverage`` = (queue + coalesce + marshal + dispatch + gather)
     / e2e within [0.9, 1.1] — attribution is checked against the
     clock, not assumed.

2. **sketch_fidelity** — the windowed-sketch telemetry vs the exact
   deque oracle: identical event counts and violation rate on a
   shared randomized trace, p50/p99 within the histogram's relative
   error bound, T_q bound within one sub-window bucket, and — the
   end-to-end criterion — the seeded DES controller runs
   (adaptive + tiered) take IDENTICAL action logs under either
   engine.

3. **export** — Prometheus text rendering (series count / bytes), a
   live ``/metrics`` scrape over HTTP (stdlib server), and the JSONL
   span dump all round-trip non-trivially.

``--smoke`` is the CI tier1-obs entry: tiny trace, relaxed overhead
gate (wall-clock medians on a shared CI box are noisy; the committed
BENCH_obs.json carries the strict 5% number), writes nothing.
"""
from __future__ import annotations

import json
import os
import statistics
import tempfile
import time
import urllib.request
from typing import Callable, Dict, List, Optional

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_obs.json")

OBS_KEYS = (
    "n_patients", "windows_per_patient", "reps", "seed",
    "spans_off_ms", "spans_on_ms", "overhead_pct",
    "overhead_budget_pct", "overhead_ok",
    "coverage", "coverage_ok", "attribution",
    "sketch_fidelity", "export",
)
SKETCH_KEYS = (
    "counts_equal", "violation_rate_equal", "p50_rel_err", "p99_rel_err",
    "rel_err_bound", "tq_abs_err", "bucket_width",
    "adaptive_decisions_equal", "tiered_decisions_equal", "n_actions",
)
EXPORT_KEYS = (
    "prometheus_bytes", "prometheus_series", "http_status", "http_bytes",
    "jsonl_spans",
)


# -------------------------------------------------------- overhead lane
def _build_service(input_len: int = 250):
    import jax

    from repro.configs.ecg_zoo import zoo_specs
    from repro.serving.pipeline import EnsembleService, ZooMember
    from repro.models.ecg_resnext import init_ecg

    specs = zoo_specs(reduced=True, input_len=input_len)
    pool = [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
            for i, s in enumerate(specs)]
    return EnsembleService(pool)


def _serve_once(svc, n_patients: int, windows_per_patient: int,
                input_len: int, seed: int, tracer=None):
    """One serving rep: every patient submits ``windows_per_patient``
    queries through the batch-aware server; returns (stats, tracer)."""
    from repro.serving.server import EnsembleServer

    srv = EnsembleServer(batch_handler=svc.predict_batch, n_workers=2,
                         max_batch=8, max_wait_ms=2.0,
                         tracer=tracer).start()
    rng = np.random.default_rng(seed)
    for _ in range(windows_per_patient):
        for p in range(n_patients):
            srv.submit(p, {"ecg": rng.standard_normal(
                (3, input_len)).astype(np.float32)})
    stats = srv.stop()
    return stats


def run_overhead(n_patients: int = 64, windows_per_patient: int = 4,
                 reps: int = 5, input_len: int = 250, seed: int = 0,
                 overhead_budget_pct: float = 5.0,
                 verbose: bool = True) -> Dict:
    """Interleaved OFF/ON reps (so drift hits both modes alike); the
    comparison is median-of-rep-means per-query latency."""
    from repro.obs.spans import SpanRecorder

    svc = _build_service(input_len)
    # warmup rep (jit compiles; discarded)
    _serve_once(svc, n_patients, 1, input_len, seed)

    off_ms: List[float] = []
    on_ms: List[float] = []
    tracer = SpanRecorder(keep=4 * n_patients * windows_per_patient * reps)
    for r in range(reps):
        st = _serve_once(svc, n_patients, windows_per_patient,
                         input_len, seed + r)
        off_ms.append(1e3 * st.mean_latency)
        st = _serve_once(svc, n_patients, windows_per_patient,
                         input_len, seed + r, tracer=tracer)
        on_ms.append(1e3 * st.mean_latency)

    med_off = statistics.median(off_ms)
    med_on = statistics.median(on_ms)
    overhead_pct = 100.0 * (med_on - med_off) / med_off
    att = tracer.attribution()
    coverage = att["coverage"]
    out = {
        "n_patients": n_patients,
        "windows_per_patient": windows_per_patient,
        "reps": reps, "seed": seed,
        "spans_off_ms": med_off, "spans_on_ms": med_on,
        "overhead_pct": overhead_pct,
        "overhead_budget_pct": overhead_budget_pct,
        "overhead_ok": bool(overhead_pct <= overhead_budget_pct),
        "coverage": coverage,
        "coverage_ok": bool(0.9 <= coverage <= 1.1),
        "attribution": {
            "n_spans": att["n_spans"],
            "by_status": att["by_status"],
            "stage_ms": {k: 1e3 * v / max(att["n_spans"], 1)
                         for k, v in att["stage_seconds"].items()},
            "mean_e2e_ms": 1e3 * att["mean_e2e_s"],
        },
    }
    if verbose:
        print(f"  overhead: off {med_off:.2f} ms  on {med_on:.2f} ms  "
              f"(+{overhead_pct:.2f}%, budget "
              f"{overhead_budget_pct:.0f}%)  coverage {coverage:.3f}")
        stage_ms = out["attribution"]["stage_ms"]
        print("  per-query stage ms: "
              + "  ".join(f"{k} {v:.2f}" for k, v in stage_ms.items()))
    return out, tracer, svc


# ------------------------------------------------- sketch-fidelity lane
def run_sketch_fidelity(seed: int = 0, verbose: bool = True) -> Dict:
    from benchmarks.adaptive_bench import (run_adaptive_sim,
                                           run_tiered_sim,
                                           synthetic_testbed)
    from repro.control.telemetry import SloTelemetry
    from repro.obs.sketch import REL_ERR_BOUND

    # shared randomized trace through both engines
    rng = np.random.default_rng(seed)
    mk = lambda exact: SloTelemetry(slo_seconds=0.3, window_seconds=20.0,
                                    clock=lambda: t, exact=exact)
    t = 0.0
    sk, ex = mk(False), mk(True)
    for _ in range(4000):
        t += float(rng.exponential(0.004))
        lat = float(rng.lognormal(-2.0, 0.8))
        for eng in (sk, ex):
            eng.record_arrival(t)
            eng.record_served(lat, t)
    s_sk, s_ex = sk.snapshot(), ex.snapshot()
    bw = sk.window / sk.n_buckets
    mu = 1.0 / 0.05
    tq_err = abs(sk.queueing_bound(mu, 0.01)
                 - ex.queueing_bound(mu, 0.01))
    fid = {
        "counts_equal": bool(
            s_sk.n_arrivals == s_ex.n_arrivals
            and s_sk.n_served == s_ex.n_served
            and s_sk.n_shed == s_ex.n_shed),
        "violation_rate_equal": bool(
            abs(s_sk.violation_rate - s_ex.violation_rate) < 1e-12),
        "p50_rel_err": abs(s_sk.p50 - s_ex.p50) / max(s_ex.p50, 1e-12),
        "p99_rel_err": abs(s_sk.p99 - s_ex.p99) / max(s_ex.p99, 1e-12),
        "rel_err_bound": REL_ERR_BOUND,
        "tq_abs_err": tq_err,
        "bucket_width": bw,
    }

    # end-to-end: seeded DES controller decisions identical per engine
    zoo, costs, f_a = synthetic_testbed(seed=0)
    sched = [(3, 24), (4, 72), (3, 24)]
    a_sk = run_adaptive_sim(zoo, costs, f_a, 1.0, sched, adaptive=True,
                            seed=seed, telemetry_exact=False)
    a_ex = run_adaptive_sim(zoo, costs, f_a, 1.0, sched, adaptive=True,
                            seed=seed, telemetry_exact=True)
    t_sk = run_tiered_sim(zoo, costs, f_a, 1.0, sched, seed=seed,
                          telemetry_exact=False)
    t_ex = run_tiered_sim(zoo, costs, f_a, 1.0, sched, seed=seed,
                          telemetry_exact=True)
    fid["adaptive_decisions_equal"] = bool(
        a_sk["actions"] == a_ex["actions"])
    fid["tiered_decisions_equal"] = bool(
        t_sk["actions"] == t_ex["actions"])
    fid["n_actions"] = len(a_sk["actions"]) + len(t_sk["actions"])
    if verbose:
        print(f"  sketch fidelity: counts_equal {fid['counts_equal']}  "
              f"p50 err {fid['p50_rel_err']:.4f}  "
              f"p99 err {fid['p99_rel_err']:.4f} "
              f"(bound {REL_ERR_BOUND:.4f})  tq err "
              f"{tq_err:.4f} (bucket {bw:.4f})")
        print(f"  decisions: adaptive "
              f"{fid['adaptive_decisions_equal']}  tiered "
              f"{fid['tiered_decisions_equal']}  "
              f"({fid['n_actions']} actions compared)")
    return fid


# ------------------------------------------------------------ export lane
def run_export(tracer, svc, verbose: bool = True) -> Dict:
    """Render/scrape/dump the export plane around a live traced run."""
    from repro.control.telemetry import SloTelemetry
    from repro.obs.export import (MetricsExporter, start_metrics_server,
                                  write_spans_jsonl)
    from repro.serving.server import EnsembleServer

    telemetry = SloTelemetry(slo_seconds=1.0, window_seconds=10.0)
    srv = EnsembleServer(batch_handler=svc.predict_batch, n_workers=2,
                         telemetry=telemetry, tracer=tracer).start()
    rng = np.random.default_rng(0)
    for p in range(8):
        srv.submit(p, {"ecg": rng.standard_normal(
            (3, 250)).astype(np.float32)})
    srv.drain(timeout=30.0)

    exporter = MetricsExporter(server=srv, telemetry=telemetry,
                               tracer=tracer, service=svc)
    text = exporter.render()
    series = sum(1 for ln in text.splitlines()
                 if ln and not ln.startswith("#"))

    httpd = start_metrics_server(exporter, port=0)
    try:
        url = f"http://127.0.0.1:{httpd.server_port}/metrics"
        with urllib.request.urlopen(url, timeout=10) as resp:
            status = resp.status
            body = resp.read()
    finally:
        httpd.shutdown()
    srv.stop()

    with tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False) as f:
        jsonl_path = f.name
    try:
        n_spans = write_spans_jsonl(tracer, jsonl_path)
        with open(jsonl_path) as f:
            for line in f:
                json.loads(line)            # every line parses
    finally:
        os.unlink(jsonl_path)

    out = {
        "prometheus_bytes": len(text.encode()),
        "prometheus_series": series,
        "http_status": status,
        "http_bytes": len(body),
        "jsonl_spans": n_spans,
    }
    if verbose:
        print(f"  export: {series} series / "
              f"{out['prometheus_bytes']} B text, HTTP {status} "
              f"({out['http_bytes']} B), {n_spans} spans JSONL")
    return out


# --------------------------------------------------------------- schema
def check_obs_schema(data: Dict) -> None:
    for k in OBS_KEYS:
        assert k in data, f"missing obs key: {k}"
    assert data["overhead_ok"] is True, \
        (f"tracing overhead {data['overhead_pct']:.2f}% over budget "
         f"{data['overhead_budget_pct']}%")
    assert data["coverage_ok"] is True, \
        f"stage attribution coverage {data['coverage']:.3f} not in [0.9, 1.1]"
    assert data["attribution"]["n_spans"] > 0, "no spans recorded"
    fid = data["sketch_fidelity"]
    for k in SKETCH_KEYS:
        assert k in fid, f"missing sketch_fidelity key: {k}"
    assert fid["counts_equal"] is True
    assert fid["violation_rate_equal"] is True
    assert fid["p50_rel_err"] <= fid["rel_err_bound"], "p50 outside bound"
    assert fid["p99_rel_err"] <= fid["rel_err_bound"], "p99 outside bound"
    assert fid["tq_abs_err"] <= fid["bucket_width"] + 1e-9, \
        "T_q bound off by more than one bucket"
    assert fid["adaptive_decisions_equal"] is True, \
        "sketch flipped an adaptive-controller decision"
    assert fid["tiered_decisions_equal"] is True, \
        "sketch flipped a tiered-controller decision"
    assert fid["n_actions"] > 0, "DES runs took no actions to compare"
    exp = data["export"]
    for k in EXPORT_KEYS:
        assert k in exp, f"missing export key: {k}"
    assert exp["http_status"] == 200
    assert exp["prometheus_series"] >= 20, "suspiciously few series"
    assert exp["jsonl_spans"] > 0


def check_obs_file(path: str = BENCH_JSON) -> None:
    """CI gate on the committed BENCH_obs.json."""
    with open(path) as f:
        data = json.load(f)
    check_obs_schema(data)
    print(f"obs schema OK ({path})")


# ------------------------------------------------------------------ main
def bench_obs(n_patients: int = 64, windows_per_patient: int = 4,
              reps: int = 5, seed: int = 0,
              overhead_budget_pct: float = 5.0,
              write_json: bool = True, verbose: bool = True) -> Dict:
    if verbose:
        print(f"\nobservability bench ({n_patients} patients x "
              f"{windows_per_patient} windows x {reps} interleaved reps):")
    over, tracer, svc = run_overhead(
        n_patients, windows_per_patient, reps, seed=seed,
        overhead_budget_pct=overhead_budget_pct, verbose=verbose)
    over["sketch_fidelity"] = run_sketch_fidelity(seed=seed,
                                                  verbose=verbose)
    over["export"] = run_export(tracer, svc, verbose=verbose)
    check_obs_schema(over)
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(over, f, indent=2)
        check_obs_file()
    return over


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-trace CI invocation: relaxed overhead "
                         "gate, writes nothing")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        bench_obs(n_patients=8, windows_per_patient=2, reps=2,
                  seed=args.seed, overhead_budget_pct=50.0,
                  write_json=False)
        print("obs smoke OK (overhead + fidelity + export lanes)")
    else:
        bench_obs(seed=args.seed)
