"""Ensemble composition deep-dive: HOLMES vs all baselines (Table 2) with
the search trajectory (Fig. 6) and the accuracy-constrained dual (A.6).

    PYTHONPATH=src:. python examples/compose_ensemble.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.composition import bench_fig6, bench_table2
from benchmarks.zoo_setup import (binding_budget, build_zoo,
                                  make_profilers, single_model_stats)
from repro.core.composer import ComposerParams, compose
from repro.core.objective import AccuracyConstrainedObjective
from repro.core.profiles import SystemConfig


def accuracy_constrained_demo(zoo, extras):
    """A.6: min latency s.t. accuracy >= floor, same search machinery."""
    sysconf = SystemConfig(n_devices=2, n_patients=64)
    f_a, f_l = make_profilers(zoo, sysconf, extras)
    acc1, _ = single_model_stats(zoo, f_a, f_l)
    floor = float(np.quantile(acc1, 0.75))
    obj = AccuracyConstrainedObjective(floor)

    # reuse compose() by flipping the roles: maximize -latency with a
    # pseudo-"budget" on negative accuracy
    res = compose(len(zoo),
                  f_a=lambda b: -f_l(b),          # maximize -> min latency
                  f_l=lambda b: -f_a(b),          # constraint -> acc floor
                  latency_budget=-floor,
                  params=ComposerParams(N=8, K=6, seed=0))
    print(f"\nA.6 dual: accuracy floor {floor:.4f} -> "
          f"latency {-res.accuracy * 1000:.1f} ms at "
          f"accuracy {-res.latency:.4f} "
          f"(objective value {obj(-res.latency, -res.accuracy):.4f})")


def main():
    zoo, extras = build_zoo(n_patients=16, clips=8, steps=120)
    bench_table2(seeds=(0, 1), zoo=zoo, extras=extras)
    bench_fig6(zoo=zoo, extras=extras)
    accuracy_constrained_demo(zoo, extras)


if __name__ == "__main__":
    main()
