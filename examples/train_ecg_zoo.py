"""Train the paper's model zoo (§4.1.1) end-to-end: per-lead 1-D-stripe
ResNeXt classifiers across the width x depth grid, plus the vitals random
forest and labs logistic regression.  A few hundred optimizer steps per
model on the synthetic cohort (~100M-scale training overall).

    PYTHONPATH=src:. python examples/train_ecg_zoo.py [--steps 200]
"""
import argparse
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--patients", type=int, default=16)
    args = ap.parse_args()

    from benchmarks.zoo_setup import build_zoo
    zoo, extras = build_zoo(n_patients=args.patients, clips=8,
                            steps=args.steps)
    print("\nmodel zoo profiles (Table 3):")
    print(f"{'name':16s} {'depth':>5s} {'width':>5s} {'MACs':>10s} "
          f"{'mem(KB)':>8s} {'val AUC':>8s}")
    for p in zoo.profiles:
        print(f"{p.name:16s} {p.depth:5d} {p.width:5d} {p.macs:10.2e} "
              f"{p.memory_bytes / 1024:8.1f} {p.val_auc:8.4f}")
    aucs = [p.val_auc for p in zoo.profiles]
    print(f"\nzoo AUC range: {min(aucs):.3f} .. {max(aucs):.3f} "
          f"(spread is what the composer exploits)")


if __name__ == "__main__":
    main()
