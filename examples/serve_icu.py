"""End-to-end ICU serving driver: 64-bed discrete-event simulation of the
served ensemble (Fig. 10 conditions) + a real wall-clock fused-serving
demo (bucketed stacked dispatch + cross-patient micro-batching through
the batch-aware ``EnsembleServer``).

``--adaptive`` additionally exercises the online control plane against
a census spike (beds tripling mid-run): per-epoch telemetry drives the
controller (shed / warm-started recompose / climb) with the trained zoo
and measured member costs, and a real hot-swap segment shows selector
swaps mid-stream with zero dropped queries.

``--chaos`` runs a fault drill against the live fused server: a
deterministic ``FaultPlane`` schedule injects a transient device loss,
a worker stall, and a backpressure episode; the drill prints how each
fault was absorbed — served late, NaN-failed by the watchdog, or
counted rejected — with full query conservation.

    PYTHONPATH=src:. python examples/serve_icu.py [--beds 64] [--adaptive]
"""
import argparse
import sys
import os
import time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.zoo_setup import (binding_budget, build_zoo,
                                  make_profilers)
from repro.core.composer import ComposerParams, compose
from repro.core.profiles import SystemConfig
from repro.serving.latency import queueing_bound
from repro.serving.pipeline import EnsembleService, ZooMember
from repro.serving.server import EnsembleServer
from repro.serving.simulator import SimConfig, simulate
from repro.training.data import ecg_clip, sample_patient


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--beds", type=int, default=64)
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--minutes", type=float, default=3.0)
    ap.add_argument("--adaptive", action="store_true",
                    help="run the online control plane against a "
                         "census spike (beds tripling mid-run)")
    ap.add_argument("--tiered", action="store_true",
                    help="run the per-acuity-tier control plane: "
                         "stable beds shed first under the spike, "
                         "critical beds hold the rich ensemble")
    ap.add_argument("--chaos", action="store_true",
                    help="run a deterministic fault drill against the "
                         "live server: transient device loss, worker "
                         "stall, backpressure — every query accounted")
    ap.add_argument("--metrics", action="store_true",
                    help="attach the observability plane to the fused "
                         "serving demo: per-stage span attribution, a "
                         "live /metrics scrape, and a JSONL span dump")
    args = ap.parse_args()

    zoo, extras = build_zoo(n_patients=16, clips=8, steps=120)
    sysconf = SystemConfig(n_devices=args.devices, n_patients=args.beds)
    f_a, f_l = make_profilers(zoo, sysconf, extras)
    budget = binding_budget(zoo, f_l)
    res = compose(len(zoo), f_a, f_l, budget,
                  ComposerParams(N=8, K=6, seed=0))
    sel = np.flatnonzero(res.b_star)
    costs = [extras["measured_costs"][i] for i in sel]
    print(f"ensemble: {[zoo.profiles[i].name for i in sel]}")
    print(f"predicted latency {res.latency * 1000:.1f} ms "
          f"(budget {budget * 1000:.1f} ms)")

    cfg = SimConfig(n_patients=args.beds, n_devices=args.devices,
                    duration_seconds=args.minutes * 60,
                    window_seconds=30.0)
    r = simulate(costs, cfg)
    mu = args.devices / sum(costs)
    tq = queueing_bound(r.arrivals, mu, max(costs))
    print(f"\n{args.beds}-bed simulation, {args.minutes:.0f} min, "
          f"{args.beds * 250} qps ingest:")
    print(f"  queries served     : {len(r.queries)}")
    if len(r.queries):
        print(f"  p50 / p95 / max    : {r.p(50) * 1000:.1f} / "
              f"{r.p(95) * 1000:.1f} / "
              f"{r.latencies().max() * 1000:.1f} ms")
        print(f"  device utilization : {r.utilization:.2%}")
        print(f"  empirical max Tq   : "
              f"{r.queue_delays().max() * 1000:.1f} ms"
              f"  (network-calculus bound {tq * 1000:.1f} ms)")
        print(f"  sub-second p95     : {r.p(95) < 1.0}")
    else:
        print("  (duration shorter than one observation window — "
              "no sim queries)")

    # real wall-clock fused serving: the composed ensemble behind the
    # batch-aware server, windows from many beds coalesced per flush
    members = [ZooMember(extras["specs"][i],
                         extras["params"][zoo.profiles[i].name])
               for i in sel]
    svc = EnsembleService(members)
    svc.warmup(batch_sizes=(1, 2, 4, 8))      # pow2-padded flush sizes
    tracer = telem = None
    if args.metrics:
        from repro.control.telemetry import SloTelemetry
        from repro.obs.spans import SpanRecorder
        tracer = SpanRecorder()
        telem = SloTelemetry(slo_seconds=1.0, window_seconds=30.0)
    srv = EnsembleServer(batch_handler=svc.predict_batch,
                         n_workers=args.devices, max_batch=8,
                         max_wait_ms=2.0, telemetry=telem,
                         tracer=tracer).start()
    rng = np.random.default_rng(0)
    n_demo = min(args.beds, 16)
    d0 = svc.dispatch_count
    for bed in range(n_demo):
        pp = sample_patient(rng, bed % 2)
        srv.submit(bed, {"ecg": ecg_clip(rng, pp, seconds=3)})
    stats = srv.stop()
    print(f"\nfused wall-clock serving ({len(members)} members -> "
          f"{svc.n_buckets} buckets, {n_demo} beds):")
    print(f"  served             : {stats.served}")
    print(f"  p50 / p95          : {stats.p(50) * 1000:.1f} / "
          f"{stats.p(95) * 1000:.1f} ms")
    print(f"  jit dispatches     : {svc.dispatch_count - d0} "
          f"({(svc.dispatch_count - d0) / max(stats.served, 1):.2f}"
          f"/query; mean batch "
          f"{srv.batcher.stats.mean_batch:.1f})")

    if args.metrics:
        # where did each query's latency go? — the span recorder
        # attributed every retired query across queue / coalesce /
        # marshal / dispatch / gather, and the exporter publishes the
        # same numbers as Prometheus text + JSONL traces
        import tempfile
        import urllib.request
        from repro.obs.export import (MetricsExporter,
                                      start_metrics_server,
                                      write_spans_jsonl)
        att = tracer.attribution()
        stage_ms = {k: 1e3 * v / max(att["n_spans"], 1)
                    for k, v in att["stage_seconds"].items()}
        print(f"\nobservability plane ({att['n_spans']} spans, "
              f"coverage {att['coverage']:.3f}):")
        print("  per-query stage ms : "
              + "  ".join(f"{k} {v:.2f}" for k, v in stage_ms.items()))
        exporter = MetricsExporter(server=srv, telemetry=telem,
                                   tracer=tracer, service=svc)
        httpd = start_metrics_server(exporter, port=0)
        try:
            url = f"http://127.0.0.1:{httpd.server_port}/metrics"
            with urllib.request.urlopen(url, timeout=10) as resp:
                body = resp.read().decode()
        finally:
            httpd.shutdown()
        n_series = sum(1 for ln in body.splitlines()
                       if ln and not ln.startswith("#"))
        print(f"  /metrics scrape    : {n_series} series from {url}")
        with tempfile.NamedTemporaryFile(suffix=".jsonl",
                                         delete=False) as f:
            n = write_spans_jsonl(tracer, f.name)
            print(f"  JSONL span dump    : {n} spans -> {f.name}")

    # device-resident ingest: the same beds stream 250-sample chunks
    # into on-device ring buffers; a closed window is submitted as a
    # DeviceWindowRef (three host ints) and the flush gathers + lead-
    # expands it on device — no per-member H2D marshaling at all
    from repro.configs.ecg_zoo import ECG_LEADS
    from repro.serving.aggregator import DeviceIngest, ModalitySpec
    clip_len = members[0].spec.input_len
    di = DeviceIngest([ModalitySpec("ecg", float(clip_len), ECG_LEADS)],
                      n_patients=n_demo, window_seconds=1.0)
    di.warm_gather(lens=tuple({m.spec.input_len for m in members}))
    h0, q0 = svc.h2d_bytes, svc.dispatch_count
    srv2 = EnsembleServer(batch_handler=svc.predict_batch,
                          n_workers=args.devices, max_batch=8,
                          max_wait_ms=2.0).start()
    for bed in range(n_demo):
        pp = sample_patient(rng, bed % 2)
        ecg = ecg_clip(rng, pp, seconds=3)
        for off in range(0, ecg.shape[-1], 250):
            di.ingest(off / 250.0, bed, "ecg", ecg[:, off:off + 250])
        srv2.submit(bed, di.close_window(bed, 1.0))
    stats2 = srv2.stop()
    print(f"\ndevice-resident ingest ({n_demo} beds, ring-buffered "
          f"250 Hz chunks, on-device lead-gather):")
    print(f"  served             : {stats2.served}")
    print(f"  p50 / p95          : {stats2.p(50) * 1000:.1f} / "
          f"{stats2.p(95) * 1000:.1f} ms")
    print(f"  jit dispatches     : {svc.dispatch_count - q0} "
          f"({(svc.dispatch_count - q0) / max(stats2.served, 1):.2f}"
          f"/query)")
    print(f"  flush H2D          : "
          f"{(svc.h2d_bytes - h0) / max(stats2.served, 1):.0f} B/query"
          f" (vs {ECG_LEADS * clip_len * 4} B/query packed, "
          f"{len(members) * clip_len * 4} B/query pre-refactor)")

    if args.chaos:
        # chaos drill: the same fused service behind a watchdogged,
        # priority-bounded server, with a seeded fault schedule fired
        # against it.  The transient device loss is ridden out by the
        # protect() retry loop (queries served LATE, heart-beating so
        # the watchdog knows they are alive); the injected stall never
        # heart-beats, so the watchdog NaN-fails that co-batch and
        # respawns the worker; the backpressure episode floods stable
        # beds and the priority queue sheds them first.
        from repro.control.faults import FaultEvent, FaultPlane
        schedule = [
            FaultEvent(t=0.2, kind="device_loss", target=0, duration=0.6),
            FaultEvent(t=1.0, kind="worker_stall", duration=0.8),
            FaultEvent(t=1.6, kind="backpressure", duration=0.5),
        ]
        plane = FaultPlane(schedule)
        guarded = plane.protect(lambda ws, *_tier: svc.predict_batch(ws),
                                heartbeat=lambda: srv3.heartbeat())
        srv3 = EnsembleServer(
            batch_handler=guarded, n_workers=2, max_batch=4,
            max_wait_ms=2.0, max_queue=8,
            tier_of=lambda bed: "critical" if bed % 4 == 0 else "stable",
            tier_priority={"critical": 1.0, "stable": 0.0},
            deadline_seconds=0.5).start()
        svc.dispatch_guard = plane.guard
        plane.arm()           # clock starts AFTER all compilation above
        submitted = 0
        while plane.now() < 2.5 or not plane.done():
            bed = submitted % n_demo
            pp = sample_patient(rng, bed % 2)
            win = {"ecg": ecg_clip(rng, pp, seconds=3)}
            srv3.submit(bed, win)
            submitted += 1
            if plane.backpressure_active():   # overrun the stable tier
                for b in range(n_demo):
                    if b % 4 != 0:
                        srv3.submit(b, win)
                        submitted += 1
            time.sleep(0.03)
        stats3 = srv3.stop(join_timeout=5.0)
        svc.dispatch_guard = None
        rej = sum(stats3.rejected.values())
        print(f"\nchaos drill (transient device loss, worker stall, "
              f"backpressure):")
        print(f"  submitted / served : {submitted} / {stats3.served}")
        print(f"  NaN-failed (stall) : {stats3.failed}  "
              f"(watchdog stalls {stats3.stalls})")
        print(f"  rejected           : {rej} "
              f"(critical {stats3.rejected.get('critical', 0)}, "
              f"stable {stats3.rejected.get('stable', 0)})")
        print(f"  conservation       : "
              f"{stats3.served + stats3.shed == submitted} "
              f"(served + shed == submitted)")
        for r in plane.recoveries:
            print(f"  recovery           : t={r['t']:.2f}s "
                  f"{r['kind']} device {r['target']}")
        print(f"  leaked threads     : {srv3.leaked or 'none'}")

    if args.tiered:
        # per-acuity-tier degradation: the same spike, but the unit of
        # actuation is a TIER — stable beds shed first (and climb
        # last), critical beds keep the composed rich ensemble
        from benchmarks.adaptive_bench import run_tiered_sim
        schedule = [(3, args.beds), (4, 3 * args.beds), (3, args.beds)]
        print(f"\ntiered control plane (census "
              f"{' -> '.join(str(c) for _, c in schedule)}, "
              f"SLO {budget * 1000:.0f} ms):")
        td = run_tiered_sim(zoo=zoo, costs=extras["measured_costs"],
                            f_a=f_a, slo=budget, schedule=schedule,
                            n_devices=args.devices, verbose=True)
        crit = list(td["tier_fracs"])[-1]
        stab = list(td["tier_fracs"])[0]
        print(f"  critical: viol "
              f"{td['per_tier'][crit]['violation_rate']:.2f}  "
              f"acc {td['per_tier'][crit]['mean_accuracy']:.3f}  "
              f"min rung {td['per_tier'][crit]['min_rung']}")
        print(f"  stable  : viol "
              f"{td['per_tier'][stab]['violation_rate']:.2f}  "
              f"acc {td['per_tier'][stab]['mean_accuracy']:.3f}  "
              f"min rung {td['per_tier'][stab]['min_rung']}")

    if not args.adaptive:
        return

    # ------------------------------------------- online control plane
    # the same closed loop as benchmarks/adaptive_bench, but with the
    # TRAINED zoo and its measured per-member costs: census triples
    # mid-run, the static selector from above stays frozen, the
    # adaptive one sheds / recomposes / climbs
    from benchmarks.adaptive_bench import (run_adaptive_sim,
                                           wallclock_hot_swap)

    schedule = [(3, args.beds), (4, 3 * args.beds), (3, args.beds)]
    print(f"\nadaptive control plane (census "
          f"{' -> '.join(str(c) for _, c in schedule)}, "
          f"SLO {budget * 1000:.0f} ms):")
    common = dict(zoo=zoo, costs=extras["measured_costs"], f_a=f_a,
                  slo=budget, schedule=schedule,
                  n_devices=args.devices, verbose=True)
    st = run_adaptive_sim(adaptive=False, **common)
    ad = run_adaptive_sim(adaptive=True, **common)
    print(f"  static  : viol {st['violation_rate']:.2f}  "
          f"p99@spike {st['p99_final_spike_s'] * 1000:.0f} ms")
    print(f"  adaptive: viol {ad['violation_rate']:.2f}  "
          f"p99@spike {ad['p99_final_spike_s'] * 1000:.0f} ms  "
          f"({ad['n_recomposes']} recomposes)")

    # real hot-swap mid-stream on the trained members: the full zoo is
    # the pool, selectors toggle between the composed ensemble and its
    # cheapest member; every submitted query is served across the swaps
    pool = [ZooMember(extras["specs"][i],
                      extras["params"][zoo.profiles[i].name])
            for i in range(len(zoo))]
    cheap = np.zeros(len(zoo), np.int8)
    cheap[int(np.argmin(extras["measured_costs"]))] = 1
    swap = wallclock_hot_swap(
        n_queries=3 * n_demo, n_swaps=2, pool=pool,
        sel_a=res.b_star, sel_b=cheap, n_workers=args.devices,
        window_fn=lambda r_, i: {"ecg": ecg_clip(
            r_, sample_patient(r_, i % 2), seconds=3)},
        verbose=False)
    print(f"  hot-swap mid-stream: {swap['served']}/{swap['submitted']} "
          f"served across {swap['swaps']} swaps "
          f"({swap['dropped']} dropped)")


if __name__ == "__main__":
    main()
