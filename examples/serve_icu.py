"""End-to-end ICU serving driver: 64-bed discrete-event simulation of the
served ensemble (Fig. 10 conditions) + a real wall-clock fused-serving
demo (bucketed stacked dispatch + cross-patient micro-batching through
the batch-aware ``EnsembleServer``).

    PYTHONPATH=src:. python examples/serve_icu.py [--beds 64]
"""
import argparse
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.zoo_setup import (binding_budget, build_zoo,
                                  make_profilers)
from repro.core.composer import ComposerParams, compose
from repro.core.profiles import SystemConfig
from repro.serving.latency import queueing_bound
from repro.serving.pipeline import EnsembleService, ZooMember
from repro.serving.server import EnsembleServer
from repro.serving.simulator import SimConfig, simulate
from repro.training.data import ecg_clip, sample_patient


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--beds", type=int, default=64)
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--minutes", type=float, default=3.0)
    args = ap.parse_args()

    zoo, extras = build_zoo(n_patients=16, clips=8, steps=120)
    sysconf = SystemConfig(n_devices=args.devices, n_patients=args.beds)
    f_a, f_l = make_profilers(zoo, sysconf, extras)
    budget = binding_budget(zoo, f_l)
    res = compose(len(zoo), f_a, f_l, budget,
                  ComposerParams(N=8, K=6, seed=0))
    sel = np.flatnonzero(res.b_star)
    costs = [extras["measured_costs"][i] for i in sel]
    print(f"ensemble: {[zoo.profiles[i].name for i in sel]}")
    print(f"predicted latency {res.latency * 1000:.1f} ms "
          f"(budget {budget * 1000:.1f} ms)")

    cfg = SimConfig(n_patients=args.beds, n_devices=args.devices,
                    duration_seconds=args.minutes * 60,
                    window_seconds=30.0)
    r = simulate(costs, cfg)
    mu = args.devices / sum(costs)
    tq = queueing_bound(r.arrivals, mu, max(costs))
    print(f"\n{args.beds}-bed simulation, {args.minutes:.0f} min, "
          f"{args.beds * 250} qps ingest:")
    print(f"  queries served     : {len(r.queries)}")
    if len(r.queries):
        print(f"  p50 / p95 / max    : {r.p(50) * 1000:.1f} / "
              f"{r.p(95) * 1000:.1f} / "
              f"{r.latencies().max() * 1000:.1f} ms")
        print(f"  device utilization : {r.utilization:.2%}")
        print(f"  empirical max Tq   : "
              f"{r.queue_delays().max() * 1000:.1f} ms"
              f"  (network-calculus bound {tq * 1000:.1f} ms)")
        print(f"  sub-second p95     : {r.p(95) < 1.0}")
    else:
        print("  (duration shorter than one observation window — "
              "no sim queries)")

    # real wall-clock fused serving: the composed ensemble behind the
    # batch-aware server, windows from many beds coalesced per flush
    members = [ZooMember(extras["specs"][i],
                         extras["params"][zoo.profiles[i].name])
               for i in sel]
    svc = EnsembleService(members)
    svc.warmup(batch_sizes=(1, 2, 4, 8))      # pow2-padded flush sizes
    srv = EnsembleServer(batch_handler=svc.predict_batch,
                         n_workers=args.devices, max_batch=8,
                         max_wait_ms=2.0).start()
    rng = np.random.default_rng(0)
    n_demo = min(args.beds, 16)
    d0 = svc.dispatch_count
    for bed in range(n_demo):
        pp = sample_patient(rng, bed % 2)
        srv.submit(bed, {"ecg": ecg_clip(rng, pp, seconds=3)})
    stats = srv.stop()
    print(f"\nfused wall-clock serving ({len(members)} members -> "
          f"{svc.n_buckets} buckets, {n_demo} beds):")
    print(f"  served             : {stats.served}")
    print(f"  p50 / p95          : {stats.p(50) * 1000:.1f} / "
          f"{stats.p(95) * 1000:.1f} ms")
    print(f"  jit dispatches     : {svc.dispatch_count - d0} "
          f"({(svc.dispatch_count - d0) / max(stats.served, 1):.2f}"
          f"/query; mean batch "
          f"{srv.batcher.stats.mean_batch:.1f})")


if __name__ == "__main__":
    main()
