"""End-to-end ICU serving driver: 64-bed discrete-event simulation of the
served ensemble (Fig. 10 conditions) + a real wall-clock streaming demo.

    PYTHONPATH=src:. python examples/serve_icu.py [--beds 64]
"""
import argparse
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.zoo_setup import (binding_budget, build_zoo,
                                  make_profilers)
from repro.core.composer import ComposerParams, compose
from repro.core.profiles import SystemConfig
from repro.serving.latency import queueing_bound
from repro.serving.simulator import SimConfig, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--beds", type=int, default=64)
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--minutes", type=float, default=3.0)
    args = ap.parse_args()

    zoo, extras = build_zoo(n_patients=16, clips=8, steps=120)
    sysconf = SystemConfig(n_devices=args.devices, n_patients=args.beds)
    f_a, f_l = make_profilers(zoo, sysconf, extras)
    budget = binding_budget(zoo, f_l)
    res = compose(len(zoo), f_a, f_l, budget,
                  ComposerParams(N=8, K=6, seed=0))
    sel = np.flatnonzero(res.b_star)
    costs = [extras["measured_costs"][i] for i in sel]
    print(f"ensemble: {[zoo.profiles[i].name for i in sel]}")
    print(f"predicted latency {res.latency * 1000:.1f} ms "
          f"(budget {budget * 1000:.1f} ms)")

    cfg = SimConfig(n_patients=args.beds, n_devices=args.devices,
                    duration_seconds=args.minutes * 60,
                    window_seconds=30.0)
    r = simulate(costs, cfg)
    mu = args.devices / sum(costs)
    tq = queueing_bound(r.arrivals, mu, max(costs))
    print(f"\n{args.beds}-bed simulation, {args.minutes:.0f} min, "
          f"{args.beds * 250} qps ingest:")
    print(f"  queries served     : {len(r.queries)}")
    print(f"  p50 / p95 / max    : {r.p(50) * 1000:.1f} / "
          f"{r.p(95) * 1000:.1f} / {r.latencies().max() * 1000:.1f} ms")
    print(f"  device utilization : {r.utilization:.2%}")
    print(f"  empirical max Tq   : {r.queue_delays().max() * 1000:.1f} ms"
          f"  (network-calculus bound {tq * 1000:.1f} ms)")
    sub_second = r.p(95) < 1.0
    print(f"  sub-second p95     : {sub_second}")


if __name__ == "__main__":
    main()
