"""Quickstart: the full HOLMES loop in miniature, on CPU, in ~2 minutes.

1. Generate a synthetic ICU cohort and train a small ECG model zoo.
2. Profile accuracy (true bagging on validation) + latency (network
   calculus over measured per-member costs).
3. Compose the ensemble with HOLMES (Algorithm 1) under a latency budget.
4. Deploy the chosen ensemble in the streaming pipeline and serve a few
   observation windows end-to-end.

    PYTHONPATH=src:. python examples/quickstart.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.zoo_setup import (binding_budget, build_zoo,
                                  make_profilers)
from repro.core.composer import ComposerParams, compose
from repro.core.profiles import SystemConfig
from repro.serving.pipeline import (EnsembleService, StreamingPipeline,
                                    ZooMember)
from repro.training.data import ecg_clip, sample_patient, vitals_clip


def main():
    print("== 1. train the model zoo (cached after first run) ==")
    zoo, extras = build_zoo(n_patients=16, clips=8, steps=120)

    print("\n== 2+3. compose the ensemble under a latency budget ==")
    sysconf = SystemConfig(n_devices=2, n_patients=8)
    f_a, f_l = make_profilers(zoo, sysconf, extras)
    budget = binding_budget(zoo, f_l)
    res = compose(len(zoo), f_a, f_l, budget,
                  ComposerParams(N=8, K=6, N0=10, seed=0))
    chosen = [zoo.profiles[i].name for i in np.flatnonzero(res.b_star)]
    print(f"budget {budget * 1000:.1f} ms -> ensemble {chosen}")
    print(f"val ROC-AUC {res.accuracy:.4f} @ latency "
          f"{res.latency * 1000:.1f} ms ({res.n_profiler_calls} "
          f"profiler calls)")

    print("\n== 4. serve it on a live stream ==")
    members = [ZooMember(extras["specs"][i],
                         extras["params"][zoo.profiles[i].name])
               for i in np.flatnonzero(res.b_star)]
    svc = EnsembleService(members, vitals_model=extras["vitals_model"],
                          labs_model=extras["labs_model"])
    svc.warmup()
    print(f"fused dispatch plan: {len(members)} members -> "
          f"{svc.n_buckets} stacked buckets per query")
    pipe = StreamingPipeline(svc, n_patients=2, window_seconds=3.0)
    rng = np.random.default_rng(0)
    for patient in range(2):
        pp = sample_patient(rng, patient % 2)
        t = 0.0
        for _ in range(3):                    # three 3-second windows
            ecg = ecg_clip(rng, pp, seconds=3)
            vit = vitals_clip(rng, pp, seconds=3)
            pipe.feed(t, patient, "vitals", vit)
            rec = pipe.feed(t + 3.0, patient, "ecg", ecg)
            t += 3.0
            if rec:
                print(f"  patient {patient} t={t:5.1f}s "
                      f"P(stable)={rec.score:.3f} "
                      f"latency={rec.latency * 1000:.1f} ms")
    lats = pipe.latencies()
    if len(lats):
        print(f"served {len(lats)} queries, p95 latency "
              f"{np.percentile(lats, 95) * 1000:.1f} ms")


if __name__ == "__main__":
    main()
