"""Device-resident streaming ingest + on-device lead-gather:

* ring-phase correctness: ``write_idx`` wraps at a MULTIPLE of the
  capacity (regression for the ``% 2**30`` shear on non-pow2 caps);
* ``ingest_chunk``'s pow2 chunk ladder — semantics equal to the
  per-length ``ingest_step``, compiled-variant count bounded under
  mixed-rate feeds;
* the Pallas ``window_gather`` kernel against the jnp oracle
  (interpret mode), including wraparound / dropout / padding rows;
* THE acceptance property: device-resident ingest + on-device
  lead-gather scores BITWISE-identical to the ``PatientAggregator`` +
  host-marshaling path, across ring wraparound, sensor dropout
  (zero-fill), short-window left-padding, every pow2 flush-ladder
  rung, and (via the ``multi_device`` lane) the sharded 8-device path;
* the warmed pow2 flush ladder: no compile on the flush path after
  ``warmup()``;
* device refs flowing through the batch-aware server and a zero-drop
  hot swap mid-stream.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.serving.aggregator import (DeviceIngest, ModalitySpec,
                                      agg_init, chunk_rung,
                                      gather_windows, ingest_chunk,
                                      ingest_step, read_window_static,
                                      ring_wrap)
from repro.serving.pipeline import EnsembleService, StreamingPipeline

N_FORCED = 8
IN_LANE = jax.device_count() >= N_FORCED
multi_device = pytest.mark.multi_device
needs_devices = pytest.mark.skipif(
    not IN_LANE,
    reason=f"needs {N_FORCED} forced host devices (CI lane or the "
           "subprocess wrapper below)")


# ------------------------------------------------------------ ring phase
def test_ring_wrap_is_multiple_of_capacity():
    for cap in (1, 7, 8, 10, 12, 100, 512, 7500, 2 ** 20):
        w = ring_wrap(cap)
        assert w % cap == 0
        assert 0 < w <= 2 ** 30
    assert ring_wrap(512) == 2 ** 30      # pow2 caps keep the old wrap


def test_write_idx_wrap_preserves_ring_phase_non_pow2_cap():
    """Regression: wrapping ``write_idx`` at a modulus that is NOT a
    multiple of the capacity shears the ring after the wrap (the old
    ``% 2**30`` with e.g. cap=12).  Seed the counter just below the
    wrap point and stream across it: the ring must stay consistent
    with a plain host-side tail."""
    cap = 12                              # does not divide 2**30
    st = agg_init(n_patients=1, channels=1, capacity=cap)
    wrap = ring_wrap(cap)
    # shifting write_idx by a multiple of cap is semantically inert,
    # so this fast-forward is equivalent to actually streaming
    # wrap - 2*cap samples
    st = st._replace(write_idx=st.write_idx + (wrap - 2 * cap))
    stream = []
    rng = np.random.default_rng(0)
    for k in (5, 7, 4, 9, 6):             # 31 samples: crosses wrap
        c = rng.standard_normal((1, k)).astype(np.float32)
        stream.append(c)
        st = ingest_chunk(st, 0, c)
    full = np.concatenate(stream, -1)
    got = np.asarray(read_window_static(st, 0, cap))
    np.testing.assert_array_equal(got, full[:, -cap:])
    assert int(st.write_idx[0]) < wrap    # counter actually wrapped


def test_ingest_step_wrap_matches_chunk_path():
    st_a = agg_init(1, 2, 8)
    st_b = agg_init(1, 2, 8)
    rng = np.random.default_rng(1)
    for k in (3, 1, 5, 2, 8, 4):
        c = rng.standard_normal((2, k)).astype(np.float32)
        st_a = ingest_step(st_a, jnp.asarray(0), jnp.asarray(c))
        st_b = ingest_chunk(st_b, 0, c)
    np.testing.assert_array_equal(np.asarray(st_a.buf),
                                  np.asarray(st_b.buf))
    assert int(st_a.total[0]) == int(st_b.total[0]) == 23


# ------------------------------------------------------------ chunk ladder
def test_chunk_rung_is_pow2_ladder():
    assert [chunk_rung(k) for k in (1, 2, 3, 4, 5, 9, 250, 257)] \
        == [1, 2, 4, 4, 8, 16, 256, 512]


def test_ingest_chunk_bounded_retrace_under_mixed_rates():
    """Mixed-rate feeds (every chunk length 1..64) must compile at most
    one variant per pow2 rung, not one per length."""
    from repro.serving.aggregator import _ingest_padded
    st = agg_init(1, 1, 128)
    before = _ingest_padded._cache_size()
    lens = list(range(1, 65))
    np.random.default_rng(2).shuffle(lens)
    for k in lens:
        st = ingest_chunk(st, 0, np.zeros((1, k), np.float32))
    grew = _ingest_padded._cache_size() - before
    assert grew <= len({chunk_rung(k) for k in lens}) == 7
    assert int(st.total[0]) == sum(lens)


def test_ingest_chunk_rejects_oversized_chunk():
    st = agg_init(1, 1, 16)
    with pytest.raises(ValueError):
        ingest_chunk(st, 0, np.zeros((1, 17), np.float32))


# --------------------------------------------------- window-gather kernel
def _random_ring(rng, n=3, c=2, cap=16, feeds=(11, 30, 5)):
    st = agg_init(n, c, cap)
    streams = {p: [] for p in range(n)}
    for p, total in enumerate(feeds):
        off = 0
        while off < total:
            k = min(int(rng.integers(1, 7)), total - off)
            chunk = rng.standard_normal((c, k)).astype(np.float32)
            streams[p].append(chunk)
            st = ingest_chunk(st, p, chunk)
            off += k
    return st, {p: (np.concatenate(s, -1) if s
                    else np.zeros((c, 0), np.float32))
                for p, s in streams.items()}


def test_window_gather_ref_semantics():
    rng = np.random.default_rng(3)
    st, streams = _random_ring(rng)                   # feeds wrap cap=16
    L = 8
    patients = jnp.asarray([2, 0, 1, 0], jnp.int32)
    ends = jnp.asarray([5, 11, 30 % 16, 11], jnp.int32)
    valid = jnp.asarray([5, 8, 8, 3], jnp.int32)      # incl. dropout row
    got = np.asarray(gather_windows(st.buf, patients, ends, valid, L))
    for i, (p, e, v) in enumerate(((2, 5, 5), (0, 11, 8),
                                   (1, 30, 8), (0, 11, 3))):
        tail = streams[p][:, :e][:, -min(v, L):]
        want = np.zeros((2, L), np.float32)
        if tail.shape[-1]:
            want[:, L - tail.shape[-1]:] = tail
        np.testing.assert_array_equal(got[i], want)


def test_window_gather_pallas_matches_ref():
    from repro.kernels.window_gather import window_gather
    rng = np.random.default_rng(4)
    st, _ = _random_ring(rng, n=4, c=3, cap=32, feeds=(40, 7, 33, 0))
    L = 16
    patients = jnp.asarray([0, 3, 2, 1], jnp.int32)
    ends = jnp.asarray([40 % 32, 0, 33 % 32, 7], jnp.int32)
    valid = jnp.asarray([16, 0, 9, 7], jnp.int32)     # pad row: valid=0
    want = kref.window_gather(st.buf, patients, ends, valid, L)
    got = window_gather(st.buf, patients, ends, valid, L,
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got)[1].sum() == 0.0            # padding row zero


# --------------------------------------------- service-level equivalence
def _ingest_windows(windows, window_seconds=1.0, chunks=(100, 75, 75)):
    """Stream host windows into a DeviceIngest and close one ref per
    patient; chunk sizes exercise the pow2 ladder."""
    di = DeviceIngest([ModalitySpec("ecg", 250.0, 3)],
                      n_patients=len(windows),
                      window_seconds=window_seconds)
    refs = []
    for p, w in enumerate(windows):
        ecg, off = np.asarray(w["ecg"], np.float32), 0
        for k in chunks:
            if off >= ecg.shape[-1]:
                break
            di.ingest(off / 250.0, p, "ecg", ecg[:, off:off + k])
            off += k
        while off < ecg.shape[-1]:
            di.ingest(off / 250.0, p, "ecg", ecg[:, off:off + 100])
            off += 100
        refs.append(di.close_window(p, window_seconds))
    return di, refs


def test_refs_bitwise_every_ladder_rung(zoo_members, rng):
    """Device-resident flushes match the host-marshaled pack BITWISE at
    every pow2 flush rung (and the odd sizes that pad up to them)."""
    svc = EnsembleService(zoo_members)
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(8)]
    _, refs = _ingest_windows(windows)
    for P in (1, 2, 3, 5, 8):
        want = svc.predict_batch(windows[:P])
        got = svc.predict_batch(refs[:P])
        assert np.array_equal(np.asarray(got), np.asarray(want)), P


def test_refs_bitwise_short_window_left_padding(zoo_members, rng):
    """A window holding fewer samples than input_len is left-zero-padded
    identically on both paths."""
    svc = EnsembleService(zoo_members)
    windows = [{"ecg": rng.standard_normal((3, n)).astype(np.float32)}
               for n in (40, 100, 249)]
    _, refs = _ingest_windows(windows, chunks=(30, 30, 40))
    got = svc.predict_batch(refs)
    want = svc.predict_batch(windows)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_refs_bitwise_after_ring_wraparound(zoo_members, rng):
    """Several windows per patient: the ring (capacity 2 windows) wraps
    and the LAST window must still score bitwise-identically."""
    svc = EnsembleService(zoo_members)
    di = DeviceIngest([ModalitySpec("ecg", 250.0, 3)], n_patients=2,
                      window_seconds=1.0)
    cap = di.states["ecg"].buf.shape[-1]
    last = {}
    ref = {}
    for p in range(2):
        for w in range(4):                 # 4 x 250 samples > cap=512
            ecg = rng.standard_normal((3, 250)).astype(np.float32)
            for off in range(0, 250, 50):
                di.ingest(w + off / 250.0, p, "ecg",
                          ecg[:, off:off + 50])
            ref[p] = di.close_window(p, w + 1.0)
            last[p] = ecg
        assert int(di.fed["ecg"][p]) == 1000 > cap
    got = svc.predict_batch([ref[0], ref[1]])
    want = svc.predict_batch([{"ecg": last[0]}, {"ecg": last[1]}])
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_refs_bitwise_sensor_dropout_zero_fill(zoo_members, rng):
    """Dropout mid-window: only 120 of 250 samples arrive; both paths
    zero-fill the missing head."""
    svc = EnsembleService(zoo_members)
    windows = [{"ecg": rng.standard_normal((3, 120)).astype(np.float32)}
               for _ in range(3)]
    _, refs = _ingest_windows(windows, chunks=(50, 50, 20))
    assert all(r.valid["ecg"] == 120 for r in refs)
    got = svc.predict_batch(refs)
    want = svc.predict_batch(windows)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_stale_ref_refused_not_silently_wrong(zoo_members, rng):
    """A ref whose ring region has been overwritten by later ingest
    must be REFUSED (the server's safe-batch wrapper then NaNs only the
    stale query) — never silently served with the wrong window's
    samples.  Refs within the capacity slack still serve bitwise."""
    svc = EnsembleService(zoo_members)
    di = DeviceIngest([ModalitySpec("ecg", 250.0, 3)], n_patients=1,
                      window_seconds=1.0)                 # cap = 512
    first = rng.standard_normal((3, 250)).astype(np.float32)
    di.ingest(0.0, 0, "ecg", first)
    ref = di.close_window(0, 1.0)
    # one more full window: 500 <= cap, the ref is still intact
    di.ingest(1.0, 0, "ecg",
              rng.standard_normal((3, 250)).astype(np.float32))
    got = svc.predict_batch([ref])
    assert np.array_equal(np.asarray(got),
                          np.asarray(svc.predict_batch([{"ecg":
                                                         first}])))
    # a third window pushes ingest past cap beyond the ref's window
    di.ingest(2.0, 0, "ecg",
              rng.standard_normal((3, 250)).astype(np.float32))
    with pytest.raises(ValueError, match="stale"):
        svc.predict_batch([ref])
    # the unfused oracle path reads back via host_window: same guard
    with pytest.raises(ValueError, match="stale"):
        EnsembleService(zoo_members, fused=False).predict(ref)


def test_stale_vitals_ring_refused(zoo_members, rng):
    """The low-rate vitals ring overruns on its own clock: a ref whose
    VITALS window was overwritten must be refused even while its ECG
    window is still intact."""
    class Const:
        def predict_proba(self, x):
            return np.full(len(x), 0.5)

    svc = EnsembleService(zoo_members, vitals_model=Const())
    di = DeviceIngest([ModalitySpec("ecg", 250.0, 3),
                       ModalitySpec("vitals", 1.0, 7)],
                      n_patients=1, window_seconds=1.0)  # vitals cap=2
    di.ingest(0.0, 0, "ecg",
              rng.standard_normal((3, 250)).astype(np.float32))
    di.ingest(0.0, 0, "vitals",
              rng.standard_normal((7, 1)).astype(np.float32))
    ref = di.close_window(0, 1.0)
    assert 0.0 <= svc.predict(ref) <= 1.0      # fresh: serves fine
    di.ingest(1.0, 0, "vitals",
              rng.standard_normal((7, 2)).astype(np.float32))
    with pytest.raises(ValueError, match="vitals ring"):
        svc.predict(ref)                       # ECG intact, vitals gone


def test_refs_with_cpu_side_models(zoo_members, rng):
    """Vitals/labs CPU-side models join the bag identically: labs ride
    the ref's host side channel, vitals are read back from the ring."""
    class Const:
        def __init__(self, v):
            self.v = v

        def predict_proba(self, x):
            return np.full(len(x), self.v)

    svc = EnsembleService(zoo_members, vitals_model=Const(0.9),
                          labs_model=Const(0.1))
    di = DeviceIngest([ModalitySpec("ecg", 250.0, 3),
                       ModalitySpec("vitals", 1.0, 7)],
                      n_patients=1, window_seconds=1.0)
    ecg = rng.standard_normal((3, 250)).astype(np.float32)
    vit = rng.standard_normal((7, 1)).astype(np.float32)
    labs = rng.standard_normal(8).astype(np.float32)
    di.ingest(0.0, 0, "ecg", ecg)
    di.ingest(0.0, 0, "vitals", vit)
    r = di.close_window(0, 1.0, extra={"labs": labs})
    host_vit = np.zeros((7, 1), np.float32)
    host_vit[:, :] = vit                   # want=1 sample at 1 Hz
    want = svc.predict({"ecg": ecg, "vitals": host_vit, "labs": labs})
    assert svc.predict(r) == want
    # and without the models attached, the ref path never reads back
    bare = EnsembleService(zoo_members)
    assert bare.predict(r) == bare.predict({"ecg": ecg})


def test_refs_reject_legacy_marshal_and_mixed_ingest(zoo_members, rng):
    legacy = EnsembleService(zoo_members, marshal="legacy")
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(2)]
    _, refs_a = _ingest_windows(windows[:1])
    _, refs_b = _ingest_windows(windows[1:])
    with pytest.raises(ValueError):
        legacy.predict_batch(refs_a)
    svc = EnsembleService(zoo_members)
    with pytest.raises(ValueError):
        svc.predict_batch([refs_a[0], refs_b[0]])
    with pytest.raises(ValueError):
        EnsembleService(zoo_members, marshal="nope")


def test_legacy_marshal_matches_packed(zoo_members, rng):
    """The preserved pre-refactor marshaling loop is still a correct
    oracle for the packed path."""
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(5)]
    packed = EnsembleService(zoo_members)
    legacy = EnsembleService(zoo_members, marshal="legacy")
    np.testing.assert_allclose(packed.predict_batch(windows),
                               legacy.predict_batch(windows),
                               atol=1e-6)
    # the packed pack ships 3 leads once vs M member rows: M/3 less H2D
    assert legacy.h2d_bytes == 4 * packed.h2d_bytes


# ------------------------------------------------- pipeline equivalence
def _drive(pipe, feed):
    return [r.score for r in filter(None, (
        pipe.feed(t, p, m, s) for (t, p, m, s) in feed))]


def _full_rate_feed(rng, n_patients=2, n_windows=3, chunk=25,
                    window=1.0, drop=()):
    """Aligned contract feed: a uniform stream of ``chunk``-sample ECG
    bursts every chunk/250 s per patient starting at t=0, so every
    window closes exactly at its boundary (on the burst whose arrival
    crosses it).  ``drop`` lists (patient, burst_idx) bursts to
    withhold (sensor dropout) — never burst 0 or a window-closing
    burst, and only in the FIRST window under this boundary-aligned
    feed: the oracle's time-based retention re-reads a window-closing
    burst in the next window (count-based accounting attributes it to
    the window it closed), and only a full next window slices that
    boundary sample back out.  Arbitrary-window dropout is covered at
    the service level, where close times are explicit."""
    feed = []
    per_w = int(round(250 * window)) // chunk
    for j in range(n_windows * per_w + 1):
        t = j * (chunk / 250.0)
        for p in range(n_patients):
            if (p, j) in drop:
                continue
            feed.append((t, p, "ecg", rng.standard_normal(
                (3, chunk)).astype(np.float32)))
    return feed


def test_pipeline_device_vs_host_bitwise(zoo_members, rng):
    """End-to-end StreamingPipeline equivalence: same service, same
    stream, device rings vs python aggregators — identical scores,
    across enough windows to wrap the ring."""
    svc = EnsembleService(zoo_members)
    host = StreamingPipeline(svc, n_patients=2, window_seconds=1.0)
    dev = StreamingPipeline(svc, n_patients=2, window_seconds=1.0,
                            device_ingest=True)
    feed = _full_rate_feed(rng, n_windows=3)
    got, want = _drive(dev, feed), _drive(host, feed)
    assert len(want) == 2 * 3              # every window served
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert int(dev.device_ingest.fed["ecg"][0]) == 775 \
        > dev.device_ingest.states["ecg"].buf.shape[-1]   # wrapped


def test_pipeline_device_vs_host_with_dropout(zoo_members, rng):
    svc = EnsembleService(zoo_members)
    host = StreamingPipeline(svc, n_patients=2, window_seconds=1.0)
    dev = StreamingPipeline(svc, n_patients=2, window_seconds=1.0,
                            device_ingest=True)
    drop = {(0, 3), (0, 4), (1, 6)}      # first-window mid dropouts
    feed = _full_rate_feed(rng, n_windows=3, drop=drop)
    got, want = _drive(dev, feed), _drive(host, feed)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@multi_device
@needs_devices
def test_refs_bitwise_sharded_8_devices(zoo_members, rng):
    """The forced-8-device lane: device-resident flushes through a
    sharded placement equal the unsharded host path bitwise — the
    gathered pack is copied once per shard device, never per member."""
    from repro.configs.ecg_zoo import bucket_zoo
    from repro.serving.placement import grouped_lpt_placement
    groups = list(bucket_zoo([m.spec for m in zoo_members]).values())
    pl = grouped_lpt_placement(groups, [1.0 + 0.1 * j for j in
                                        range(len(groups))], N_FORCED)
    sharded = EnsembleService(zoo_members, placement=pl,
                              devices=jax.devices()[:N_FORCED])
    flat = EnsembleService(zoo_members)
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(6)]
    _, refs = _ingest_windows(windows)
    want = flat.predict_batch(windows)
    assert np.array_equal(np.asarray(sharded.predict_batch(refs)),
                          np.asarray(want))
    assert np.array_equal(np.asarray(sharded.predict_batch(windows)),
                          np.asarray(want))


@pytest.mark.skipif(IN_LANE, reason="already in the multi-device lane")
def test_multi_device_lane_subprocess():
    """Single-device lane: re-run this module's ``multi_device``
    selection under 8 forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={N_FORCED}")
    env.pop("PYTEST_CURRENT_TEST", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-m", "multi_device"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=900)
    tail = (r.stdout or "") + (r.stderr or "")
    assert r.returncode == 0, tail[-4000:]
    assert " passed" in r.stdout, tail[-2000:]
    assert " skipped" not in r.stdout, tail[-2000:]


# ----------------------------------------------------------- headroom
def test_headroom_min_across_modalities_not_ecg_only(rng):
    """Regression: ``DeviceIngest.headroom`` hardcoded the ECG ring, so
    a vitals ring about to overrun reported full slack and the
    backpressure guard admitted queries that went stale-then-NaN
    downstream.  The aggregate signal is now the MIN across modalities
    in window units (< 1.0 => shed); the per-ring sample views survive
    via the modality arg and ``headroom_by_modality``."""
    di = DeviceIngest([ModalitySpec("ecg", 250.0, 3),
                       ModalitySpec("vitals", 1.0, 7)],
                      n_patients=1, window_seconds=1.0)
    di.ingest(0.0, 0, "ecg",
              np.zeros((3, 250), np.float32))
    di.ingest(0.0, 0, "vitals", np.zeros((7, 1), np.float32))
    di.close_window(0, 1.0)
    assert di.headroom(0) >= 1.0          # fresh: >= one window of slack
    # the low-rate vitals ring overruns on its OWN clock while the ECG
    # ring still has hundreds of samples of slack
    di.ingest(1.0, 0, "vitals", np.zeros((7, 2), np.float32))
    di.ingest(2.0, 0, "vitals", np.zeros((7, 1), np.float32))
    by_mod = di.headroom_by_modality(0)
    assert by_mod["ecg"] >= 250           # per-ring: ecg fine...
    assert by_mod["vitals"] < 1           # ...vitals exhausted
    assert di.headroom(0, "ecg") == by_mod["ecg"]
    # pre-fix the aggregate WAS the ecg number (hundreds of samples);
    # now it must surface the vitals overrun as backpressure
    assert di.headroom(0) < 1.0


# ------------------------------------------------------- warmup ladder
def test_warmup_compiles_full_flush_ladder(zoo_members, rng):
    """After default ``warmup()`` every pow2 flush size 1..8 hits a
    compiled program: no bucket dispatch compiles on the flush path."""
    svc = EnsembleService(zoo_members)
    svc.warmup()
    sizes = {id(b.fn): b.fn._cache_size() for b in svc._buckets}
    for P in (1, 2, 3, 4, 5, 8):
        svc.predict_batch([{"ecg": rng.standard_normal((3, 250))
                            .astype(np.float32)}] * P)
    for b in svc._buckets:
        assert b.fn._cache_size() == sizes[id(b.fn)]


# ------------------------------------------------- server + hot swap
def test_server_serves_device_refs(zoo_members, rng):
    from repro.serving.server import EnsembleServer
    svc = EnsembleService(zoo_members)
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(12)]
    _, refs = _ingest_windows(windows)
    want = {p: svc.predict_batch(windows[p:p + 1])[0]
            for p in range(12)}
    srv = EnsembleServer(batch_handler=svc.predict_batch, n_workers=2,
                         max_batch=4, max_wait_ms=2.0).start()
    for p, r in enumerate(refs):
        assert srv.submit(p, r)
    stats = srv.stop()
    assert stats.served == 12
    for p, score, *_ in srv.results():
        # float tolerance: the server coalesces refs into flushes of
        # its own sizes, and different pow2 pads are different XLA
        # programs (same contract as the host-dict batching tests)
        assert score == pytest.approx(want[p], abs=1e-6)


def test_hot_swap_zero_drop_with_device_refs(zoo_members, rng):
    """Selector hot-swaps mid-stream under device-resident ingest: no
    query dropped, post-swap scores equal a cold service on the new
    selector fed the same refs."""
    from repro.control.swap import HotSwapper
    from repro.serving.server import EnsembleServer
    n = len(zoo_members)
    sel_a = np.ones(n, np.int8)
    sel_b = np.zeros(n, np.int8)
    sel_b[::2] = 1
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(18)]
    di, refs = _ingest_windows(windows)
    sw = HotSwapper(zoo_members, sel_a, warmup_batch_sizes=(1,))
    sw.stage(sel_b)
    srv = EnsembleServer(batch_handler=sw.facade.predict_batch,
                         n_workers=2, max_batch=1,
                         max_wait_ms=0.5).start()
    for p, r in enumerate(refs):
        if p == 9:
            sw.swap_to(sel_b)
        assert srv.submit(p, r)
    stats = srv.stop()
    assert stats.served == 18              # zero dropped across the swap
    cold = EnsembleService.for_selector(zoo_members, sel_b)
    scores = {p: s for p, s, *_ in srv.results()}
    for p in range(9, 18):
        assert scores[p] == cold.predict_batch([refs[p]])[0]


# ------------------------------------------------------- bench schema
def test_bench_ingest_smoke_schema():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.serving_bench import bench_ingest, \
        check_ingest_schema
    out = bench_ingest(n_patients=2, reps=1, input_len=250,
                       verbose=False, write_json=False)
    check_ingest_schema(out)
    assert out["h2d_reduction_x"] == pytest.approx(4.0)
