"""Ensemble-parallel serving (Eq. 5 as a collective): numerics on the
host mesh must equal plain bagging."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.ensemble_parallel import ensemble_serve, stack_members
from repro.launch.mesh import make_host_mesh


def test_ensemble_serve_equals_bagging():
    key = jax.random.PRNGKey(0)
    d, n_members = 16, 4

    def member_apply(p, batch):
        h = jnp.tanh(batch["x"] @ p["w1"])
        return jax.nn.softmax(h @ p["w2"], axis=-1)

    members = []
    for i in range(n_members):
        k1, k2, key = jax.random.split(key, 3)
        members.append({"w1": jax.random.normal(k1, (d, d)) * 0.3,
                        "w2": jax.random.normal(k2, (d, 2)) * 0.3})
    batch = {"x": jax.random.normal(key, (8, d))}

    want = jnp.mean(jnp.stack([member_apply(p, batch) for p in members]),
                    axis=0)
    mesh = make_host_mesh()
    step = ensemble_serve(member_apply, mesh, n_members)
    with mesh:
        got = jax.jit(step)(stack_members(members), batch)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_stack_members_shape():
    ms = [{"w": jnp.ones((3,)) * i} for i in range(5)]
    st = stack_members(ms)
    assert st["w"].shape == (5, 3)
    np.testing.assert_allclose(st["w"][:, 0], np.arange(5))
