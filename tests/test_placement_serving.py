"""Multi-device sharded ensemble placement: the controller-actuated
placement dimension, verified by a device-count-parametrized harness.

Three layers:

* pure LPT invariants (always run): member conservation, load/cost
  accounting, imbalance >= 1, makespan <= serial cost, monotone
  non-increasing makespan in device count, stability under duplicate
  costs — property-based via hypothesis (or the seeded shim);
* ``multi_device``-marked wall-clock tests (need 8 forced host
  devices): sharded ``predict``/``predict_batch`` bitwise-equal to the
  single-device path for every ladder selector at 1/2/4/8 devices,
  shard params actually pinned per plan, ``(selector, placement)``
  staging cache semantics, and zero-drop hot-swaps across placement
  changes with post-swap bitwise equality;
* a subprocess wrapper that, in the default single-device lane,
  re-runs the ``multi_device`` selection in a child process with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — so the
  sharded hot path is exercised on every tier-1 run, not only in the
  CI multi-device lane.
"""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, st

import jax

from repro.configs.ecg_zoo import bucket_zoo
from repro.serving.placement import (Placement, finish_imbalance,
                                     grouped_lpt_placement,
                                     lpt_placement, placement_signature,
                                     plan_pod_ensemble)
from repro.serving.pipeline import PLAN_BATCH, EnsembleService

N_FORCED = 8
IN_LANE = jax.device_count() >= N_FORCED
multi_device = pytest.mark.multi_device
needs_devices = pytest.mark.skipif(
    not IN_LANE,
    reason=f"needs {N_FORCED} forced host devices (CI lane or the "
           "subprocess wrapper below)")


# ---------------------------------------------------- LPT property tests
@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=24),
       st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_lpt_conserves_members_and_loads(costs, k):
    pl = lpt_placement(costs, k)
    # every member assigned exactly once
    placed = sorted(i for slot in pl.assignment for i in slot)
    assert placed == list(range(len(costs)))
    # per-slot loads are exactly the sums of the assigned costs
    for slot, load in zip(pl.assignment, pl.loads):
        assert load == pytest.approx(sum(costs[i] for i in slot))
    assert sum(pl.loads) == pytest.approx(sum(costs))


@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=24),
       st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_lpt_makespan_invariants(costs, k):
    pl = lpt_placement(costs, k)
    assert pl.imbalance >= 1.0 - 1e-12
    # parallelism can never be worse than serial execution...
    assert pl.makespan <= sum(costs) + 1e-9
    # ...nor better than the critical path / perfect split
    assert pl.makespan >= max(max(costs), sum(costs) / k) - 1e-9


@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=16))
@settings(max_examples=25, deadline=None)
def test_lpt_makespan_monotone_in_device_count(costs):
    """More devices never hurt: the re-place actuator relies on this to
    treat device-count growth as strictly-no-worse."""
    spans = [lpt_placement(costs, k).makespan for k in range(1, 9)]
    assert spans[0] == pytest.approx(sum(costs))
    for a, b in zip(spans, spans[1:]):
        assert b <= a + 1e-9


@given(st.integers(1, 12), st.integers(1, 8),
       st.floats(0.001, 1.0))
@settings(max_examples=25, deadline=None)
def test_lpt_stable_under_duplicate_costs(n, k, c):
    """All-equal costs: ties must break deterministically (stable sort +
    first-min slot), so two runs agree and staging caches stay hot."""
    costs = [c] * n
    p1, p2 = lpt_placement(costs, k), lpt_placement(costs, k)
    assert p1.assignment == p2.assignment
    assert p1.signature() == p2.signature()
    placed = sorted(i for slot in p1.assignment for i in slot)
    assert placed == list(range(n))
    # slot sizes differ by at most one member (round-robin under ties)
    sizes = sorted(len(s) for s in p1.assignment)
    assert sizes[-1] - sizes[0] <= 1


@given(st.lists(st.floats(0.01, 1.0), min_size=1, max_size=8),
       st.integers(1, 6), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_grouped_lpt_keeps_groups_atomic(group_costs, k, group_size):
    """Bucket-granularity planning: a stacked bucket is never split
    across devices, and the expansion covers every member once."""
    groups = [list(range(g * group_size, (g + 1) * group_size))
              for g in range(len(group_costs))]
    pl = grouped_lpt_placement(groups, group_costs, k)
    placed = sorted(m for slot in pl.assignment for m in slot)
    assert placed == list(range(len(group_costs) * group_size))
    for g in groups:                      # group lands on ONE slot whole
        owners = {i for i, slot in enumerate(pl.assignment)
                  if set(g) & set(slot)}
        assert len(owners) == 1
        assert set(g) <= set(pl.assignment[owners.pop()])
    assert pl.makespan <= sum(group_costs) + 1e-9


@given(st.lists(st.floats(0.01, 1.0), min_size=1, max_size=10),
       st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_plan_pod_ensemble_assigns_every_member(costs, k):
    member_costs = {f"m{i}": c for i, c in enumerate(costs)}
    out = plan_pod_ensemble(member_costs, k)
    assert sorted(out) == sorted(member_costs)
    assert set(out.values()) <= set(range(max(1, k)))


# ------------------------------------------ speed-vector LPT properties
# Speeds are drawn from a pow2 grid: real pools come in speed CLASSES
# (a CPU node, a 2x accelerator, a 4x accelerator), and on that space
# the greedy planner's monotonicity properties hold exhaustively (for
# arbitrary continuous speeds pure greedy LPT admits rare sub-0.1%
# makespan regressions under a speed increase — a planner swap this
# repo deliberately avoids to keep unit-speed plans bitwise-stable).
SPEED_GRID = (0.5, 1.0, 2.0, 4.0)
SPEED_UPS = (2.0, 4.0, 8.0)


@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=16),
       st.integers(1, 8), st.floats(0.25, 4.0))
@settings(max_examples=40, deadline=None)
def test_speed_lpt_uniform_speeds_reduce_bitwise(costs, k, s):
    """Unit (and any all-equal) speed vector yields EXACTLY today's
    speed-blind plan — assignment, loads, signature — so enabling the
    heterogeneity API on a homogeneous pool changes nothing, including
    staging-cache keys."""
    blind = lpt_placement(costs, k)
    for sp in ([1.0] * max(1, k), [s] * max(1, k)):
        pl = lpt_placement(costs, k, speeds=sp)
        assert pl.assignment == blind.assignment
        assert pl.loads == blind.loads
        assert pl.signature() == blind.signature()
        assert pl.speeds == sp
    assert blind.speeds is None
    assert blind.finish_times == blind.loads


@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=12),
       st.integers(1, 8),
       st.lists(st.sampled_from(SPEED_GRID), min_size=8, max_size=8))
@settings(max_examples=40, deadline=None)
def test_speed_lpt_conserves_members_and_work(costs, k, speeds8):
    """Heterogeneity moves work, never creates or destroys it: every
    member placed once, loads stay cost sums (work units), finish
    times are loads normalized by slot speed."""
    sp = speeds8[:max(1, k)]
    pl = lpt_placement(costs, k, speeds=sp)
    placed = sorted(i for slot in pl.assignment for i in slot)
    assert placed == list(range(len(costs)))
    assert sum(pl.loads) == pytest.approx(sum(costs))
    for slot, load in zip(pl.assignment, pl.loads):
        assert load == pytest.approx(sum(costs[i] for i in slot))
    for f, l, s in zip(pl.finish_times, pl.loads, sp):
        assert f == pytest.approx(l / s)
    assert pl.makespan == pytest.approx(max(pl.finish_times))


@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=12),
       st.integers(2, 6),
       st.lists(st.sampled_from(SPEED_GRID), min_size=6, max_size=6),
       st.integers(0, 5), st.sampled_from(SPEED_UPS))
@settings(max_examples=60, deadline=None)
def test_speed_lpt_makespan_monotone_in_speedup(costs, k, speeds6,
                                                which, factor):
    """A device getting FASTER never worsens the planned makespan (on
    the pow2 speed-class grid) — the invariant that lets RE-PLACE
    treat a recovered/upgraded device as strictly-no-worse."""
    sp = speeds6[:k]
    base = lpt_placement(costs, k, speeds=sp).makespan
    up = list(sp)
    up[which % k] *= factor
    assert lpt_placement(costs, k, speeds=up).makespan <= base + 1e-9


@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=12),
       st.integers(1, 6),
       st.lists(st.sampled_from(SPEED_GRID), min_size=7, max_size=7))
@settings(max_examples=60, deadline=None)
def test_speed_lpt_makespan_monotone_in_added_device(costs, k, speeds7):
    """Adding a device (of any grid speed) never worsens the planned
    makespan."""
    sp = speeds7[:k]
    base = lpt_placement(costs, k, speeds=sp).makespan
    grown = lpt_placement(costs, k + 1, speeds=sp + [speeds7[k]])
    assert grown.makespan <= base + 1e-9


@given(st.integers(1, 12), st.integers(1, 6), st.floats(0.01, 1.0),
       st.lists(st.sampled_from(SPEED_GRID), min_size=6, max_size=6))
@settings(max_examples=25, deadline=None)
def test_speed_lpt_stable_under_duplicates(n, k, c, speeds6):
    """Duplicate costs AND duplicate speeds: ties break
    deterministically, so repeated derivations agree bitwise and the
    staging cache stays hot."""
    costs = [c] * n
    sp = speeds6[:k]
    p1 = lpt_placement(costs, k, speeds=sp)
    p2 = lpt_placement(costs, k, speeds=list(sp))
    assert p1.assignment == p2.assignment
    assert p1.signature() == p2.signature()


def test_speed_lpt_puts_heavy_work_on_fast_devices():
    """The point of the whole exercise: with a 4x device available the
    heavy bucket lands there, and the speed-aware plan strictly beats
    the speed-blind plan evaluated under the TRUE speeds."""
    costs, speeds = [4.0, 1.0, 1.0, 1.0, 1.0], [1.0, 4.0]
    aware = lpt_placement(costs, 2, speeds=speeds)
    assert 0 in aware.assignment[1]       # heaviest item on the 4x slot
    blind = lpt_placement(costs, 2)
    blind_true = Placement(assignment=blind.assignment,
                           loads=blind.loads, speeds=speeds)
    assert aware.makespan < blind_true.makespan - 1e-9


def test_speed_lpt_rejects_bad_speed_vectors():
    with pytest.raises(ValueError):
        lpt_placement([1.0, 2.0], 2, speeds=[1.0])          # wrong len
    with pytest.raises(ValueError):
        lpt_placement([1.0, 2.0], 2, speeds=[1.0, 0.0])     # nonpositive
    with pytest.raises(ValueError):
        Placement(assignment=[[0], [1]], loads=[1.0, 1.0],
                  speeds=[1.0, -2.0])


def test_grouped_lpt_carries_speeds():
    groups = [[0, 1], [2], [3, 4]]
    pl = grouped_lpt_placement(groups, [2.0, 1.0, 1.0], 2,
                               speeds=[1.0, 2.0])
    assert pl.speeds == [1.0, 2.0]
    placed = sorted(m for slot in pl.assignment for m in slot)
    assert placed == list(range(5))


# ------------------------------------------- bugfix regression: imbalance
def test_imbalance_counts_stranded_slots():
    """REGRESSION (pre-fix: imbalance averaged over nonzero slots only,
    so a plan leaving a device fully idle reported 1.0 — 'perfectly
    balanced' — and the controller's RE-PLACE trigger could never fire
    on it)."""
    stranded = Placement(assignment=[[0, 1], []], loads=[3.0, 0.0])
    assert stranded.imbalance == pytest.approx(2.0)
    # well above the controller's default imbalance_high=1.25 gate
    assert stranded.imbalance > 1.25
    balanced = Placement(assignment=[[0], [1]], loads=[1.0, 1.0])
    assert balanced.imbalance == pytest.approx(1.0)
    assert Placement(assignment=[[]], loads=[0.0]).imbalance == 0.0


def test_imbalance_is_finish_time_weighted():
    """Equal LOADS on unequal devices are imbalanced: the slow device
    finishes late.  max(1.0, 0.25) / mean = 1.0 / 0.625 = 1.6."""
    pl = Placement(assignment=[[0], [1]], loads=[1.0, 1.0],
                   speeds=[1.0, 4.0])
    assert pl.finish_times == pytest.approx([1.0, 0.25])
    assert pl.imbalance == pytest.approx(1.6)
    assert pl.makespan == pytest.approx(1.0)


def test_finish_imbalance_helper():
    assert finish_imbalance([1.0, 0.0, 0.0, 0.0]) == pytest.approx(4.0)
    assert finish_imbalance([2.0, 2.0]) == pytest.approx(1.0)
    assert finish_imbalance([]) == 0.0
    assert finish_imbalance([0.0, 0.0]) == 0.0


def test_placement_signature_distinguishes_plans():
    a = Placement(assignment=[[0, 1], [2]], loads=[2.0, 1.0])
    b = Placement(assignment=[[0], [1, 2]], loads=[1.0, 2.0])
    assert a.signature() != b.signature()
    # slot-internal order is irrelevant (same device->members map)
    c = Placement(assignment=[[1, 0], [2]], loads=[2.0, 1.0])
    assert a.signature() == c.signature()
    assert placement_signature(None) not in (a.signature(),
                                             b.signature())


def test_signature_ignores_speeds():
    """Speeds are planner input, not actuated state: a re-speeded but
    identically-assigned plan must hit the same staging-cache entry
    (no recompile churn when only the speed estimate moves)."""
    a = Placement(assignment=[[0, 1], [2]], loads=[2.0, 1.0])
    b = Placement(assignment=[[0, 1], [2]], loads=[2.0, 1.0],
                  speeds=[1.0, 4.0])
    assert a.signature() == b.signature()


def test_failover_placement_keeps_survivor_speeds():
    """Quarantining a device must preserve the SURVIVORS' speed
    sub-vector, and the orphaned members land on the least-FINISH-TIME
    survivor (the least-loaded slot may be the slowest device)."""
    from repro.control.swap import HotSwapper
    old = Placement(assignment=[[0], [1], [2]], loads=[1.0, 1.0, 1.2],
                    speeds=[1.0, 1.0, 4.0])
    pl = HotSwapper._failover_placement(old, 1)
    assert pl.speeds == [1.0, 4.0]
    # slot 1 (speed 4, finish 0.3) absorbs, not slot 0 (finish 1.0)
    assert pl.assignment == [[0], [2, 1]]
    assert pl.loads == pytest.approx([1.0, 2.2])
    # homogeneous plans stay speed-free
    pl0 = HotSwapper._failover_placement(
        Placement(assignment=[[0], [1]], loads=[1.0, 2.0]), 0)
    assert pl0.speeds is None


# ------------------------------------------- sharded-serving equivalence
def _sel(n, idx):
    b = np.zeros(n, np.int8)
    b[list(idx)] = 1
    return b


def _ladder(n):
    """Cheapest -> richest selector family over the reduced zoo."""
    return {"cheap": _sel(n, [0]),
            "mid": _sel(n, range(0, n, 2)),
            "full": _sel(n, range(n))}


def _bucket_plan(pool, selector, n_devices, seed=0):
    """Deterministic bucket-granularity LPT plan for a selector (synthetic
    distinct costs: correctness must hold for ANY valid plan)."""
    idx = np.flatnonzero(np.asarray(selector, bool))
    specs = [pool[i].spec for i in idx]
    groups = list(bucket_zoo(specs).values())
    costs = [float(len(g) + 1 + 0.1 * ((seed + j) % 3))
             for j, g in enumerate(groups)]
    return grouped_lpt_placement(groups, costs, n_devices)


@pytest.fixture(scope="module")
def batch(rng):
    return [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
            for _ in range(5)]


@pytest.fixture(scope="module")
def references(zoo_members, batch):
    """Single-device fused outputs per ladder selector — the oracle the
    sharded path must reproduce bitwise."""
    out = {}
    for name, sel in _ladder(len(zoo_members)).items():
        svc = EnsembleService.for_selector(zoo_members, sel)
        out[name] = (sel, svc.predict_batch(batch),
                     svc.predict(batch[0]))
    return out


@multi_device
@needs_devices
@pytest.mark.parametrize("n_devices", [1, 2, 4, 8])
@pytest.mark.parametrize("rung", ["cheap", "mid", "full"])
def test_sharded_predict_matches_single_device(zoo_members, batch,
                                               references, rung,
                                               n_devices):
    """THE acceptance property: for every ladder selector and every
    device count, the sharded service is numerically IDENTICAL (same
    dtype, np.array_equal) to the single-device path."""
    sel, want_batch, want_one = references[rung]
    pl = _bucket_plan(zoo_members, sel, n_devices)
    svc = EnsembleService.for_selector(
        zoo_members, sel, placement=pl,
        devices=jax.devices()[:n_devices])
    got_batch = svc.predict_batch(batch)
    ga, wa = np.asarray(got_batch), np.asarray(want_batch)
    assert ga.dtype == wa.dtype
    assert np.array_equal(ga, wa)
    assert svc.predict(batch[0]) == want_one


@multi_device
@needs_devices
def test_shard_params_pinned_to_planned_devices(zoo_members, batch):
    """Every (bucket, device) shard's stacked params live on exactly the
    device its placement slot names, and one dispatch is issued per
    shard (not per member)."""
    sel = _ladder(len(zoo_members))["full"]
    pl = _bucket_plan(zoo_members, sel, 4)
    devs = jax.devices()[:4]
    svc = EnsembleService.for_selector(zoo_members, sel, placement=pl,
                                       devices=devs)
    slot_of = {m: d for d, slot in enumerate(pl.assignment)
               for m in slot}
    seen_devices = set()
    for b in svc._buckets:
        want_dev = devs[slot_of[b.idx[0]]]
        assert b.device is want_dev
        for leaf in jax.tree.leaves(b.stacked):
            assert leaf.devices() == {want_dev}
        seen_devices.add(want_dev)
    assert len(seen_devices) > 1          # genuinely multi-device
    d0 = svc.dispatch_count
    svc.predict_batch(batch)
    assert svc.dispatch_count - d0 == svc.n_buckets == len(svc._buckets)


@multi_device
@needs_devices
def test_member_level_split_close_to_oracle(zoo_members, batch,
                                            references):
    """A member-level plan (bucket split across devices) is still valid:
    stacking group sizes change, so it matches to float tolerance."""
    sel, want_batch, _ = references["full"]
    pl = lpt_placement(list(range(12, 0, -1)), 3)    # splits buckets
    svc = EnsembleService.for_selector(zoo_members, sel, placement=pl,
                                       devices=jax.devices()[:3])
    assert svc.n_buckets > 4              # buckets really were split
    np.testing.assert_allclose(svc.predict_batch(batch), want_batch,
                               atol=1e-6)


@multi_device
@needs_devices
def test_placement_must_cover_members():
    import jax as _jax
    from repro.configs.ecg_zoo import zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.pipeline import ZooMember
    specs = zoo_specs(reduced=True, input_len=250)[:2]
    members = [ZooMember(s, init_ecg(_jax.random.PRNGKey(i), s))
               for i, s in enumerate(specs)]
    bad = Placement(assignment=[[0]], loads=[1.0])          # missing 1
    with pytest.raises(ValueError):
        EnsembleService(members, placement=bad)
    dup = Placement(assignment=[[0, 1], [1]], loads=[1.0, 1.0])
    with pytest.raises(ValueError):
        EnsembleService(members, placement=dup)


@multi_device
@needs_devices
def test_stage_caches_selector_placement_pairs(zoo_members):
    from repro.control.swap import HotSwapper
    n = len(zoo_members)
    sel = _ladder(n)["mid"]
    pl2 = _bucket_plan(zoo_members, sel, 2)
    pl4 = _bucket_plan(zoo_members, sel, 4)
    sw = HotSwapper(zoo_members, sel, warmup_batch_sizes=(1,),
                    placement_fn=lambda s: _bucket_plan(zoo_members,
                                                        s, 2))
    assert sw.sharded
    a1 = sw.stage(sel, pl2)
    a2 = sw.stage(sel, pl2)
    b1 = sw.stage(sel, pl4)
    assert a1 is a2                       # pair cache hit
    assert a1 is not b1                   # same selector, new placement
    assert a1.placement.signature() == pl2.signature()
    assert b1.placement.signature() == pl4.signature()


@multi_device
@needs_devices
def test_hot_swap_zero_drop_across_placement_changes(zoo_members, rng):
    """Placement changes are hot-swaps too: toggling the active plan
    mid-stream must drop zero queries, and post-swap scores must be
    bitwise-equal to a cold-started service on the new plan."""
    from repro.control.swap import HotSwapper
    from repro.serving.server import EnsembleServer
    n = len(zoo_members)
    sel = _ladder(n)["full"]
    plans = [_bucket_plan(zoo_members, sel, d, seed=d)
             for d in (2, 4, 8)]
    sw = HotSwapper(zoo_members, sel, warmup_batch_sizes=(1,),
                    placement_fn=lambda s: plans[0])
    for pl in plans:                      # pre-stage every plan
        sw.stage(sel, pl)
    srv = EnsembleServer(batch_handler=sw.facade.predict_batch,
                         n_workers=2, max_batch=1,
                         max_wait_ms=0.5).start()
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(24)]
    for i in range(24):
        if i in (8, 16):                  # re-place mid-stream
            assert sw.re_place(plans[i // 8])
        assert srv.submit(i, windows[i])
    stats = srv.stop()
    assert stats.served == 24             # zero dropped
    assert sw.facade.swap_count == 2
    assert placement_signature(sw.active_placement) \
        == plans[2].signature()
    scores = {p: s for p, s, *_ in srv.results()}
    cold = EnsembleService.for_selector(zoo_members, sel,
                                        placement=plans[2],
                                        devices=jax.devices())
    for i in range(16, 24):
        assert scores[i] == cold.predict_batch([windows[i]])[0]


@multi_device
@needs_devices
def test_re_place_noop_when_plan_unchanged(zoo_members):
    from repro.control.swap import HotSwapper
    n = len(zoo_members)
    sel = _ladder(n)["cheap"]
    pl = _bucket_plan(zoo_members, sel, 2)
    sw = HotSwapper(zoo_members, sel, warmup_batch_sizes=(1,),
                    placement_fn=lambda s: pl)
    svc = sw.facade.current
    assert sw.re_place() is False         # same signature: no swap
    assert sw.facade.current is svc
    assert sw.facade.swap_count == 0


@multi_device
@needs_devices
@pytest.mark.parametrize("speeds", [(4.0, 2.0, 1.0, 1.0),
                                    (1.0, 1.0, 4.0, 0.5)])
@pytest.mark.parametrize("rung", ["mid", "full"])
def test_sharded_hetero_speeds_bitwise(zoo_members, batch, references,
                                       rung, speeds):
    """Speeds move work, never change math: for NON-UNIFORM synthetic
    speed vectors the speed-aware sharded service stays bitwise-equal
    to the single-device oracle, and the aware plan's finish-time
    makespan never exceeds the speed-blind plan's under the true
    speeds."""
    sel, want_batch, want_one = references[rung]
    idx = np.flatnonzero(np.asarray(sel, bool))
    groups = list(bucket_zoo([zoo_members[i].spec for i in idx]).values())
    costs = [float(len(g) + 0.25 * j) for j, g in enumerate(groups)]
    pl = grouped_lpt_placement(groups, costs, len(speeds),
                               speeds=list(speeds))
    blind = grouped_lpt_placement(groups, costs, len(speeds))
    blind_true = Placement(assignment=blind.assignment,
                           loads=blind.loads, speeds=list(speeds))
    assert pl.makespan <= blind_true.makespan + 1e-9
    svc = EnsembleService.for_selector(
        zoo_members, sel, placement=pl,
        devices=jax.devices()[:len(speeds)])
    got = np.asarray(svc.predict_batch(batch))
    want = np.asarray(want_batch)
    assert got.dtype == want.dtype
    assert np.array_equal(got, want)
    assert svc.predict(batch[0]) == want_one


@multi_device
@needs_devices
def test_quarantine_drops_dead_device_speed(zoo_members):
    """Device loss on a heterogeneous pool: the swapper's speed vector
    loses the dead device's entry, and the failover plan carries the
    survivor speed sub-vector."""
    from repro.control.swap import HotSwapper
    n = len(zoo_members)
    sel = _ladder(n)["mid"]
    devs = jax.devices()[:2]
    sw = HotSwapper(zoo_members, sel, warmup_batch_sizes=(1,),
                    n_devices=2, devices=devs, speeds=[1.0, 3.0],
                    plan_batch=1, cost_reps=1)
    assert sw.active_placement is not None
    assert sw.active_placement.speeds == [1.0, 3.0]
    assert sw.quarantine_device(devs[0])
    assert sw.speeds == [3.0]
    assert sw.active_placement.speeds == [3.0]
    assert sw.active_placement.n_slots == 1


@multi_device
@needs_devices
def test_retire_drift_feeds_replace(zoo_members, rng):
    """ISSUE 9 acceptance lane: an injected per-device slowdown drifts
    the live shard retire EWMAs; the controller sees the measured
    finish-time imbalance, fires RE-PLACE, and ``re_place`` re-derives
    the plan FROM THE DRIFT (live costs, not a fresh offline
    measurement on the healthy reference device) — landing a plan that
    splits the slowed device's buckets, without dropping a query."""
    from repro.control.controller import ControllerConfig, Decision
    from repro.control.faults import wire_controller
    from repro.control.swap import HotSwapper
    from repro.control.telemetry import SloTelemetry
    from repro.serving.server import EnsembleServer

    n = len(zoo_members)
    sel = _ladder(n)["full"]
    groups = list(bucket_zoo([m.spec for m in zoo_members]).values())
    assert len(groups) >= 4
    g_costs = [1.0, 1.0] + [0.5] * (len(groups) - 2)
    pl_init = grouped_lpt_placement(groups, g_costs, 2)
    devs = jax.devices()[:2]
    sw = HotSwapper(zoo_members, sel, warmup_batch_sizes=(1,),
                    n_devices=2, devices=devs,
                    placement_fn=lambda s: pl_init)
    # hand planning back to the measured/drift path: placement_fn only
    # pinned a deterministic INITIAL plan for the scenario
    sw.placement_fn = None
    assert placement_signature(sw.active_placement) \
        == pl_init.signature()
    slow_dev = devs[0]
    slow_keys = {tuple(sorted(b.idx))
                 for b in sw.facade.current._buckets
                 if b.device is slow_dev}
    assert len(slow_keys) >= 2            # a co-resident pair to split

    def guard(dev):
        if dev is slow_dev:
            time.sleep(0.02)              # the injected slowdown

    sw.service_hook = lambda svc: setattr(svc, "dispatch_guard", guard)
    sw.facade.current.dispatch_guard = guard

    tel = SloTelemetry(slo_seconds=2.0, window_seconds=60.0)
    ctl = wire_controller(
        tel, sw, member_costs=[0.01] * n,
        config=ControllerConfig(slo_seconds=2.0, cooldown_seconds=0.0,
                                min_samples=5),
        sync=True, start=False)
    srv = EnsembleServer(batch_handler=sw.facade.predict_batch,
                         n_workers=2, max_batch=1, max_wait_ms=0.5,
                         telemetry=tel).start()
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(24)]
    for i in range(12):                   # drift phase
        assert srv.submit(i, windows[i])
    deadline = time.monotonic() + 30.0
    while srv.stats.served < 12 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert srv.stats.served == 12
    # the live EWMAs must already show the slowdown on slot 0's buckets
    live = sw.facade.current.live_bucket_costs()
    assert live is not None
    fin = sw.facade.current.measured_finish_times()
    assert fin is not None and fin[0] > 3.0 * max(fin[1], 1e-9)
    assert ctl.step() is Decision.REPLACE
    new_pl = sw.active_placement
    assert placement_signature(new_pl) != pl_init.signature()
    # the formerly co-resident slowed buckets are now split: no slot
    # hosts every one of them
    for slot in new_pl.assignment:
        keys_on_slot = set()
        for b in sw.facade.current._buckets:
            if set(b.idx) <= set(slot):
                keys_on_slot.add(tuple(sorted(b.idx)))
        assert not slow_keys <= keys_on_slot
    for i in range(12, 24):               # rebalanced phase
        assert srv.submit(i, windows[i])
    stats = srv.stop()
    assert stats.served == 24             # zero dropped
    assert stats.failed == 0


# ---------------------------- bugfix regression: plan at the flush rung
def test_plan_placement_measures_at_flush_rung(zoo_members):
    """REGRESSION (pre-fix: ``plan_placement`` measured bucket costs at
    batch=1 and took no ``batch=`` at all, while serving flushes pad to
    the pow2 rung ladder — cost RATIOS differ between the two, so the
    derived plan could be wrong for the traffic it serves).  With
    synthetic batch-dependent timings the batch-1 plan and the
    flush-rung plan genuinely flip, and the default must be the
    flush-rung one."""
    svc = EnsembleService(zoo_members)
    groups = list(bucket_zoo([m.spec for m in zoo_members]).values())
    n = len(groups)
    assert n >= 3
    # batch 1: fixed dispatch overhead dominates -> bucket 0 looks
    # heaviest; at the flush rung the compute-heavy rest dominate
    fake = {1: [0.4] + [0.1] * (n - 1),
            PLAN_BATCH: [0.1] + [0.4] * (n - 1)}
    svc.measured_bucket_costs = \
        lambda reps=3, batch=1, warmup=1: list(fake[batch])
    plan_default = svc.plan_placement(2)
    plan_flush = svc.plan_placement(2, batch=PLAN_BATCH)
    plan_b1 = svc.plan_placement(2, batch=1)
    assert plan_b1.signature() != plan_flush.signature()   # plans flip
    assert plan_default.signature() == plan_flush.signature()


# ------------------------------------------- live shard retire EWMAs
def test_flush_records_shard_retire_ewmas(zoo_members, batch):
    """Every fused flush folds per-shard dispatch->retire wall-clock
    into an O(1) EWMA; the snapshot covers every bucket, the live cost
    vector lines up with the planner's groups, and state never grows
    with the number of flushes."""
    svc = EnsembleService.for_selector(zoo_members,
                                       _ladder(len(zoo_members))["full"])
    svc.warmup(batch_sizes=(8,))      # keep compile out of the EWMAs
    assert svc.shard_cost_snapshot() == {}
    assert svc.live_bucket_costs() is None       # nothing observed yet
    for _ in range(3):
        svc.predict_batch(batch)
    snap = svc.shard_cost_snapshot()
    groups = list(bucket_zoo([m.spec for m in zoo_members]).values())
    assert set(snap) == {tuple(sorted(g)) for g in groups}
    assert all(v > 0 for v in snap.values())
    live = svc.live_bucket_costs()
    assert live is not None and len(live) == len(groups)
    fin = svc.measured_finish_times()
    assert fin is not None and len(fin) == 1     # unsharded: one slot
    assert fin[0] == pytest.approx(max(snap.values()))
    svc.predict_batch(batch)
    assert len(svc.shard_cost_snapshot()) == len(snap)   # O(1) state


def test_retire_ewma_tracks_injected_slowdown(zoo_members, batch):
    """A dispatch_guard stall on the (single) device shows up in the
    retire EWMAs within a few flushes — the drift signal RE-PLACE
    consumes."""
    svc = EnsembleService.for_selector(zoo_members,
                                       _ladder(len(zoo_members))["full"])
    svc.warmup(batch_sizes=(8,))      # keep compile out of the EWMAs
    for _ in range(3):
        svc.predict_batch(batch)
    fast = dict(svc.shard_cost_snapshot())
    # 50ms stall: large vs per-shard compute, so every shard's EWMA
    # must drift well past its fast baseline even though the stalls
    # also absorb some cross-shard gather wait
    svc.dispatch_guard = lambda dev: time.sleep(0.05)
    for _ in range(5):
        svc.predict_batch(batch)
    slow = svc.shard_cost_snapshot()
    assert set(slow) == set(fast)
    assert all(slow[k] > fast[k] + 0.01 for k in fast)


# ------------------------------------------------- subprocess lane
@pytest.mark.skipif(IN_LANE, reason="already in the multi-device lane")
def test_multi_device_lane_subprocess():
    """Default single-device lane: re-run this module's ``multi_device``
    selection in a child process with 8 forced host devices, so the
    sharded hot path is verified on every tier-1 run."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={N_FORCED}")
    env.pop("PYTEST_CURRENT_TEST", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-m", "multi_device"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=900)
    tail = (r.stdout or "") + (r.stderr or "")
    assert r.returncode == 0, tail[-4000:]
    # the lane must have RUN the tests, not collected zero / skipped all
    assert " passed" in r.stdout, tail[-2000:]
    assert " skipped" not in r.stdout, tail[-2000:]
