"""Multi-device sharded ensemble placement: the controller-actuated
placement dimension, verified by a device-count-parametrized harness.

Three layers:

* pure LPT invariants (always run): member conservation, load/cost
  accounting, imbalance >= 1, makespan <= serial cost, monotone
  non-increasing makespan in device count, stability under duplicate
  costs — property-based via hypothesis (or the seeded shim);
* ``multi_device``-marked wall-clock tests (need 8 forced host
  devices): sharded ``predict``/``predict_batch`` bitwise-equal to the
  single-device path for every ladder selector at 1/2/4/8 devices,
  shard params actually pinned per plan, ``(selector, placement)``
  staging cache semantics, and zero-drop hot-swaps across placement
  changes with post-swap bitwise equality;
* a subprocess wrapper that, in the default single-device lane,
  re-runs the ``multi_device`` selection in a child process with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — so the
  sharded hot path is exercised on every tier-1 run, not only in the
  CI multi-device lane.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, st

import jax

from repro.configs.ecg_zoo import bucket_zoo
from repro.serving.placement import (Placement, grouped_lpt_placement,
                                     lpt_placement, placement_signature,
                                     plan_pod_ensemble)
from repro.serving.pipeline import EnsembleService

N_FORCED = 8
IN_LANE = jax.device_count() >= N_FORCED
multi_device = pytest.mark.multi_device
needs_devices = pytest.mark.skipif(
    not IN_LANE,
    reason=f"needs {N_FORCED} forced host devices (CI lane or the "
           "subprocess wrapper below)")


# ---------------------------------------------------- LPT property tests
@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=24),
       st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_lpt_conserves_members_and_loads(costs, k):
    pl = lpt_placement(costs, k)
    # every member assigned exactly once
    placed = sorted(i for slot in pl.assignment for i in slot)
    assert placed == list(range(len(costs)))
    # per-slot loads are exactly the sums of the assigned costs
    for slot, load in zip(pl.assignment, pl.loads):
        assert load == pytest.approx(sum(costs[i] for i in slot))
    assert sum(pl.loads) == pytest.approx(sum(costs))


@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=24),
       st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_lpt_makespan_invariants(costs, k):
    pl = lpt_placement(costs, k)
    assert pl.imbalance >= 1.0 - 1e-12
    # parallelism can never be worse than serial execution...
    assert pl.makespan <= sum(costs) + 1e-9
    # ...nor better than the critical path / perfect split
    assert pl.makespan >= max(max(costs), sum(costs) / k) - 1e-9


@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=16))
@settings(max_examples=25, deadline=None)
def test_lpt_makespan_monotone_in_device_count(costs):
    """More devices never hurt: the re-place actuator relies on this to
    treat device-count growth as strictly-no-worse."""
    spans = [lpt_placement(costs, k).makespan for k in range(1, 9)]
    assert spans[0] == pytest.approx(sum(costs))
    for a, b in zip(spans, spans[1:]):
        assert b <= a + 1e-9


@given(st.integers(1, 12), st.integers(1, 8),
       st.floats(0.001, 1.0))
@settings(max_examples=25, deadline=None)
def test_lpt_stable_under_duplicate_costs(n, k, c):
    """All-equal costs: ties must break deterministically (stable sort +
    first-min slot), so two runs agree and staging caches stay hot."""
    costs = [c] * n
    p1, p2 = lpt_placement(costs, k), lpt_placement(costs, k)
    assert p1.assignment == p2.assignment
    assert p1.signature() == p2.signature()
    placed = sorted(i for slot in p1.assignment for i in slot)
    assert placed == list(range(n))
    # slot sizes differ by at most one member (round-robin under ties)
    sizes = sorted(len(s) for s in p1.assignment)
    assert sizes[-1] - sizes[0] <= 1


@given(st.lists(st.floats(0.01, 1.0), min_size=1, max_size=8),
       st.integers(1, 6), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_grouped_lpt_keeps_groups_atomic(group_costs, k, group_size):
    """Bucket-granularity planning: a stacked bucket is never split
    across devices, and the expansion covers every member once."""
    groups = [list(range(g * group_size, (g + 1) * group_size))
              for g in range(len(group_costs))]
    pl = grouped_lpt_placement(groups, group_costs, k)
    placed = sorted(m for slot in pl.assignment for m in slot)
    assert placed == list(range(len(group_costs) * group_size))
    for g in groups:                      # group lands on ONE slot whole
        owners = {i for i, slot in enumerate(pl.assignment)
                  if set(g) & set(slot)}
        assert len(owners) == 1
        assert set(g) <= set(pl.assignment[owners.pop()])
    assert pl.makespan <= sum(group_costs) + 1e-9


@given(st.lists(st.floats(0.01, 1.0), min_size=1, max_size=10),
       st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_plan_pod_ensemble_assigns_every_member(costs, k):
    member_costs = {f"m{i}": c for i, c in enumerate(costs)}
    out = plan_pod_ensemble(member_costs, k)
    assert sorted(out) == sorted(member_costs)
    assert set(out.values()) <= set(range(max(1, k)))


def test_placement_signature_distinguishes_plans():
    a = Placement(assignment=[[0, 1], [2]], loads=[2.0, 1.0])
    b = Placement(assignment=[[0], [1, 2]], loads=[1.0, 2.0])
    assert a.signature() != b.signature()
    # slot-internal order is irrelevant (same device->members map)
    c = Placement(assignment=[[1, 0], [2]], loads=[2.0, 1.0])
    assert a.signature() == c.signature()
    assert placement_signature(None) not in (a.signature(),
                                             b.signature())


# ------------------------------------------- sharded-serving equivalence
def _sel(n, idx):
    b = np.zeros(n, np.int8)
    b[list(idx)] = 1
    return b


def _ladder(n):
    """Cheapest -> richest selector family over the reduced zoo."""
    return {"cheap": _sel(n, [0]),
            "mid": _sel(n, range(0, n, 2)),
            "full": _sel(n, range(n))}


def _bucket_plan(pool, selector, n_devices, seed=0):
    """Deterministic bucket-granularity LPT plan for a selector (synthetic
    distinct costs: correctness must hold for ANY valid plan)."""
    idx = np.flatnonzero(np.asarray(selector, bool))
    specs = [pool[i].spec for i in idx]
    groups = list(bucket_zoo(specs).values())
    costs = [float(len(g) + 1 + 0.1 * ((seed + j) % 3))
             for j, g in enumerate(groups)]
    return grouped_lpt_placement(groups, costs, n_devices)


@pytest.fixture(scope="module")
def batch(rng):
    return [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
            for _ in range(5)]


@pytest.fixture(scope="module")
def references(zoo_members, batch):
    """Single-device fused outputs per ladder selector — the oracle the
    sharded path must reproduce bitwise."""
    out = {}
    for name, sel in _ladder(len(zoo_members)).items():
        svc = EnsembleService.for_selector(zoo_members, sel)
        out[name] = (sel, svc.predict_batch(batch),
                     svc.predict(batch[0]))
    return out


@multi_device
@needs_devices
@pytest.mark.parametrize("n_devices", [1, 2, 4, 8])
@pytest.mark.parametrize("rung", ["cheap", "mid", "full"])
def test_sharded_predict_matches_single_device(zoo_members, batch,
                                               references, rung,
                                               n_devices):
    """THE acceptance property: for every ladder selector and every
    device count, the sharded service is numerically IDENTICAL (same
    dtype, np.array_equal) to the single-device path."""
    sel, want_batch, want_one = references[rung]
    pl = _bucket_plan(zoo_members, sel, n_devices)
    svc = EnsembleService.for_selector(
        zoo_members, sel, placement=pl,
        devices=jax.devices()[:n_devices])
    got_batch = svc.predict_batch(batch)
    ga, wa = np.asarray(got_batch), np.asarray(want_batch)
    assert ga.dtype == wa.dtype
    assert np.array_equal(ga, wa)
    assert svc.predict(batch[0]) == want_one


@multi_device
@needs_devices
def test_shard_params_pinned_to_planned_devices(zoo_members, batch):
    """Every (bucket, device) shard's stacked params live on exactly the
    device its placement slot names, and one dispatch is issued per
    shard (not per member)."""
    sel = _ladder(len(zoo_members))["full"]
    pl = _bucket_plan(zoo_members, sel, 4)
    devs = jax.devices()[:4]
    svc = EnsembleService.for_selector(zoo_members, sel, placement=pl,
                                       devices=devs)
    slot_of = {m: d for d, slot in enumerate(pl.assignment)
               for m in slot}
    seen_devices = set()
    for b in svc._buckets:
        want_dev = devs[slot_of[b.idx[0]]]
        assert b.device is want_dev
        for leaf in jax.tree.leaves(b.stacked):
            assert leaf.devices() == {want_dev}
        seen_devices.add(want_dev)
    assert len(seen_devices) > 1          # genuinely multi-device
    d0 = svc.dispatch_count
    svc.predict_batch(batch)
    assert svc.dispatch_count - d0 == svc.n_buckets == len(svc._buckets)


@multi_device
@needs_devices
def test_member_level_split_close_to_oracle(zoo_members, batch,
                                            references):
    """A member-level plan (bucket split across devices) is still valid:
    stacking group sizes change, so it matches to float tolerance."""
    sel, want_batch, _ = references["full"]
    pl = lpt_placement(list(range(12, 0, -1)), 3)    # splits buckets
    svc = EnsembleService.for_selector(zoo_members, sel, placement=pl,
                                       devices=jax.devices()[:3])
    assert svc.n_buckets > 4              # buckets really were split
    np.testing.assert_allclose(svc.predict_batch(batch), want_batch,
                               atol=1e-6)


@multi_device
@needs_devices
def test_placement_must_cover_members():
    import jax as _jax
    from repro.configs.ecg_zoo import zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.pipeline import ZooMember
    specs = zoo_specs(reduced=True, input_len=250)[:2]
    members = [ZooMember(s, init_ecg(_jax.random.PRNGKey(i), s))
               for i, s in enumerate(specs)]
    bad = Placement(assignment=[[0]], loads=[1.0])          # missing 1
    with pytest.raises(ValueError):
        EnsembleService(members, placement=bad)
    dup = Placement(assignment=[[0, 1], [1]], loads=[1.0, 1.0])
    with pytest.raises(ValueError):
        EnsembleService(members, placement=dup)


@multi_device
@needs_devices
def test_stage_caches_selector_placement_pairs(zoo_members):
    from repro.control.swap import HotSwapper
    n = len(zoo_members)
    sel = _ladder(n)["mid"]
    pl2 = _bucket_plan(zoo_members, sel, 2)
    pl4 = _bucket_plan(zoo_members, sel, 4)
    sw = HotSwapper(zoo_members, sel, warmup_batch_sizes=(1,),
                    placement_fn=lambda s: _bucket_plan(zoo_members,
                                                        s, 2))
    assert sw.sharded
    a1 = sw.stage(sel, pl2)
    a2 = sw.stage(sel, pl2)
    b1 = sw.stage(sel, pl4)
    assert a1 is a2                       # pair cache hit
    assert a1 is not b1                   # same selector, new placement
    assert a1.placement.signature() == pl2.signature()
    assert b1.placement.signature() == pl4.signature()


@multi_device
@needs_devices
def test_hot_swap_zero_drop_across_placement_changes(zoo_members, rng):
    """Placement changes are hot-swaps too: toggling the active plan
    mid-stream must drop zero queries, and post-swap scores must be
    bitwise-equal to a cold-started service on the new plan."""
    from repro.control.swap import HotSwapper
    from repro.serving.server import EnsembleServer
    n = len(zoo_members)
    sel = _ladder(n)["full"]
    plans = [_bucket_plan(zoo_members, sel, d, seed=d)
             for d in (2, 4, 8)]
    sw = HotSwapper(zoo_members, sel, warmup_batch_sizes=(1,),
                    placement_fn=lambda s: plans[0])
    for pl in plans:                      # pre-stage every plan
        sw.stage(sel, pl)
    srv = EnsembleServer(batch_handler=sw.facade.predict_batch,
                         n_workers=2, max_batch=1,
                         max_wait_ms=0.5).start()
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(24)]
    for i in range(24):
        if i in (8, 16):                  # re-place mid-stream
            assert sw.re_place(plans[i // 8])
        assert srv.submit(i, windows[i])
    stats = srv.stop()
    assert stats.served == 24             # zero dropped
    assert sw.facade.swap_count == 2
    assert placement_signature(sw.active_placement) \
        == plans[2].signature()
    scores = {p: s for p, s, *_ in srv.results()}
    cold = EnsembleService.for_selector(zoo_members, sel,
                                        placement=plans[2],
                                        devices=jax.devices())
    for i in range(16, 24):
        assert scores[i] == cold.predict_batch([windows[i]])[0]


@multi_device
@needs_devices
def test_re_place_noop_when_plan_unchanged(zoo_members):
    from repro.control.swap import HotSwapper
    n = len(zoo_members)
    sel = _ladder(n)["cheap"]
    pl = _bucket_plan(zoo_members, sel, 2)
    sw = HotSwapper(zoo_members, sel, warmup_batch_sizes=(1,),
                    placement_fn=lambda s: pl)
    svc = sw.facade.current
    assert sw.re_place() is False         # same signature: no swap
    assert sw.facade.current is svc
    assert sw.facade.swap_count == 0


# ------------------------------------------------- subprocess lane
@pytest.mark.skipif(IN_LANE, reason="already in the multi-device lane")
def test_multi_device_lane_subprocess():
    """Default single-device lane: re-run this module's ``multi_device``
    selection in a child process with 8 forced host devices, so the
    sharded hot path is verified on every tier-1 run."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={N_FORCED}")
    env.pop("PYTEST_CURRENT_TEST", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-m", "multi_device"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=900)
    tail = (r.stdout or "") + (r.stderr or "")
    assert r.returncode == 0, tail[-4000:]
    # the lane must have RUN the tests, not collected zero / skipped all
    assert " passed" in r.stdout, tail[-2000:]
    assert " skipped" not in r.stdout, tail[-2000:]
