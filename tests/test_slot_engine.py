"""Slot-based continuous serving engine (serving/slots.py):

* THE acceptance property: a ticked slot's score is BITWISE-identical
  to the flush oracle (``EnsembleService.predict_batch`` over the same
  refs), across partial occupancy, sensor dropout / short windows,
  ring wraparound, occupancy churn, CPU-side vitals/labs models, and
  (via the ``multi_device`` lane) a sharded 8-device placement;
* zero per-query device work: reads are host int indexing and the
  tick's dispatch count is exactly ``n_buckets`` per tick;
* version-gated reads (``wait_scored``), the tick-age staleness guard,
  and slot admin (admit idempotence, discharge semantics, ABA churn);
* ``EnsembleServer(engine="slots")`` end-to-end: conservation, bitwise
  scores, no leaked threads;
* ``StreamingPipeline(engine="slots")`` vs the flush-engine pipeline;
* ``TickLadder``: tick rate as a controller-actuated degradation rung
  (shed slows the tick, climb speeds it up), driven standalone and
  through ``control.controller.AdaptiveController``.

Oracle caveat (see slots.py module doc): a flush of exactly ONE window
compiles a batch-1-specialized XLA program with different float
numerics, and different pow2 pads are different programs — so every
oracle flush here uses the SAME pow2 rung as the engine's slot batch.
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import jax

from repro.serving.aggregator import (DeviceIngest, ModalitySpec,
                                      pow2_rung)
from repro.serving.pipeline import EnsembleService, StreamingPipeline
from repro.serving.server import EnsembleServer
from repro.serving.slots import SlotEngine, SlotTicker, TickLadder

N_FORCED = 8
IN_LANE = jax.device_count() >= N_FORCED
multi_device = pytest.mark.multi_device
needs_devices = pytest.mark.skipif(
    not IN_LANE,
    reason=f"needs {N_FORCED} forced host devices (CI lane or the "
           "subprocess wrapper below)")


# ---------------------------------------------------------------- helpers
def _make_ingest(n_patients, vitals=False):
    mods = [ModalitySpec("ecg", 250.0, 3)]
    if vitals:
        mods.append(ModalitySpec("vitals", 1.0, 7))
    return DeviceIngest(mods, n_patients=n_patients, window_seconds=1.0)


def _close_round(di, rng, patients, t0, n_samples=250, extra=None):
    """Feed one ECG window per patient (mixed chunk sizes exercise the
    pow2 ingest ladder) and close it; returns {patient: ref}."""
    refs = {}
    for p in patients:
        ecg = rng.standard_normal((3, n_samples)).astype(np.float32)
        off = 0
        for k in (100, 75, 75, 250):
            if off >= n_samples:
                break
            di.ingest(t0 + off / 250.0, p, "ecg", ecg[:, off:off + k])
            off += k
        refs[p] = di.close_window(p, t0 + 1.0,
                                  extra=dict(extra or {}))
    return refs


def _oracle(svc, refs, patients):
    return np.asarray(svc.predict_batch([refs[p] for p in patients]))


def _reads(eng, patients):
    return np.asarray([eng.read(p) for p in patients])


# ----------------------------------------------- tick bitwise equivalence
def test_tick_bitwise_vs_flush_oracle(zoo_members, rng):
    """Full house, two rounds (the second overwrites ring heads): every
    slot's read equals the flush oracle bit for bit, at n_buckets
    dispatches per tick and ZERO per-read."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(8)
    eng = SlotEngine(svc, di)
    patients = list(range(8))
    for rnd in range(2):
        refs = _close_round(di, rng, patients, t0=float(rnd))
        for p in patients:
            eng.update(refs[p])
        rep = eng.tick()
        assert rep.n_scored == 8 and rep.n_stale == 0
        assert sorted(map(int, rep.scored)) == patients
        want = _oracle(svc, refs, patients)
        d0 = eng.dispatch_count
        got = _reads(eng, patients)
        assert eng.dispatch_count == d0        # reads dispatch nothing
        assert np.array_equal(got, want), f"round {rnd}"
    assert eng.dispatch_count == 2 * svc.n_buckets
    assert eng.tick_count == 2
    np.testing.assert_array_equal(eng.scores(), got)
    # the on-device artifact exists, is slot-batch sized and on device
    assert eng.device_scores.shape == (pow2_rung(8),)


def test_tick_partial_occupancy(zoo_members, rng):
    """Only 5 of 8 slots occupied: the occupancy mask drops the garbage
    columns; occupied reads stay bitwise, empty reads raise."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(8)
    eng = SlotEngine(svc, di)
    occ = [0, 2, 3, 5, 7]                    # pow2_rung(5) == Spad == 8
    refs = _close_round(di, rng, occ, t0=0.0)
    for p in occ:
        eng.update(refs[p])
    rep = eng.tick()
    assert rep.n_scored == len(occ)
    assert np.array_equal(_reads(eng, occ), _oracle(svc, refs, occ))
    for p in (1, 4, 6):
        with pytest.raises(KeyError):
            eng.read(p)
    s = eng.scores()
    assert np.isnan(s[[1, 4, 6]]).all() and np.isfinite(s[occ]).all()


def test_tick_bitwise_dropout_short_windows_and_wraparound(zoo_members,
                                                           rng):
    """Windows with missing samples (sensor dropout -> left-zero pad)
    and rings that wrapped several times still read bitwise."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    cap = di.states["ecg"].buf.shape[-1]
    eng = SlotEngine(svc, di)
    refs = {}
    for w in range(4):                       # 4 windows > cap=2 windows
        n = 120 if w == 3 else 250           # last window: dropout
        refs = _close_round(di, rng, [0, 1], t0=float(w), n_samples=n)
        for p in (0, 1):
            eng.update(refs[p])
    assert int(di.fed["ecg"][0]) == 3 * 250 + 120 > cap
    eng.tick()
    assert refs[0].valid["ecg"] == 120
    assert np.array_equal(_reads(eng, [0, 1]),
                          _oracle(svc, refs, [0, 1]))


def test_tick_bitwise_with_cpu_side_models(zoo_members, rng):
    """Vitals/labs CPU-side models join the slot's combined score with
    the flush path's exact float64 _combine numerics."""
    class Const:
        def __init__(self, v):
            self.v = v

        def predict_proba(self, x):
            return np.full(len(x), self.v)

    svc = EnsembleService(zoo_members, vitals_model=Const(0.9),
                          labs_model=Const(0.1))
    di = _make_ingest(2, vitals=True)
    eng = SlotEngine(svc, di)
    labs = rng.standard_normal(8).astype(np.float32)
    refs = {}
    for p in (0, 1):
        di.ingest(0.0, p, "vitals",
                  rng.standard_normal((7, 1)).astype(np.float32))
    refs = _close_round(di, rng, [0, 1], t0=0.0,
                        extra={"labs": labs})
    for p in (0, 1):
        eng.update(refs[p])
    eng.tick()
    assert np.array_equal(_reads(eng, [0, 1]),
                          _oracle(svc, refs, [0, 1]))


def test_occupancy_churn_discharge_and_readmit(zoo_members, rng):
    """Slot insert/free mid-serving: a discharged slot's read raises
    and its score never leaks into survivors; re-admission serves the
    NEW occupant's window bitwise."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(8)
    eng = SlotEngine(svc, di)
    patients = list(range(8))
    refs = _close_round(di, rng, patients, t0=0.0)
    for p in patients:
        eng.update(refs[p])
    eng.tick()
    eng.discharge(3)
    with pytest.raises(KeyError):
        eng.read(3)
    with pytest.raises(KeyError):
        eng.discharge(3)                     # double-free
    rest = [p for p in patients if p != 3]   # 7 -> same pow2 rung
    eng.tick()                               # survivors rescore fine
    assert np.array_equal(_reads(eng, rest), _oracle(svc, refs, rest))
    assert eng.n_discharges == 1
    # a new patient takes bed 3: fresh window, fresh score
    refs2 = _close_round(di, rng, [3], t0=2.0)
    v = eng.update(refs2[3])
    assert eng.n_admits == 9                 # 8 first-window + re-admit
    assert np.isnan(eng.read(3))             # admitted, not yet ticked
    eng.tick()
    assert eng.scored_version[3] == v
    all_refs = {**refs, 3: refs2[3]}
    assert np.array_equal(_reads(eng, patients),
                          _oracle(svc, all_refs, patients))


def test_admit_is_idempotent_and_prescore_reads_nan(zoo_members, rng):
    svc = EnsembleService(zoo_members)
    eng = SlotEngine(svc, _make_ingest(2))
    eng.admit(0)
    eng.admit(0)
    assert eng.n_admits == 1
    assert np.isnan(eng.read(0))             # occupied, never scored
    rep = eng.tick()                         # no window yet: no-op tick
    assert rep.n_scored == 0 and eng.dispatch_count == 0


# ------------------------------------------- versions + staleness guards
def test_wait_scored_is_version_gated(zoo_members, rng):
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    eng = SlotEngine(svc, di)
    refs = _close_round(di, rng, [0, 1], t0=0.0)
    v = eng.update(refs[0])
    eng.update(refs[1])
    assert not eng.wait_scored(0, v, timeout=0.05)   # no tick yet
    eng.tick()
    assert eng.wait_scored(0, v, timeout=0.05)
    assert not eng.wait_scored(0, v + 1, timeout=0.05)  # future close
    eng.discharge(0)
    assert not eng.wait_scored(0, v, timeout=0.05)   # gone: wake False


def test_stale_ring_skipped_and_tick_age_guard(zoo_members, rng):
    """A slot whose closed window was overwritten before the tick could
    gather it is SKIPPED (never scored with wrong-window samples): its
    mirror keeps the last good score, its version stops advancing, and
    the read-side tick-age guard turns it NaN."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    cap = di.states["ecg"].buf.shape[-1]
    eng = SlotEngine(svc, di)
    refs = _close_round(di, rng, [0, 1], t0=0.0)
    for p in (0, 1):
        eng.update(refs[p])
    eng.tick()
    good = eng.read(0)
    # over-feed slot 0 WITHOUT closing: its last closed window scrolls
    # out of the ring (fed - oldest > cap)
    for w in range(1, 4):
        for off in range(0, 250, 50):
            di.ingest(w + off / 250.0, 0, "ecg",
                      rng.standard_normal((3, 50)).astype(np.float32))
    assert int(di.fed["ecg"][0]) == 1000 > cap
    rep = eng.tick()
    assert rep.n_stale == 1 and rep.n_scored == 1
    assert eng.read(0) == good               # last good score, kept
    assert np.isnan(eng.read(0, max_age_ticks=0))    # age guard: NaN
    assert np.isfinite(eng.read(1, max_age_ticks=0))  # rescored fine
    v2 = eng.update(_close_round(di, rng, [0], t0=4.0)[0])
    eng.tick()                               # fresh close: recovers
    assert rep.n_stale == 1
    assert eng.scored_version[0] == v2
    assert np.isfinite(eng.read(0, max_age_ticks=0))


def test_engine_rejects_wrong_service_or_ingest(zoo_members, rng):
    di = _make_ingest(2)
    with pytest.raises(ValueError, match="fused"):
        SlotEngine(EnsembleService(zoo_members, fused=False), di)
    with pytest.raises(ValueError, match="packed"):
        SlotEngine(EnsembleService(zoo_members, marshal="legacy"), di)
    with pytest.raises(ValueError, match="ecg"):
        SlotEngine(EnsembleService(zoo_members),
                   DeviceIngest([ModalitySpec("vitals", 1.0, 7)],
                                n_patients=2, window_seconds=1.0))
    eng = SlotEngine(EnsembleService(zoo_members), di)
    other = _make_ingest(2)
    ref = _close_round(other, rng, [0], t0=0.0)[0]
    with pytest.raises(ValueError, match="different DeviceIngest"):
        eng.update(ref)


# ----------------------------------------------------- ticker + server
def test_ticker_scores_in_background(zoo_members, rng):
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    eng = SlotEngine(svc, di)
    ticker = SlotTicker(eng, interval=0.01).start()
    try:
        refs = _close_round(di, rng, [0, 1], t0=0.0)
        vs = {p: eng.update(refs[p]) for p in (0, 1)}
        for p in (0, 1):
            assert eng.wait_scored(p, vs[p], timeout=2.0)
        assert np.array_equal(_reads(eng, [0, 1]),
                              _oracle(svc, refs, [0, 1]))
    finally:
        assert ticker.stop()
    assert not ticker.alive


def test_server_slots_engine_end_to_end(zoo_members, rng):
    """EnsembleServer(engine='slots'): conservation (served == submitted,
    zero failed), bitwise scores vs the flush oracle, no leaked threads
    (workers + ticker), and zero per-query dispatches."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(8)
    eng = SlotEngine(svc, di)
    patients = list(range(8))
    refs = _close_round(di, rng, patients, t0=0.0)
    srv = EnsembleServer(engine="slots", slot_engine=eng,
                         tick_interval=0.01, n_workers=2).start()
    for p in patients:
        assert srv.submit(p, refs[p])
    stats = srv.stop()
    assert stats.served == 8 and stats.failed == 0
    assert srv.leaked == []
    want = _oracle(svc, refs, patients)
    got = {p: s for p, s, *_ in srv.results()}
    assert np.array_equal(np.asarray([got[p] for p in patients]), want)
    # the whole run's device work came from ticks, none from queries
    assert eng.dispatch_count % svc.n_buckets == 0


def test_server_slots_stale_read_retires_nan_not_blocks(zoo_members,
                                                        rng):
    """A query whose covering tick never lands (ticker too slow /
    stopped) must retire NaN within slot_wait_timeout, not hang
    drain()."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    eng = SlotEngine(svc, di)
    refs = _close_round(di, rng, [0], t0=0.0)
    srv = EnsembleServer(engine="slots", slot_engine=eng,
                         tick_interval=60.0,       # never ticks in test
                         slot_wait_timeout=0.1,
                         n_workers=1).start()
    assert srv.submit(0, refs[0])
    stats = srv.stop()
    assert stats.served == 1 and stats.failed == 1
    assert srv.leaked == []


def test_server_slots_ctor_validation(zoo_members):
    eng = SlotEngine(EnsembleService(zoo_members), _make_ingest(2))
    with pytest.raises(ValueError, match="slot_engine"):
        EnsembleServer(engine="slots")
    with pytest.raises(ValueError, match="handlers"):
        EnsembleServer(engine="slots", slot_engine=eng,
                       batch_handler=lambda w: [0.0])
    with pytest.raises(ValueError, match="untiered"):
        EnsembleServer(engine="slots", slot_engine=eng,
                       tier_of=lambda p: "stable")
    with pytest.raises(ValueError, match='engine="slots"'):
        EnsembleServer(handler=lambda w: 0.0, slot_engine=eng)
    with pytest.raises(ValueError, match="unknown engine"):
        EnsembleServer(handler=lambda w: 0.0, engine="nope")


# ----------------------------------------------------- pipeline engine
def test_pipeline_slots_engine_vs_flush(zoo_members, rng):
    """StreamingPipeline(engine='slots') serves every closed window the
    flush-engine pipeline serves, same windows, equivalent scores (the
    flush pipeline scores windows singly — a different XLA pad — so
    this comparison is float-tolerance; bitwise is covered at the
    engine level above)."""
    svc = EnsembleService(zoo_members)
    flush = StreamingPipeline(svc, n_patients=2, window_seconds=1.0,
                              device_ingest=True)
    slots = StreamingPipeline(svc, n_patients=2, window_seconds=1.0,
                              device_ingest=True, engine="slots")
    rng2 = np.random.default_rng(7)
    for j in range(7):                       # 3 windows/patient @0.5 s
        t = j * 0.5
        for p in range(2):
            c = rng2.standard_normal((3, 125)).astype(np.float32)
            flush.feed(t, p, "ecg", c)
            slots.feed(t, p, "ecg", c)
    slots.tick_now(3.5)                      # drain pending closes
    assert len(flush.records) == len(slots.records) == 6
    want = {(r.patient, r.t_window): r.score for r in flush.records}
    for r in slots.records:
        assert r.score == pytest.approx(want[(r.patient, r.t_window)],
                                        abs=1e-6)
    with pytest.raises(ValueError):
        flush.tick_now(0.0)                  # flush engine has no ticks


def test_pipeline_slots_ctor_validation(zoo_members):
    svc = EnsembleService(zoo_members)
    with pytest.raises(ValueError, match="device_ingest"):
        StreamingPipeline(svc, n_patients=2, engine="slots")
    with pytest.raises(ValueError, match="untiered"):
        StreamingPipeline(svc, n_patients=2, device_ingest=True,
                          engine="slots", tier_of=lambda p: "stable")
    with pytest.raises(ValueError, match="unknown engine"):
        StreamingPipeline(svc, n_patients=2, engine="nope")


# -------------------------------------------------------- tick ladder
def test_tick_ladder_shed_slows_climb_speeds(zoo_members):
    eng = SlotEngine(EnsembleService(zoo_members), _make_ingest(2))
    ticker = SlotTicker(eng, interval=0.05)   # never started: knob only
    lad = TickLadder(ticker, intervals=[0.01, 0.05, 0.2])
    assert lad.ladder == [0.2, 0.05, 0.01]    # rung 0 = slowest
    assert lad.ladder_pos == 2                # starts richest
    assert ticker.interval == 0.01
    assert lad.can_shed() and not lad.can_climb()
    assert lad.shed() and ticker.interval == 0.05
    assert lad.shed() and ticker.interval == 0.2
    assert not lad.shed() and not lad.can_shed()   # floor holds
    assert lad.climb() and ticker.interval == 0.05
    lad.swap_to(0)
    assert lad.active_interval == ticker.interval == 0.2
    with pytest.raises(ValueError):
        lad.swap_to(3)
    with pytest.raises(ValueError):
        TickLadder(ticker, intervals=[])
    with pytest.raises(ValueError):
        TickLadder(ticker, intervals=[0.1, -0.1])
    with pytest.raises(ValueError):
        TickLadder(ticker, intervals=[0.1], start=5)


def test_tick_ladder_actuated_by_adaptive_controller(zoo_members):
    """Tick rate joins the controller's knobs: SLO violations SHED the
    tick ladder (interval slows), a healthy window climbs back."""
    from repro.control.controller import (AdaptiveController,
                                          ControllerConfig, Decision)
    from repro.control.telemetry import SloTelemetry
    eng = SlotEngine(EnsembleService(zoo_members), _make_ingest(2))
    ticker = SlotTicker(eng, interval=0.01)
    lad = TickLadder(ticker, intervals=[0.01, 0.1])
    t = [100.0]
    tel = SloTelemetry(slo_seconds=0.5, clock=lambda: t[0])
    ctl = AdaptiveController(
        tel, lad, sync=True, clock=lambda: t[0],
        config=ControllerConfig(slo_seconds=0.5, cooldown_seconds=0.0,
                                drift_factor=1e9))  # isolate shed/climb
    for k in range(30):                       # violating traffic
        tel.record_arrival(99.0)
        tel.record_served(0.9, 99.0 + k / 100.0)
    assert ctl.step() is Decision.SHED
    assert lad.ladder_pos == 0 and ticker.interval == 0.1
    t[0] += 100.0                             # violations age out
    for k in range(30):
        tel.record_arrival(t[0] - 1.0)
        tel.record_served(0.05, t[0] - 1.0 + k / 100.0)
    assert ctl.step() is Decision.CLIMB
    assert lad.ladder_pos == 1 and ticker.interval == 0.01


# ------------------------------------------------- multi-device lane
@multi_device
@needs_devices
def test_slot_tick_bitwise_sharded_8_devices(zoo_members, rng):
    """The forced-8-device lane: slot ticks through an LPT-sharded
    placement (one donated state per device group, cross-group fleet
    mean) still read bitwise-equal to the UNSHARDED flush oracle."""
    from repro.configs.ecg_zoo import bucket_zoo
    from repro.serving.placement import grouped_lpt_placement
    groups = list(bucket_zoo([m.spec for m in zoo_members]).values())
    pl = grouped_lpt_placement(groups, [1.0 + 0.1 * j for j in
                                        range(len(groups))], N_FORCED)
    sharded = EnsembleService(zoo_members, placement=pl,
                              devices=jax.devices()[:N_FORCED])
    flat = EnsembleService(zoo_members)
    di = _make_ingest(8)
    eng = SlotEngine(sharded, di)
    assert len(eng.groups) > 1               # actually sharded
    patients = list(range(8))
    refs = _close_round(di, rng, patients, t0=0.0)
    for p in patients:
        eng.update(refs[p])
    rep = eng.tick()
    assert rep.n_scored == 8
    assert np.array_equal(_reads(eng, patients),
                          _oracle(flat, refs, patients))


@pytest.mark.skipif(IN_LANE, reason="already in the multi-device lane")
def test_multi_device_lane_subprocess():
    """Single-device lane: re-run this module's ``multi_device``
    selection under 8 forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={N_FORCED}")
    env.pop("PYTEST_CURRENT_TEST", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-m", "multi_device"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=900)
    tail = (r.stdout or "") + (r.stderr or "")
    assert r.returncode == 0, tail[-4000:]
    assert " passed" in r.stdout, tail[-2000:]
    assert " skipped" not in r.stdout, tail[-2000:]


# ------------------------------------------------------ warm + compile
def test_warm_precompiles_tick_path(zoo_members, rng):
    """After ``warm()`` a tick compiles nothing new on the bucket
    dispatches (the gather/update programs are shared with the flush
    path's caches)."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(4)
    eng = SlotEngine(svc, di)
    eng.warm()
    sizes = {id(b.fn): b.fn._cache_size() for b in svc._buckets}
    refs = _close_round(di, rng, [0, 1, 2, 3], t0=0.0)
    for p in range(4):
        eng.update(refs[p])
    eng.tick()
    for b in svc._buckets:
        assert b.fn._cache_size() == sizes[id(b.fn)]
