"""Launch-layer units that run WITHOUT the 512-device platform: the HLO
collective parser, roofline extrapolation, probe-pair construction,
sharding rules, and the wall-clock server."""
import time

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.configs.registry import get_config
from repro.launch.dryrun import _shape_bytes, collective_bytes
from repro.launch.roofline import _extrapolate, model_flops, probe_pair
from repro.launch.sharding import cache_spec, param_spec


# ------------------------------------------------------- HLO parsing
def test_shape_bytes():
    assert _shape_bytes("bf16[4,8]{1,0}") == 64
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("(bf16[2,2], f32[3])") == 20
    assert _shape_bytes("pred[7]") == 7
    assert _shape_bytes("token[]") == 0


def test_collective_bytes_parser():
    hlo = """
  %x.1 = bf16[128,256]{1,0} all-gather(%p.0), dimensions={0}
  ROOT %y = f32[64]{0} all-reduce(%z), to_apply=%add
  %fusion.all-reduce-ish = bf16[4,4]{1,0} fusion(%a), kind=kLoop
  %ar2 = (f32[8], f32[8]) all-reduce-start(%q, %r)
  %cp = bf16[32]{0} collective-permute(%m), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 128 * 256 * 2
    assert out["all-reduce"] == 64 * 4 + 2 * 8 * 4
    assert out["collective-permute"] == 32 * 2
    assert out["all-to-all"] == 0


# ------------------------------------------------------- roofline math
def test_extrapolation_linear():
    mk = lambda f: {"flops": f, "bytes_accessed": 2 * f,
                    "collective_total": f / 10,
                    "collective_bytes": {"all-reduce": f / 10}}
    out = _extrapolate(mk(10.0), 2.0, mk(14.0), 4.0, 32.0)
    # slope 2/unit, intercept 10-2*2=6, full = 6+64 = 70
    assert out["flops"] == pytest.approx(70.0)
    assert out["bytes_accessed"] == pytest.approx(140.0)
    assert out["collective_bytes"]["all-reduce"] == pytest.approx(7.0)


def test_probe_pairs_shapes():
    for arch in ("qwen3-4b", "deepseek-v2-lite-16b", "zamba2-7b",
                 "seamless-m4t-medium", "mamba2-2.7b"):
        cfg = get_config(arch)
        a, ua, b, ub, uf = probe_pair(cfg)
        assert ub > ua > 0
        assert uf >= ub
        assert a.d_model == cfg.d_model         # only depth reduced
        assert a.vocab_size == cfg.vocab_size
    ds = get_config("deepseek-v2-lite-16b")
    a, *_ = probe_pair(ds)
    assert a.moe.first_dense_layers == ds.moe.first_dense_layers


def test_model_flops_conventions():
    from repro.configs.shapes import get_shape
    cfg = get_config("qwen3-4b")
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    dc = model_flops(cfg, get_shape("decode_32k"))
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert dc == pytest.approx(2 * n * 128)


# ------------------------------------------------------- sharding rules
def test_param_spec_rules():
    cfg = get_config("qwen3-4b")
    assert param_spec(("segments", "0", "attn", "wq", "w"),
                      (36, 2560, 4096), cfg, 16) == P(None, None, "model")
    assert param_spec(("segments", "0", "attn", "wo", "w"),
                      (36, 4096, 2560), cfg, 16) == P(None, "model", None)
    assert param_spec(("embed", "table"), (151936, 2560), cfg, 16) \
        == P("model", None)
    # smollm's flattened q dim (15*64=960) divides 16, so it shards
    # (GSPMD reshards at the head reshape; dp_only is the fast layout)
    assert param_spec(("segments", "0", "attn", "wq", "w"),
                      (32, 960, 15 * 64), get_config("smollm-360m"), 16) \
        == P(None, None, "model")
    # genuinely non-divisible output dim -> replicate
    assert param_spec(("segments", "0", "attn", "wq", "w"),
                      (2, 64, 30), get_config("smollm-360m"), 16) == P()
    assert param_spec(("segments", "0", "mlp", "router"),
                      (2048, 64), get_config("deepseek-v2-lite-16b"),
                      16) == P()


def test_cache_spec_rules():
    import jax
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # kv cache: batch then model when divisible by 1 (host mesh)
    spec = cache_spec(("segments", "0", "k"), (27, 8, 128, 16, 64),
                      mesh, batch=8)
    assert spec == P(None, "data", None, "model", None)
    spec = cache_spec(("pos",), (128,), mesh, batch=8)
    assert spec == P()


# ------------------------------------------------------- server
def test_wallclock_server():
    from repro.serving.server import EnsembleServer

    def handler(windows):
        time.sleep(0.002)
        return float(np.mean(windows["x"]))

    srv = EnsembleServer(handler, n_workers=2, slo_seconds=0.5).start()
    for i in range(20):
        assert srv.submit(i % 4, {"x": np.full((4,), i)})
    srv.drain()
    stats = srv.stop()
    assert stats.served == 20
    assert stats.slo_violations == 0
    assert 0 < stats.p(95) < 0.5
    res = srv.results()
    assert len(res) == 20
    assert all(0 <= r[2] < 0.5 for r in res)
