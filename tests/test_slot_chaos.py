"""Chaos-hardening of the continuous slot engine (serving/slots.py +
control/faults.py): tick-path fault recovery, the generational
ticker/watchdog plane, churn-safe slot lifecycle, monotonic fault
clocks, and replayable compound fault traces.

* tick-path device loss: every gather and bucket dispatch is guarded
  and all guards fire BEFORE the donated fold, so an aborted tick
  leaves every score state untouched — reads stay stale-never-wrong
  through the outage and the post-recovery tick is bitwise the oracle;
* ``FaultPlane.protect_engine`` (multi_device lane): a PERMANENT loss
  quarantines the device, sheds the TickLadder during failover (undone
  after), rebinds the engine onto the survivor facade and re-runs the
  tick — bitwise the unsharded oracle afterwards;
* ``SlotTicker``/``TickerWatchdog``: stall and death respawns, every
  generation ever spawned joined by ``stop()`` (the leak-accounting
  regression: a watchdog-respawned ticker must never orphan a thread
  past the checker), slow TickLadder rungs never misread as stalls;
* churn: a mid-tick close must skip the stamp (version guard), and an
  adversarial admit/discharge/update hammer with census growth past
  the initial ``n_slots`` never stamps a score its own tick report
  cannot reproduce bitwise offline;
* ``FaultPlane`` rides an injectable MONOTONIC clock — schedules and
  retry budgets are immune to wall-clock steps — and round-trips its
  schedule through ``to_json``/``from_json`` trace files.
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import jax

from repro.control.faults import (DeviceLostError, FaultEvent,
                                  FaultPlane, compound_schedule,
                                  slot_compound_schedule)
from repro.serving.aggregator import DeviceIngest, ModalitySpec
from repro.serving.pipeline import EnsembleService
from repro.serving.server import EnsembleServer
from repro.serving.slots import (SlotEngine, SlotTicker, TickLadder,
                                 TickerWatchdog)

N_FORCED = 8
IN_LANE = jax.device_count() >= N_FORCED
multi_device = pytest.mark.multi_device
needs_devices = pytest.mark.skipif(
    not IN_LANE,
    reason=f"needs {N_FORCED} forced host devices (CI lane or the "
           "subprocess wrapper below)")


# ---------------------------------------------------------------- helpers
class FakeClock:
    """Injectable monotonic clock: the schedule and every deadline in
    a ``FaultPlane`` advance exactly when the test says so."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


def _make_ingest(n_patients):
    return DeviceIngest([ModalitySpec("ecg", 250.0, 3)],
                        n_patients=n_patients, window_seconds=1.0)


def _close_round(di, rng, patients, t0):
    refs = {}
    for p in patients:
        ecg = rng.standard_normal((3, 250)).astype(np.float32)
        di.ingest(t0, p, "ecg", ecg)
        refs[p] = di.close_window(p, t0 + 1.0)
    return refs


def _oracle(svc, refs, patients):
    return np.asarray(svc.predict_batch([refs[p] for p in patients]))


def _reads(eng, patients):
    return np.asarray([eng.read(p) for p in patients])


class _StubEngine:
    """Duck-typed engine for pure ticker/watchdog mechanics."""

    def __init__(self, die_first: bool = False):
        self.n = 0
        self._die = die_first

    def tick(self):
        if self._die:
            self._die = False
            raise SystemExit       # kills the generation's thread
        self.n += 1


# ------------------------------------------- tick-path fault recovery
def test_tick_device_loss_aborts_before_fold(zoo_members, rng):
    """A DeviceLostError mid-tick aborts BEFORE any donated fold: the
    mirror keeps its last good scores (stale, never wrong), no version
    stamps, and the post-restore tick is bitwise the oracle."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(4)
    eng = SlotEngine(svc, di)
    pts = [0, 1, 2, 3]
    refs = _close_round(di, rng, pts, t0=0.0)
    for p in pts:
        eng.update(refs[p])
    eng.tick()
    before = _reads(eng, pts)
    assert np.array_equal(before, _oracle(svc, refs, pts))

    clk = FakeClock()
    plane = FaultPlane([FaultEvent(1.0, "device_loss", target=0,
                                   duration=5.0)], clock=clk)
    plane.arm(devices=jax.devices())
    svc.dispatch_guard = plane.guard
    clk.advance(2.0)                          # loss active
    refs2 = _close_round(di, rng, pts, t0=1.0)
    vers = {p: eng.update(refs2[p]) for p in pts}
    with pytest.raises(DeviceLostError):
        eng.tick()
    assert eng.n_tick_faults == 1 and eng.n_tick_aborts == 1
    assert np.array_equal(_reads(eng, pts), before)   # stale, not wrong
    assert not eng.wait_scored(0, vers[0], timeout=0.05)

    clk.advance(10.0)                         # device restored
    rep = eng.tick()
    assert sorted(map(int, rep.stamped)) == pts
    assert np.array_equal(_reads(eng, pts), _oracle(svc, refs2, pts))


def test_on_device_lost_recovery_reruns_tick(zoo_members, rng):
    """When the recovery hook reports success the aborted tick re-runs
    in the SAME tick() call and lands bitwise-correct scores."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(4)
    eng = SlotEngine(svc, di)
    clk = FakeClock()
    plane = FaultPlane([FaultEvent(1.0, "device_loss", target=0,
                                   duration=3.0)], clock=clk)
    plane.arm(devices=jax.devices())
    svc.dispatch_guard = plane.guard
    pts = [0, 1, 2, 3]
    refs = _close_round(di, rng, pts, t0=0.0)
    for p in pts:
        eng.update(refs[p])
    clk.advance(1.5)                          # loss active
    calls = []

    def recover(err):
        calls.append(err.index)
        clk.advance(10.0)                     # "the device reboots"
        return True

    eng.on_device_lost = recover
    rep = eng.tick()
    assert calls == [0]
    assert eng.n_tick_faults == 1 and eng.n_tick_aborts == 0
    assert sorted(map(int, rep.stamped)) == pts
    assert np.array_equal(_reads(eng, pts), _oracle(svc, refs, pts))


def test_request_rebind_applied_at_next_tick(zoo_members, rng):
    """The async rebind (quarantine-hook form) is queued and applied at
    the next tick entry — same member composition, scores bitwise."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    eng = SlotEngine(svc, di)
    svc2 = EnsembleService(zoo_members)
    eng.request_rebind(svc2)
    refs = _close_round(di, rng, [0, 1], t0=0.0)
    for p in (0, 1):
        eng.update(refs[p])
    rep = eng.tick()
    assert eng.service is svc2 and eng.n_rebinds == 1
    assert len(rep.stamped) == 2
    assert np.array_equal(_reads(eng, [0, 1]),
                          _oracle(svc2, refs, [0, 1]))


# --------------------------------------- ticker generations + watchdog
def test_ticker_stop_joins_all_generations(zoo_members, rng):
    """Satellite regression: every respawned generation stays tracked
    and ``stop()`` joins them ALL (pre-fix, a respawn replaced the
    thread handle and the old generation escaped the leak checker)."""
    svc = EnsembleService(zoo_members)
    eng = SlotEngine(svc, _make_ingest(2))
    t = SlotTicker(eng, interval=0.01).start()
    assert t.respawn() and t.respawn()
    assert len(t._threads) == 3
    assert len({th.name for th in t._threads}) == 3
    assert t.stop(join_timeout=2.0) is True
    assert t.alive_threads() == []
    assert not t.respawn()                    # stopped for good


def test_ticker_wedged_generation_surfaces_in_leak_accounting():
    """A generation wedged inside a tick past the join timeout is
    REPORTED (stop() False + alive_threads names it), never silently
    dropped; once the tick releases, a second stop() joins it."""
    release = threading.Event()

    class Wedge:
        def tick(self):
            release.wait(10.0)

    t = SlotTicker(Wedge(), interval=0.01).start()
    time.sleep(0.1)                 # generation 0 is inside the tick
    assert t.respawn()
    assert t.stop(join_timeout=0.2) is False
    assert t.alive_threads()        # the zombie is named, not lost
    release.set()
    assert t.stop(join_timeout=2.0) is True
    assert t.alive_threads() == []


def test_watchdog_respawns_stalled_ticker():
    """An injected ticker stall starves the beat; the watchdog
    respawns a fresh generation that ticks on through."""
    stub = _StubEngine()
    t = SlotTicker(stub, interval=0.01)
    stalls = [1.0]
    t.before_tick = lambda: stalls.pop() if stalls else 0.0
    t.start()
    wd = TickerWatchdog(t, deadline_seconds=0.15, poll=0.02).start()
    deadline = time.monotonic() + 5.0
    while wd.n_respawns < 1 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert wd.n_respawns >= 1
    assert any(e["cause"] == "stall" for e in wd.events)
    n0 = stub.n
    time.sleep(0.2)
    assert stub.n > n0              # the fresh generation is ticking
    assert wd.stop()
    # gen 0 notices its stale epoch right after the stall and exits
    assert t.stop(join_timeout=3.0)
    assert t.alive_threads() == []


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_watchdog_respawns_dead_ticker():
    """A generation KILLED outright (tick raising SystemExit) is
    detected as dead and respawned."""
    stub = _StubEngine(die_first=True)
    t = SlotTicker(stub, interval=0.01).start()
    wd = TickerWatchdog(t, deadline_seconds=0.15, poll=0.02).start()
    deadline = time.monotonic() + 5.0
    while stub.n < 2 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert stub.n >= 2
    assert wd.n_respawns >= 1
    assert any(e["cause"] == "dead" for e in wd.events)
    assert wd.stop() and t.stop()


def test_watchdog_slow_rung_is_not_a_stall():
    """The quiet threshold reads ``ticker.interval`` LIVE: a TickLadder
    shed to a slow rung must not read as a stall."""
    stub = _StubEngine()
    t = SlotTicker(stub, interval=0.3).start()
    wd = TickerWatchdog(t, deadline_seconds=0.15, poll=0.02).start()
    time.sleep(0.8)                 # two slow ticks' worth of quiet
    assert wd.n_respawns == 0
    assert wd.stop() and t.stop()


def test_server_slots_watchdog_lifecycle(zoo_members, rng):
    """EnsembleServer wires the ticker watchdog: a stall mid-serve is
    respawned through, queries score real (bitwise) after the gap, and
    shutdown leaks nothing — respawned generations included."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(4)
    eng = SlotEngine(svc, di)
    srv = EnsembleServer(engine="slots", slot_engine=eng, n_workers=2,
                         tick_interval=0.01, slot_wait_timeout=5.0,
                         ticker_deadline_seconds=0.1).start()
    stalls = [1.0]
    srv.ticker.before_tick = lambda: stalls.pop() if stalls else 0.0
    pts = [0, 1, 2, 3]
    refs = _close_round(di, rng, pts, t0=0.0)
    for p in pts:
        assert srv.submit(p, refs[p])
    srv.drain(timeout=30.0)
    got = {p: s for p, s, _, _ in srv.results()}
    assert srv.ticker.n_respawns >= 1
    assert srv.ticker_watchdog.n_respawns >= 1
    want = _oracle(svc, refs, pts)
    for p in pts:
        assert got[p] == want[p]
    srv.stop()
    assert srv.leaked == []
    left = [th.name for th in threading.enumerate()
            if th.is_alive() and th.name.startswith("repro-")]
    assert left == []


# --------------------------------------------------- churn-safe slots
def test_midtick_close_skips_stamp(zoo_members, rng):
    """A close landing between a tick's gather and its stamp bumps the
    close version, so the stamp is SKIPPED (the gather may already
    have seen the newer samples) — the next tick scores the new
    window bitwise."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    eng = SlotEngine(svc, di)
    refs = _close_round(di, rng, [0, 1], t0=0.0)
    for p in (0, 1):
        eng.update(refs[p])
    newref = {}

    def hook():
        newref.update(_close_round(di, rng, [0], t0=1.0))
        eng.update(newref[0])
        eng._pre_stamp_hook = None

    eng._pre_stamp_hook = hook
    rep = eng.tick()
    assert 0 not in rep.stamped and 1 in rep.stamped
    assert np.isnan(eng.read(0))          # never scored; not wrong
    want1 = _oracle(svc, {0: refs[0], 1: refs[1]}, [0, 1])[1]
    assert eng.read(1) == want1
    rep2 = eng.tick()
    assert 0 in rep2.stamped
    want = _oracle(svc, {0: newref[0], 1: refs[1]}, [0, 1])
    assert np.array_equal(_reads(eng, [0, 1]), want)


def test_churn_hammer_never_wrong(zoo_members, rng):
    """Adversarial lifecycle hammer: one closer thread (the ingest
    plane's single feeder) closing windows and GROWING the census past
    its initial slots, a churn thread admitting/discharging at random,
    and a fast ticker.  Every (slot, version, rung) the engine ever
    stamped must rescore bitwise offline; re-stamps of the same key
    must agree."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(4)
    eng = SlotEngine(svc, di)
    eng.warm()
    rec, snaps, bad = {}, {}, []
    lock = threading.Lock()

    def on_tick(r):
        with lock:
            for s, v, sc in zip(r.stamped, r.versions, r.scores):
                key = (int(s), int(v), int(r.spad))
                prev = rec.get(key)
                if prev is not None and prev != float(sc):
                    bad.append(key)
                rec[key] = float(sc)

    eng.on_tick = on_tick
    stop = threading.Event()
    verc = {}

    def closer():
        rng2 = np.random.default_rng(11)
        t_row = {}
        rounds = 0
        while not stop.is_set():
            rounds += 1
            if rounds == 5:         # census outgrows the initial slots
                for _ in range(64):
                    if eng.n_grows:
                        break
                    eng.acquire_slot()
            slots = [int(s) for s in np.flatnonzero(eng.occupied)][:8]
            for s in slots:
                t0 = t_row.get(s, 0.0)
                di.ingest(t0, s, "ecg", rng2.standard_normal(
                    (3, 250)).astype(np.float32))
                ref = di.close_window(s, t0 + 1.0)
                t_row[s] = t0 + 1.0
                with lock:
                    v = verc.get(s, 0) + 1
                    verc[s] = v
                    snaps[(s, v)] = ref.host_window("ecg")
                eng.update(ref)
            # leave room for ticks to STAMP between close rounds: a
            # close mid-tick skips that slot's stamp (version guard),
            # so a closer outrunning the ticker stamps nothing
            time.sleep(0.05)

    def churner():
        rng3 = np.random.default_rng(7)
        while not stop.is_set():
            s = int(rng3.integers(0, eng.n_slots))
            try:
                if rng3.random() < 0.5:
                    eng.discharge(s)
                else:
                    eng.admit(s)
            except KeyError:
                pass
            time.sleep(0.001)

    ticker = SlotTicker(eng, interval=0.005).start()
    threads = [threading.Thread(target=closer, daemon=True),
               threading.Thread(target=churner, daemon=True)]
    for th in threads:
        th.start()
    time.sleep(2.0)
    stop.set()
    for th in threads:
        th.join(timeout=5.0)
    # the growth tick recompiles at the new rung — join generously
    assert ticker.stop(join_timeout=60.0)
    assert eng.n_grows >= 1 and eng.n_slots > 4
    assert not bad                  # re-stamps of a key always agree

    with lock:
        entries = sorted(rec.items())
    assert entries                  # the hammer actually stamped ticks
    zero = np.zeros((3, 250), np.float32)
    by_spad = {}
    for (s, v, spad), sc in entries:
        by_spad.setdefault(spad, []).append((s, v, sc))
    for spad, ents in by_spad.items():
        for i in range(0, len(ents), spad):
            chunk = ents[i:i + spad]
            wins = [snaps[(s, v)] for s, v, _ in chunk]
            wins += [zero] * (spad - len(wins))
            want = svc.predict_batch([{"ecg": w} for w in wins])
            for (s, v, sc), wsc in zip(chunk, want):
                assert sc == wsc, (s, v, spad)


# ------------------------------------------------- TickLadder + reads
def test_tickladder_swap_races_inflight_tick(zoo_members, rng):
    """``swap_to`` actuating mid-tick (the controller racing the
    ticker) must neither deadlock nor perturb the tick's scores."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    eng = SlotEngine(svc, di)
    ticker = SlotTicker(eng, interval=0.01)
    lad = TickLadder(ticker, intervals=(0.5, 0.05, 0.01))
    refs = _close_round(di, rng, [0, 1], t0=0.0)
    for p in (0, 1):
        eng.update(refs[p])
    hit = []

    def hook():
        lad.swap_to(0)
        hit.append(ticker.interval)
        eng._pre_stamp_hook = None

    eng._pre_stamp_hook = hook
    rep = eng.tick()
    assert hit == [0.5] and lad.ladder_pos == 0
    assert len(rep.stamped) == 2
    assert np.array_equal(_reads(eng, [0, 1]),
                          _oracle(svc, refs, [0, 1]))


def test_wait_scored_dead_ticker_times_out(zoo_members, rng):
    """With the ticker dead, a version-gated read times out cleanly to
    the NaN path — bounded wait, no hang, no invented score."""
    svc = EnsembleService(zoo_members)
    di = _make_ingest(2)
    eng = SlotEngine(svc, di)
    ticker = SlotTicker(eng, interval=0.01).start()
    assert ticker.stop()
    v = eng.update(_close_round(di, rng, [0], t0=0.0)[0])
    t0 = time.monotonic()
    assert not eng.wait_scored(0, v, timeout=0.2)
    assert time.monotonic() - t0 < 1.0
    assert np.isnan(eng.read(0))


# ------------------------------------------ monotonic clock + traces
def test_fault_plane_schedule_on_injected_clock(monkeypatch):
    """The schedule advances ONLY on the plane's injected monotonic
    clock: a wall-clock step (time.time jumping 30k years) changes
    nothing."""
    clk = FakeClock()
    plane = FaultPlane([FaultEvent(1.0, "device_loss", target=0,
                                   duration=2.0)], clock=clk)
    plane.arm(devices=[object()])
    monkeypatch.setattr(time, "time", lambda: 1e12)  # wall jump
    assert plane.active_losses() == {}
    clk.advance(1.5)
    assert 0 in plane.active_losses()
    clk.advance(2.0)                                 # t = 3.5 > 3.0
    assert plane.active_losses() == {}
    assert plane.done()
    assert any(r["kind"] == "device_restored" for r in plane.recoveries)


def test_protect_retry_budget_on_injected_clock():
    """``protect()``'s retry budget rides the SAME injected clock as
    the schedule — it expires when the plane's timeline says so, not
    wall time."""
    clk = FakeClock()
    plane = FaultPlane([FaultEvent(0.1, "device_loss", target=0,
                                   duration=0.0)], clock=clk)
    plane.arm(devices=[object()])
    clk.advance(0.2)                     # permanent loss, no swapper
    calls = []

    def fn(windows):
        calls.append(1)
        clk.advance(1.0)
        raise DeviceLostError(None, 0)

    guarded = plane.protect(fn, swapper=None, retry_budget_s=5.0,
                            retry_sleep=0.0)
    with pytest.raises(DeviceLostError):
        guarded([])
    assert 2 <= len(calls) <= 8          # retried, then gave up on the
    #                                      injected budget — not wall


def test_fault_trace_roundtrip(tmp_path):
    """to_json/from_json round-trips the schedule byte-for-byte, as
    text and as a committed trace file."""
    plane = FaultPlane(slot_compound_schedule(8, seed=3), seed=3)
    text = plane.to_json()
    p2 = FaultPlane.from_json(text)
    assert [e.to_dict() for e in p2.schedule] \
        == [e.to_dict() for e in plane.schedule]
    assert p2.seed == 3
    path = str(tmp_path / "trace.json")
    plane.to_json(path)
    p3 = FaultPlane.from_json(path)
    assert [e.to_dict() for e in p3.schedule] \
        == [e.to_dict() for e in plane.schedule]


def test_compound_schedule_shapes():
    """The compound generators keep their guaranteed shape on every
    seed: stall cascades, loss-inside-backpressure, permanent +
    transient overlap with survivors, transient-only without."""
    for nd in (1, 8):
        ev = compound_schedule(nd, seed=0)
        kinds = [e.kind for e in ev]
        assert kinds.count("worker_stall") == 2
        assert kinds.count("device_loss") == 2
        assert "backpressure" in kinds
        sev = slot_compound_schedule(nd, seed=0)
        skinds = [e.kind for e in sev]
        assert skinds.count("ticker_stall") == 2
        assert "worker_stall" not in skinds
        assert [e.t for e in sev] == sorted(e.t for e in sev)
    ev8 = compound_schedule(8, seed=0)
    losses = [e for e in ev8 if e.kind == "device_loss"]
    bp = next(e for e in ev8 if e.kind == "backpressure")
    perm = [e for e in losses if e.duration == 0]
    assert len(perm) == 1 and any(e.duration > 0 for e in losses)
    assert bp.t <= perm[0].t < bp.t + bp.duration
    assert all(e.duration > 0
               for e in compound_schedule(1, seed=0)
               if e.kind == "device_loss")
    a = [e.to_dict() for e in slot_compound_schedule(8, seed=1)]
    b = [e.to_dict() for e in slot_compound_schedule(8, seed=1)]
    assert a == b                        # deterministic in (n, seed)
    c = [e.to_dict() for e in slot_compound_schedule(8, seed=2)]
    assert a != c                        # the seed jitters timings


# ------------------------------------------------- multi-device lane
@needs_devices
@multi_device
def test_protect_engine_permanent_loss_rebind(zoo_members, rng):
    """Permanent device loss mid-tick on a sharded plan: quarantine,
    TickLadder shed during failover (undone after), rebind onto the
    survivor facade, re-tick — bitwise the UNSHARDED oracle."""
    from repro.control.swap import HotSwapper
    devices = jax.devices()
    pool = zoo_members
    rich = np.ones(len(pool), np.int8)
    swapper = HotSwapper(pool, rich, n_devices=4,
                         warmup_batch_sizes=(4,))
    di = _make_ingest(4)
    eng = SlotEngine(swapper.facade.current, di)
    ticker = SlotTicker(eng, interval=0.02)
    lad = TickLadder(ticker, intervals=(0.08, 0.02))
    clk = FakeClock()
    plane = FaultPlane([FaultEvent(0.1, "device_loss", target=1,
                                   duration=0.0)], clock=clk)
    plane.arm(swapper)
    plane.protect_engine(eng, swapper, ticker=ticker, tick_ladder=lad)
    pts = [0, 1, 2, 3]
    refs = _close_round(di, rng, pts, t0=0.0)
    for p in pts:
        eng.update(refs[p])
    eng.tick()                            # pre-loss baseline
    clk.advance(1.0)                      # permanent loss fires
    rep = eng.tick()                      # recover INSIDE the tick
    assert eng.n_tick_faults >= 1 and eng.n_tick_aborts == 0
    assert eng.n_rebinds >= 1
    assert devices[1] in swapper.quarantined
    assert sorted(map(int, rep.stamped)) == pts
    assert lad.ladder_pos == len(lad.ladder) - 1   # shed undone
    oracle = EnsembleService(pool)        # unsharded, fault-free
    assert np.array_equal(_reads(eng, pts), _oracle(oracle, refs, pts))


def test_multi_device_lane_subprocess():
    """Single-device lane: re-run this module's ``multi_device``
    selection under 8 forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={N_FORCED}")
    env.pop("PYTEST_CURRENT_TEST", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-m", "multi_device"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=900)
    tail = (r.stdout or "") + (r.stderr or "")
    assert r.returncode == 0, tail[-4000:]
    assert " passed" in r.stdout, tail[-2000:]
    assert " skipped" not in r.stdout, tail[-2000:]
