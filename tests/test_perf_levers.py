"""Numerics tests for the §Perf beyond-paper levers: they must be exact
(or float-tolerance) rewrites of the baseline semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, st

from repro.configs.registry import get_config
from repro.kernels import ref
from repro.launch.mesh import make_host_mesh
from repro.models import moe as moe_mod
from repro.models.api import get_model
from repro.models.runtime import RuntimeOptions

KEY = jax.random.PRNGKey(0)


@given(st.integers(1, 3), st.sampled_from([16, 48, 64]),
       st.sampled_from([1, 2, 4]), st.sampled_from([16, 32]),
       st.booleans(), st.sampled_from([0, 24]))
@settings(max_examples=20, deadline=None)
def test_chunked_attention_matches_plain(B, S, Hkv, D, causal, window):
    Hq = Hkv * 2
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.arange(S)
    a = ref.attention(q, k, v, pos, pos, causal=causal, window=window)
    b = ref.attention_chunked(q, k, v, pos, pos, causal=causal,
                              window=window, chunk=16)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b",
                                  "deepseek-v2-lite-16b"])
def test_moe_shard_map_matches_gspmd(arch):
    cfg = get_config(arch).reduced()
    p = moe_mod.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.1
    y1, a1 = moe_mod.moe_apply(p, x, cfg)
    y2, a2 = moe_mod.moe_apply_sharded(p, x, cfg, make_host_mesh())
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-6)


def test_moe_shard_map_grads_match():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    p = moe_mod.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 8, cfg.d_model)) * 0.1
    mesh = make_host_mesh()

    def loss_g(p):
        y, aux = moe_mod.moe_apply(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    def loss_s(p):
        y, aux = moe_mod.moe_apply_sharded(p, x, cfg, mesh)
        return jnp.sum(y ** 2) + aux

    g1 = jax.grad(loss_g)(p)
    g2 = jax.grad(loss_s)(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_absorbed_mla_matches_materialized():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    model = get_model(cfg)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
    outs = {}
    for absorbed in (False, True):
        rt = RuntimeOptions(absorbed_mla=absorbed)
        params = model.init(KEY, cfg, rt)
        outs[absorbed], _ = model.forward(params, toks, cfg, rt)
    np.testing.assert_allclose(outs[False], outs[True], rtol=2e-3,
                               atol=2e-3)


def test_chunked_attention_in_model_forward():
    cfg = get_config("qwen3-4b").reduced()
    model = get_model(cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    params = model.init(KEY, cfg, RuntimeOptions())
    base, _ = model.forward(params, toks, cfg, RuntimeOptions())
    chunked, _ = model.forward(params, toks, cfg,
                               RuntimeOptions(attn_chunk=16))
    np.testing.assert_allclose(base, chunked, rtol=1e-4, atol=1e-4)
