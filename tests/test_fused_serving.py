"""Fused stacked-member ensemble serving + cross-patient micro-batching:

* bucketed/stacked ``predict`` must match the per-member loop to 1e-5;
* ``predict_batch`` must match per-patient ``predict``;
* dispatch counts collapse from n_members to n_buckets;
* ``MicroBatcher`` flush semantics (max_batch / max_wait bounds);
* batch-aware ``EnsembleServer`` workers and the ``drain()`` race fix.
"""
import threading
import time

import numpy as np
import pytest

from repro.configs.ecg_zoo import bucket_key, bucket_zoo, zoo_specs
from repro.serving.pipeline import EnsembleService
from repro.serving.queues import MicroBatcher
from repro.serving.server import EnsembleServer


# ------------------------------------------------------------- bucketing
def test_reduced_zoo_buckets_4():
    specs = zoo_specs(reduced=True)
    buckets = bucket_zoo(specs)
    assert len(buckets) == 4                    # 2 widths x 2 block counts
    assert sorted(i for idx in buckets.values() for i in idx) \
        == list(range(12))
    for key, idx in buckets.items():
        assert len(idx) == 3                    # the 3 leads fold in
        assert {bucket_key(specs[i]) for i in idx} == {key}


def test_full_zoo_buckets_20():
    assert len(bucket_zoo(zoo_specs(reduced=False))) == 20


# ----------------------------------------------------------- equivalence
@pytest.fixture(scope="module")
def services(zoo_members):
    fused = EnsembleService(zoo_members, fused=True)
    loop = EnsembleService(zoo_members, fused=False)
    return fused, loop


def _windows(rng, n=1, L=250):
    return [{"ecg": rng.standard_normal((3, L)).astype(np.float32)}
            for _ in range(n)]


def test_fused_predict_matches_member_loop(services, rng):
    fused, loop = services
    for w in _windows(rng, n=3):
        assert fused.predict(w) == pytest.approx(loop.predict(w),
                                                 abs=1e-5)


def test_predict_batch_matches_per_patient_predict(services, rng):
    fused, _ = services
    batch = _windows(rng, n=5)
    got = fused.predict_batch(batch)
    want = [fused.predict(w) for w in batch]
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_fused_dispatch_count_is_n_buckets(services, rng):
    fused, loop = services
    batch = _windows(rng, n=4)
    d0 = fused.dispatch_count
    fused.predict_batch(batch)
    assert fused.dispatch_count - d0 == fused.n_buckets == 4
    d0 = loop.dispatch_count
    loop.predict(batch[0])
    assert loop.dispatch_count - d0 == len(loop.members) == 12


def test_fused_with_cpu_side_models(zoo_members, rng):
    class Const:
        def __init__(self, v):
            self.v = v

        def predict_proba(self, x):
            return np.full(len(x), self.v)

    svc = EnsembleService(zoo_members, vitals_model=Const(0.9),
                          labs_model=Const(0.1))
    ref = EnsembleService(zoo_members, vitals_model=Const(0.9),
                          labs_model=Const(0.1), fused=False)
    w = _windows(rng)[0]
    w["vitals"] = rng.standard_normal((7, 3)).astype(np.float32)
    w["labs"] = rng.standard_normal(8).astype(np.float32)
    assert svc.predict(w) == pytest.approx(ref.predict(w), abs=1e-5)
    no_labs = {k: v for k, v in w.items() if k != "labs"}
    assert svc.predict(no_labs) == pytest.approx(ref.predict(no_labs),
                                                 abs=1e-5)


def test_empty_batch():
    assert EnsembleService([]).predict_batch([]) == []


def test_short_window_zero_padded_both_paths(services, rng):
    """ECG windows shorter than input_len are left-zero-filled (the
    aggregator convention) on BOTH paths, and they still agree."""
    fused, loop = services
    w = {"ecg": rng.standard_normal((3, 100)).astype(np.float32)}
    got = fused.predict(w)
    assert 0.0 <= got <= 1.0
    assert got == pytest.approx(loop.predict(w), abs=1e-5)


# ---------------------------------------------------------- MicroBatcher
def test_microbatcher_flushes_on_max_batch():
    t = [0.0]
    mb = MicroBatcher(max_batch=3, max_wait_ms=1e6, clock=lambda: t[0])
    mb.push("a"), mb.push("b")
    assert not mb.ready()
    mb.push("c")
    assert mb.ready()
    assert mb.pop_batch() == ["a", "b", "c"]
    assert not mb.ready() and len(mb) == 0


def test_microbatcher_flushes_on_max_wait():
    t = [0.0]
    mb = MicroBatcher(max_batch=100, max_wait_ms=5.0, clock=lambda: t[0])
    mb.push("a")
    assert not mb.ready()
    t[0] = 0.006                              # oldest waited > 5 ms
    assert mb.ready()
    assert mb.pop_batch() == ["a"]


def test_microbatcher_pop_bounded_and_stats():
    t = [0.0]
    mb = MicroBatcher(max_batch=2, max_wait_ms=0.0, clock=lambda: t[0])
    for i in range(5):
        mb.push(i)
    assert mb.pop_batch() == [0, 1]
    assert mb.pop_batch() == [2, 3]
    assert mb.pop_batch() == [4]
    assert mb.pop_batch() == []
    assert mb.stats.n_items == 5
    assert mb.stats.n_flushes == 3
    assert mb.stats.max_batch_seen == 2
    assert mb.stats.mean_batch == pytest.approx(5 / 3)


# -------------------------------------------------- batch-aware server
def test_server_batched_handler_serves_all():
    seen_batches = []

    def batch_handler(windows):
        seen_batches.append(len(windows))
        time.sleep(0.002)
        return [float(w["x"]) for w in windows]

    srv = EnsembleServer(batch_handler=batch_handler, n_workers=2,
                         max_batch=4, max_wait_ms=2.0).start()
    n = 32
    for i in range(n):
        assert srv.submit(i, {"x": i})
    stats = srv.stop()
    assert stats.served == n
    assert sum(seen_batches) == n
    got = sorted(srv.results())
    assert [p for p, *_ in got] == list(range(n))
    for p, score, *_ in got:
        assert score == float(p)              # right answer to right query
    assert max(seen_batches) > 1              # coalescing actually happened


def test_server_batched_poison_query_isolated():
    """One bad query must not kill the worker, drop its co-batched
    healthy queries, or hang stop() on un-retired tasks."""
    def batch_handler(windows):
        if any(w.get("bad") for w in windows):
            raise ValueError("poison window")
        return [1.0] * len(windows)

    srv = EnsembleServer(batch_handler=batch_handler, n_workers=1,
                         max_batch=4, max_wait_ms=50.0).start()
    for i in range(8):
        srv.submit(i, {"bad": i == 3})
    t0 = time.monotonic()
    stats = srv.stop()
    assert time.monotonic() - t0 < 5.0        # no drain-timeout hang
    assert stats.served == 8
    scores = {p: s for p, s, *_ in srv.results()}
    assert np.isnan(scores[3])
    assert all(scores[p] == 1.0 for p in scores if p != 3)


def test_server_scalar_handler_still_works():
    srv = EnsembleServer(handler=lambda w: 0.5, n_workers=2).start()
    for i in range(8):
        srv.submit(i, {})
    stats = srv.stop()
    assert stats.served == 8


def test_server_drain_waits_for_inflight_handler():
    """A slow handler must be COUNTED by stop(): drain() waits for
    unfinished tasks, not just an empty ingest queue."""
    release = threading.Event()

    def handler(w):
        release.wait(timeout=5.0)
        return 1.0

    srv = EnsembleServer(handler=handler, n_workers=1).start()
    srv.submit(0, {})
    time.sleep(0.2)              # worker popped it; queue now empty
    assert srv.q.empty()
    threading.Timer(0.1, release.set).start()
    stats = srv.stop()           # must wait for the in-flight handler
    assert stats.served == 1


def test_server_drain_timeout_returns():
    srv = EnsembleServer(handler=lambda w: time.sleep(1.0) or 0.0,
                         n_workers=1).start()
    srv.submit(0, {})
    t0 = time.monotonic()
    srv.drain(timeout=0.05)
    assert time.monotonic() - t0 < 0.5
    srv.stop()
